"""Paper Figs. 9-10: SmallBank throughput scaling (20% / 50% distributed).
SmallBank's short transactions stress coordinator round-trips — this is where
conventional SI (and DSI at high dist%) hit the coordination wall."""
import numpy as np

from repro.core.workloads import smallbank_waves

from .simcost import DEFAULT_WAVES, KEYS_PER_NODE, print_table, simulate, wave_size

SCHEDS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")


def run(fast: bool = True, dist_frac: float = 0.2):
    nodes = (4, 8, 16, 29) if fast else (2, 4, 8, 16, 24, 29)
    rows = []
    for n in nodes:
        rng = np.random.RandomState(7)
        waves = smallbank_waves(rng, DEFAULT_WAVES, wave_size(n), n,
                                KEYS_PER_NODE, dist_frac=dist_frac)
        for sched in SCHEDS:
            hs = None
            if sched == "clocksi":
                hs = np.round(np.linspace(0, 2, n)).astype(np.int32)
            r = simulate(waves, sched, n, host_skew=hs)
            r["dist_pct"] = int(dist_frac * 100)
            rows.append(r)
    return rows


def main():
    for dist in (0.2, 0.5):
        rows = run(dist_frac=dist)
        print_table(rows, ["sched", "n_nodes", "throughput_tps", "abort_pct",
                           "msgs_per_txn"],
                    f"Fig {'9' if dist == 0.2 else '10'}: SmallBank scaling "
                    f"({int(dist*100)}% distributed)")


if __name__ == "__main__":
    main()
