"""Distributed wave-engine benchmark: the decentralized-scaling story on a
virtual-device mesh (DESIGN.md §4).

Needs more than one XLA device, so ``main()`` defaults
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* importing
jax (the device count is locked at jax init) — which is also why the
``benchmarks.run dist`` block shells out to this module instead of calling
into it.  Three sections, all through the ONE shared commit loop
(``engine.run_wave_on``) over a ``MeshSubstrate``:

* **scaling** — goodput (committed txns/s) for every scheduler × node
  count, fused executor, fixed total key space (so more nodes = smaller
  blocks + more peer-collective fan-in, the paper's §V scaling axis);
* **executor** — fused scan-on-mesh vs per-wave dispatch at max nodes:
  the host-sync tax measured on the distributed path;
* **service** — one closed-loop SmallBank session served from the mesh
  (``TxnService(mesh=...)``) against the identical single-device session:
  commits must match exactly, walls differ.

Prints ``name,us_per_call,derived`` CSV rows (aggregator format) and writes
``BENCH_dist.json`` at the repo root.

Run:  PYTHONPATH=src python -m benchmarks.bench_dist [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_dist.json")

N_WAVES = 8
WAVE_T = 64
N_KEYS = 512            # divisible by every node count below
NODE_COUNTS = (1, 2, 4, 8)
LOAD_FACTOR = 0.9
SVC_TICKS = 10

SMOKE = dict(n_waves=3, T=16, node_counts=(1, 2), svc_ticks=5,
             scheds=("postsi", "si"))


def _mk_waves(n_waves: int, T: int, n_nodes: int, n_keys: int):
    import numpy as np
    from repro.core.workloads import smallbank_waves
    return smallbank_waves(np.random.RandomState(7), n_waves, T, n_nodes,
                           n_keys // n_nodes, dist_frac=0.3, hot_frac=0.4,
                           hot_per_node=4)


def _host_skew(sched: str, n_nodes: int):
    import numpy as np
    return (np.round(np.linspace(0, 2, n_nodes)).astype(np.int32)
            if sched == "clocksi" else None)


def _timed(setup, fn, reps: int = 3):
    """(result, best wall seconds, warmup seconds) for ``fn(setup())``.
    The first call pays jit outside the timers but its wall is *recorded*
    (compile cost is reported, not hidden); each rep's fresh store
    (allocation + device_put sharding) is built and synced *before* its
    timer starts, and every timed region ends with ``block_until_ready``
    on the actual outputs — only synced mesh execution is measured."""
    import jax
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(setup()))
    warmup = time.perf_counter() - t0
    best = float("inf")
    for _ in range(reps):
        arg = jax.block_until_ready(setup())
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(arg))
        best = min(best, time.perf_counter() - t0)
    return out, best, warmup


def _scaling(scheds, node_counts, n_waves, T) -> Dict:
    from repro.core import make_store
    from repro.core.dist_engine import (make_node_mesh, run_workload_fused_dist,
                                        shard_store)
    rows = []
    for n in node_counts:
        mesh = make_node_mesh(n)
        waves = _mk_waves(n_waves, T, n, N_KEYS)
        for sched in scheds:
            hs = _host_skew(sched, n)

            def setup():
                return shard_store(make_store(N_KEYS, 8), mesh)

            def run(st):
                return run_workload_fused_dist(st, waves, mesh, sched=sched,
                                               n_nodes=n, host_skew=hs)

            (_, _, stats), wall, warm = _timed(setup, run)
            n_txn = stats.committed + stats.aborted
            rows.append({
                "sched": sched, "n_nodes": n, "wall_s": round(wall, 6),
                "warmup_s": round(warm, 6),
                "committed": stats.committed, "aborted": stats.aborted,
                "goodput_tps": round(stats.committed / wall, 1),
                "txns_per_sec": round(n_txn / wall, 1),
                "msgs_cross": stats.msgs_cross,
            })
    return {"rows": rows}


def _executor(scheds, n, n_waves, T) -> Dict:
    from repro.core import make_store
    from repro.core.dist_engine import (make_node_mesh, run_workload_dist,
                                        run_workload_fused_dist, shard_store)
    mesh = make_node_mesh(n)
    waves = _mk_waves(n_waves, T, n, N_KEYS)
    rows = []
    for sched in scheds:
        hs = _host_skew(sched, n)

        def setup():
            return shard_store(make_store(N_KEYS, 8), mesh)

        def per_wave(st):
            return run_workload_dist(st, waves, mesh, sched=sched, n_nodes=n,
                                     host_skew=hs)

        def fused(st):
            return run_workload_fused_dist(st, waves, mesh, sched=sched,
                                           n_nodes=n, host_skew=hs)

        (_, h1, s1), wall_pw, warm_pw = _timed(setup, per_wave)
        (_, h2, s2), wall_fz, warm_fz = _timed(setup, fused)
        assert s1 == s2, (sched, s1, s2)    # bit-identical by construction
        rows.append({
            "sched": sched, "n_nodes": n,
            "per_wave_wall_s": round(wall_pw, 6),
            "fused_wall_s": round(wall_fz, 6),
            "per_wave_warmup_s": round(warm_pw, 6),
            "fused_warmup_s": round(warm_fz, 6),
            "speedup": round(wall_pw / wall_fz, 2),
            "committed": s1.committed, "aborted": s1.aborted,
        })
    return {"n_nodes": n, "rows": rows}


def _service(n, T, n_ticks, sched: str = "postsi") -> Dict:
    import numpy as np
    from repro.core.dist_engine import make_node_mesh
    from repro.core.workloads import poisson_arrivals
    from repro.service import RetryPolicy, TxnService, smallbank_txn_gen
    mesh = make_node_mesh(n)
    out = {}
    for tag, m in (("single", None), ("mesh", mesh)):
        svc = TxnService(n_keys=N_KEYS, n_versions=8, T=T, sched=sched,
                         n_nodes=n, retry=RetryPolicy(max_attempts=6),
                         seed=0, mesh=m)
        arrivals = poisson_arrivals(np.random.RandomState(100),
                                    LOAD_FACTOR * T, n_ticks)
        gen = smallbank_txn_gen(np.random.RandomState(200), n, N_KEYS // n,
                                dist_frac=0.3, hot_frac=0.5, hot_per_node=4)
        rep = svc.run_stream(arrivals, gen)
        row = rep.as_dict()
        row["verify_errors"] = len(svc.verify())
        out[tag] = row
    assert out["single"]["committed"] == out["mesh"]["committed"], out
    return out


def run(smoke: bool = False) -> Dict:
    import jax
    from repro.core import SCHEDULERS
    from repro.core.substrate import effective_mesh_backend
    if smoke:
        n_waves, T = SMOKE["n_waves"], SMOKE["T"]
        node_counts, scheds = SMOKE["node_counts"], SMOKE["scheds"]
        svc_ticks = SMOKE["svc_ticks"]
    else:
        n_waves, T, svc_ticks = N_WAVES, WAVE_T, SVC_TICKS
        node_counts, scheds = NODE_COUNTS, SCHEDULERS
    node_counts = tuple(n for n in node_counts if n <= jax.device_count())
    n_max = max(node_counts)
    return {
        "config": {"workload": "smallbank", "n_waves": n_waves,
                   "wave_size": T, "n_keys": N_KEYS,
                   "node_counts": list(node_counts),
                   "device_count": jax.device_count(), "smoke": smoke,
                   # honest label: what the mesh rows below actually ran —
                   # a 'pallas' process default degrades to 'jnp' on the
                   # mesh path (substrate.mesh_kernels warns and counts)
                   "kernel_backend": effective_mesh_backend()},
        "scaling": _scaling(scheds, node_counts, n_waves, T),
        "executor": _executor(scheds, n_max, n_waves, T),
        "service": _service(n_max, T, svc_ticks),
    }


def write_report(report: Dict) -> None:
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def print_csv(report: Dict) -> None:
    """Aggregator-format rows (``name,us_per_call,derived``)."""
    for r in report["scaling"]["rows"]:
        n_txn = max(r["committed"] + r["aborted"], 1)
        print(f"dist/fused/{r['sched']}/n{r['n_nodes']},"
              f"{r['wall_s'] * 1e6 / n_txn:.2f},"
              f"goodput={r['goodput_tps']:.0f}tps "
              f"cross/txn={r['msgs_cross'] / n_txn:.2f}", flush=True)
    for r in report["executor"]["rows"]:
        n_txn = max(r["committed"] + r["aborted"], 1)
        print(f"dist/executor/{r['sched']}/n{r['n_nodes']},"
              f"{r['fused_wall_s'] * 1e6 / n_txn:.2f},"
              f"fused_vs_per_wave={r['speedup']:.2f}x", flush=True)
    for tag in ("single", "mesh"):
        r = report["service"][tag]
        print(f"dist/service/{tag}/{r['sched']},"
              f"{r['wall_s'] * 1e6 / max(r['executions'], 1):.2f},"
              f"goodput={r['goodput_tps']:.0f}tps committed={r['committed']} "
              f"verify_errors={r['verify_errors']}", flush=True)


def main(argv=None) -> Dict:
    argv = sys.argv[1:] if argv is None else argv
    report = run(smoke="--smoke" in argv)
    write_report(report)
    print_csv(report)
    return report


if __name__ == "__main__":
    # must precede the first jax import: device count is locked at init
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    main()
