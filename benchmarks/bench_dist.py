"""Distributed wave-engine benchmark: the decentralized-scaling story on a
virtual-device mesh (DESIGN.md §4).

Needs more than one XLA device, so ``main()`` defaults
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* importing
jax (the device count is locked at jax init) — which is also why the
``benchmarks.run dist`` block shells out to this module instead of calling
into it.  Three sections, all through the ONE shared commit loop
(``engine.run_wave_on``) over a ``MeshSubstrate``:

* **scaling** — goodput (committed txns/s) for every scheduler × node
  count, fused executor, fixed total key space (so more nodes = smaller
  blocks + more peer-collective fan-in, the paper's §V scaling axis);
* **executor** — fused scan-on-mesh vs per-wave dispatch at max nodes:
  the host-sync tax measured on the distributed path;
* **service** — one closed-loop SmallBank session served from the mesh
  (``TxnService(mesh=...)``) against the identical single-device session:
  commits must match exactly, walls differ.

Prints ``name,us_per_call,derived`` CSV rows (aggregator format) and writes
``BENCH_dist.json`` at the repo root.

Run:  PYTHONPATH=src python -m benchmarks.bench_dist [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_dist.json")

N_WAVES = 8
WAVE_T = 64
N_KEYS = 512            # divisible by every node count below
NODE_COUNTS = (1, 2, 4, 8)
LOAD_FACTOR = 0.9
SVC_TICKS = 10

SMOKE = dict(n_waves=3, T=16, node_counts=(1, 2), svc_ticks=5,
             scheds=("postsi", "si"))


def _mk_waves(n_waves: int, T: int, n_nodes: int, n_keys: int):
    import numpy as np
    from repro.core.workloads import smallbank_waves
    return smallbank_waves(np.random.RandomState(7), n_waves, T, n_nodes,
                           n_keys // n_nodes, dist_frac=0.3, hot_frac=0.4,
                           hot_per_node=4)


def _host_skew(sched: str, n_nodes: int):
    import numpy as np
    return (np.round(np.linspace(0, 2, n_nodes)).astype(np.int32)
            if sched == "clocksi" else None)


def _timed(setup, fn, reps: int = 3):
    """(result, best wall seconds, warmup seconds) for ``fn(setup())``.
    The first call pays jit outside the timers but its wall is *recorded*
    (compile cost is reported, not hidden); each rep's fresh store
    (allocation + device_put sharding) is built and synced *before* its
    timer starts, and every timed region ends with ``block_until_ready``
    on the actual outputs — only synced mesh execution is measured."""
    import jax
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(setup()))
    warmup = time.perf_counter() - t0
    best = float("inf")
    for _ in range(reps):
        arg = jax.block_until_ready(setup())
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(arg))
        best = min(best, time.perf_counter() - t0)
    return out, best, warmup


def _history_occupancy(history, n_nodes: int, kpn: int):
    """Per-node committed-txn occupancy from a driver/service history:
    each committed txn is attributed to the PHYSICAL block owner
    (``key // kpn``) of its first touched key — the node whose version
    rings its commit actually landed on under the static block layout."""
    import numpy as np
    occ = np.zeros(n_nodes, np.int64)
    for _, out in history:
        st = np.asarray(out.status)
        rk, wk = np.asarray(out.read_key), np.asarray(out.write_key)
        keys = np.where(rk >= 0, rk, wk)              # [T, O], -1 = no op
        for t in np.nonzero(st == 1)[0]:              # COMMITTED
            touched = keys[t][keys[t] >= 0]
            if touched.size:
                occ[int(touched[0]) // kpn] += 1
    return occ


def _imbalance(occ) -> float:
    return round(float(occ.max() / occ.mean()), 4) if occ.sum() else 0.0


def _scaling(scheds, node_counts, n_waves, T) -> Dict:
    from repro.core import make_store
    from repro.core.dist_engine import (make_node_mesh, run_workload_fused_dist,
                                        shard_store)
    rows = []
    for n in node_counts:
        mesh = make_node_mesh(n)
        waves = _mk_waves(n_waves, T, n, N_KEYS)
        for sched in scheds:
            hs = _host_skew(sched, n)

            def setup():
                return shard_store(make_store(N_KEYS, 8), mesh)

            def run(st):
                return run_workload_fused_dist(st, waves, mesh, sched=sched,
                                               n_nodes=n, host_skew=hs)

            (_, hist, stats), wall, warm = _timed(setup, run)
            n_txn = stats.committed + stats.aborted
            occ = _history_occupancy(hist, n, N_KEYS // n)
            rows.append({
                "sched": sched, "n_nodes": n, "wall_s": round(wall, 6),
                "warmup_s": round(warm, 6),
                "committed": stats.committed, "aborted": stats.aborted,
                "goodput_tps": round(stats.committed / wall, 1),
                "txns_per_sec": round(n_txn / wall, 1),
                "msgs_cross": stats.msgs_cross,
                "occupancy": occ.tolist(),
                "imbalance": _imbalance(occ),
            })
    return {"rows": rows}


def _executor(scheds, n, n_waves, T) -> Dict:
    from repro.core import make_store
    from repro.core.dist_engine import (make_node_mesh, run_workload_dist,
                                        run_workload_fused_dist, shard_store)
    mesh = make_node_mesh(n)
    waves = _mk_waves(n_waves, T, n, N_KEYS)
    rows = []
    for sched in scheds:
        hs = _host_skew(sched, n)

        def setup():
            return shard_store(make_store(N_KEYS, 8), mesh)

        def per_wave(st):
            return run_workload_dist(st, waves, mesh, sched=sched, n_nodes=n,
                                     host_skew=hs)

        def fused(st):
            return run_workload_fused_dist(st, waves, mesh, sched=sched,
                                           n_nodes=n, host_skew=hs)

        (_, h1, s1), wall_pw, warm_pw = _timed(setup, per_wave)
        (_, h2, s2), wall_fz, warm_fz = _timed(setup, fused)
        assert s1 == s2, (sched, s1, s2)    # bit-identical by construction
        rows.append({
            "sched": sched, "n_nodes": n,
            "per_wave_wall_s": round(wall_pw, 6),
            "fused_wall_s": round(wall_fz, 6),
            "per_wave_warmup_s": round(warm_pw, 6),
            "fused_warmup_s": round(warm_fz, 6),
            "speedup": round(wall_pw / wall_fz, 2),
            "committed": s1.committed, "aborted": s1.aborted,
        })
    return {"n_nodes": n, "rows": rows}


def _service(n, T, n_ticks, sched: str = "postsi") -> Dict:
    import numpy as np
    from repro.core.dist_engine import make_node_mesh
    from repro.core.workloads import poisson_arrivals
    from repro.service import RetryPolicy, TxnService, smallbank_txn_gen
    mesh = make_node_mesh(n)
    out = {}
    for tag, m in (("single", None), ("mesh", mesh)):
        svc = TxnService(n_keys=N_KEYS, n_versions=8, T=T, sched=sched,
                         n_nodes=n, retry=RetryPolicy(max_attempts=6),
                         seed=0, mesh=m)
        arrivals = poisson_arrivals(np.random.RandomState(100),
                                    LOAD_FACTOR * T, n_ticks)
        gen = smallbank_txn_gen(np.random.RandomState(200), n, N_KEYS // n,
                                dist_frac=0.3, hot_frac=0.5, hot_per_node=4)
        rep = svc.run_stream(arrivals, gen)
        row = rep.as_dict()
        row["verify_errors"] = len(svc.verify())
        out[tag] = row
    assert out["single"]["committed"] == out["mesh"]["committed"], out
    return out


ELASTIC_THETA = 0.99
ELASTIC_READ_FRAC = 0.97
ELASTIC_N_OPS = 2
ELASTIC_TICKS = 20
ELASTIC_REFRESH = 8
ELASTIC_LOAD = 3         # arrivals per tick = LOAD * T: offer more than the
                         # engine's admission cap (4T queue) can absorb, the
                         # open-system regime where static load-shedding
                         # starts rejecting but replica-served reads never
                         # enter the queue at all
ELASTIC_MASS = 0.95      # replica hot set: rank prefix covering this much
ELASTIC_MAX_FRAC = 0.4   # of the zipf mass, capped at 40% of the key space


def _elastic(node_counts, T, n_ticks) -> Dict:
    """Static vs elastic service pairs on IDENTICAL zipf θ=0.99 read-heavy
    streams (paper §V-D's hot-shard regime: the interleaved key encoding
    lands every node's rank-0 hot keys in node 0's physical block, so the
    static mesh serializes on one node while the others idle).

    Two goodput columns per row, honestly labeled:

    * ``goodput_tps`` — MEASURED committed/s on the virtual-device mesh.
      The elastic lever that moves this number is real: hot-key read-only
      txns are answered from the visibility-floor replicas at submit time
      and never enter the engine, so the elastic service dispatches roughly
      half the waves for the same committed work.
    * ``modeled_goodput_tps`` — simcost.py's cluster cost model (T_OP per
      executed op on the OWNING node) with the makespan taken as the MAX
      per-node busy time from measured occupancy, not the perfect-balance
      ``/ n_nodes`` the static model assumes.  Replica-served reads cost
      the engine nothing (a host hashmap hit at submit).  Cross-node
      message latency is excluded (the service report does not split
      messages per node); the column isolates the load-balance axis.
    """
    import numpy as np
    from repro.core.dist_engine import make_node_mesh
    from repro.core.workloads import zipf_hot_keys
    from repro.placement import PlacementMap
    from repro.service import TxnService, ycsb_txn_gen
    from .simcost import T_OP
    rows = []
    for n in node_counts:
        kpn = N_KEYS // n
        mesh = make_node_mesh(n)
        row = {"n_nodes": n, "theta": ELASTIC_THETA}

        def make_svc(elastic: bool) -> TxnService:
            return TxnService(
                n_keys=N_KEYS, n_versions=8, T=T, O=ELASTIC_N_OPS,
                sched="postsi", n_nodes=n, seed=0, mesh=mesh,
                placement=(PlacementMap(N_KEYS, n, headroom=2)
                           if elastic else None),
                replicas=(zipf_hot_keys(n, kpn, ELASTIC_THETA,
                                        mass=ELASTIC_MASS,
                                        max_frac=ELASTIC_MAX_FRAC)
                          if elastic else None),
                balancer=elastic or None, replica_refresh=ELASTIC_REFRESH)

        def stream():
            return ycsb_txn_gen(np.random.RandomState(31), n, kpn,
                                theta=ELASTIC_THETA,
                                read_frac=ELASTIC_READ_FRAC,
                                n_ops=ELASTIC_N_OPS)

        for tag in ("static", "elastic"):
            elastic = tag == "elastic"
            # _timed's policy applied to service sessions: XLA compiles
            # (wave fn, replica-refresh gather, move kernel pad sizes) are
            # paid by a discarded warmup session, the measured run is
            # steady-state.  The jitted fns are lru-cached per mesh/shape,
            # so a fresh service reuses them.
            warm = make_svc(elastic)
            warm.run_stream([ELASTIC_LOAD * T] * 4, stream())
            if elastic:
                for m in (5, 10, 20, 40):    # move pads 8/16/32/64
                    lo = int(np.argmax(warm.placement.owner
                                       == warm.placement.owner[0]))
                    warm.move_range(lo, lo + m,
                                    (int(warm.placement.owner[lo]) + 1) % n)
            svc = rep = None
            for _ in range(3):               # _timed's reps policy: best of 3
                cand = make_svc(elastic)
                r = cand.run_stream([ELASTIC_LOAD * T] * n_ticks, stream())
                if rep is None or r.wall_s < rep.wall_s:
                    svc, rep = cand, r
            occ = (np.asarray(rep.occupancy, np.int64) if elastic
                   else _history_occupancy(svc.history, n, kpn))
            busy_us = occ * ELASTIC_N_OPS * T_OP
            makespan_us = float(busy_us.max()) or T_OP
            row[tag] = {
                "committed": rep.committed,
                "offered": rep.offered,
                "rejected": rep.rejected,
                "wall_s": round(rep.wall_s, 6),
                "goodput_tps": round(rep.goodput_tps, 1),
                "modeled_goodput_tps": round(
                    rep.committed / makespan_us * 1e6, 1),
                "waves": rep.waves,
                "occupancy": occ.tolist(),
                "imbalance": _imbalance(occ),
                "replica_commits": rep.replica_commits,
                "placement_moves": rep.placement_moves,
                "moved_keys": rep.moved_keys,
                "verify_errors": len(svc.verify()),
            }
        row["goodput_ratio"] = round(
            row["elastic"]["goodput_tps"]
            / max(row["static"]["goodput_tps"], 1e-9), 2)
        row["modeled_ratio"] = round(
            row["elastic"]["modeled_goodput_tps"]
            / max(row["static"]["modeled_goodput_tps"], 1e-9), 2)
        rows.append(row)
    return {"read_frac": ELASTIC_READ_FRAC, "n_ops": ELASTIC_N_OPS,
            "ticks": n_ticks, "wave_T": T, "rows": rows}


def run(smoke: bool = False) -> Dict:
    import jax
    from repro.core import SCHEDULERS
    from repro.core.substrate import effective_mesh_backend
    if smoke:
        n_waves, T = SMOKE["n_waves"], SMOKE["T"]
        node_counts, scheds = SMOKE["node_counts"], SMOKE["scheds"]
        svc_ticks = SMOKE["svc_ticks"]
    else:
        n_waves, T, svc_ticks = N_WAVES, WAVE_T, SVC_TICKS
        node_counts, scheds = NODE_COUNTS, SCHEDULERS
    node_counts = tuple(n for n in node_counts if n <= jax.device_count())
    n_max = max(node_counts)
    return {
        "config": {"workload": "smallbank", "n_waves": n_waves,
                   "wave_size": T, "n_keys": N_KEYS,
                   "node_counts": list(node_counts),
                   "device_count": jax.device_count(), "smoke": smoke,
                   # honest label: what the mesh rows below actually ran —
                   # a 'pallas' process default degrades to 'jnp' on the
                   # mesh path (substrate.mesh_kernels warns and counts)
                   "kernel_backend": effective_mesh_backend()},
        "scaling": _scaling(scheds, node_counts, n_waves, T),
        "executor": _executor(scheds, n_max, n_waves, T),
        "service": _service(n_max, T, svc_ticks),
        "elastic": _elastic(node_counts, T,
                            max(3, ELASTIC_TICKS // 2) if smoke
                            else ELASTIC_TICKS),
    }


def write_report(report: Dict) -> None:
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def print_csv(report: Dict) -> None:
    """Aggregator-format rows (``name,us_per_call,derived``)."""
    for r in report["scaling"]["rows"]:
        n_txn = max(r["committed"] + r["aborted"], 1)
        print(f"dist/fused/{r['sched']}/n{r['n_nodes']},"
              f"{r['wall_s'] * 1e6 / n_txn:.2f},"
              f"goodput={r['goodput_tps']:.0f}tps "
              f"cross/txn={r['msgs_cross'] / n_txn:.2f}", flush=True)
    for r in report["executor"]["rows"]:
        n_txn = max(r["committed"] + r["aborted"], 1)
        print(f"dist/executor/{r['sched']}/n{r['n_nodes']},"
              f"{r['fused_wall_s'] * 1e6 / n_txn:.2f},"
              f"fused_vs_per_wave={r['speedup']:.2f}x", flush=True)
    for tag in ("single", "mesh"):
        r = report["service"][tag]
        print(f"dist/service/{tag}/{r['sched']},"
              f"{r['wall_s'] * 1e6 / max(r['executions'], 1):.2f},"
              f"goodput={r['goodput_tps']:.0f}tps committed={r['committed']} "
              f"verify_errors={r['verify_errors']}", flush=True)
    for row in report.get("elastic", {}).get("rows", []):
        for tag in ("static", "elastic"):
            r = row[tag]
            print(f"dist/elastic/{tag}/n{row['n_nodes']},"
                  f"{r['wall_s'] * 1e6 / max(r['committed'], 1):.2f},"
                  f"goodput={r['goodput_tps']:.0f}tps "
                  f"modeled={r['modeled_goodput_tps']:.0f}tps "
                  f"imbalance={r['imbalance']:.2f} "
                  f"replica_commits={r['replica_commits']} "
                  f"moves={r['placement_moves']}", flush=True)


def elastic_smoke() -> Dict:
    """CI gate (the ``elastic-smoke`` workflow leg): elastic must beat
    static at the paper's hardest skew on the full 8-virtual-device mesh,
    with zero silent kernel degrades, and the artifacts go to
    ``artifacts/elastic_smoke`` for the run page."""
    from repro.core.substrate import mesh_degrade_count
    import jax
    n = min(8, jax.device_count())
    report = {"config": {"n_nodes": n, "theta": ELASTIC_THETA,
                         "device_count": jax.device_count()},
              "elastic": _elastic((1, n), WAVE_T, ELASTIC_TICKS)}
    rows = report["elastic"]["rows"]
    by_n = {r["n_nodes"]: r for r in rows}
    top = by_n[n]
    assert top["elastic"]["goodput_tps"] >= top["static"]["goodput_tps"], top
    modeled = [r["elastic"]["modeled_goodput_tps"] for r in rows]
    assert modeled == sorted(modeled), \
        f"elastic modeled goodput not non-decreasing 1->{n}: {modeled}"
    assert top["elastic"]["verify_errors"] == 0, top
    assert mesh_degrade_count() == 0, mesh_degrade_count()
    out_dir = os.path.join(os.path.dirname(OUT_PATH), "artifacts",
                           "elastic_smoke")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "elastic_smoke.json"), "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for row in rows:
        print(f"elastic-smoke n={row['n_nodes']}: "
              f"static={row['static']['goodput_tps']:.0f}tps "
              f"elastic={row['elastic']['goodput_tps']:.0f}tps "
              f"(x{row['goodput_ratio']:.2f} measured, "
              f"x{row['modeled_ratio']:.2f} modeled) "
              f"replica_commits={row['elastic']['replica_commits']} "
              f"moves={row['elastic']['placement_moves']}", flush=True)
    print("ELASTIC-SMOKE-OK", flush=True)
    return report


def main(argv=None) -> Dict:
    argv = sys.argv[1:] if argv is None else argv
    if "--elastic-smoke" in argv:
        return elastic_smoke()
    report = run(smoke="--smoke" in argv)
    write_report(report)
    print_csv(report)
    return report


if __name__ == "__main__":
    # must precede the first jax import: device count is locked at init
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    main()
