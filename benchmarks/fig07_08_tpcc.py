"""Paper Figs. 7-8: TPC-C throughput scaling with node count, at 20% and 50%
distributed transactions, for all six schedulers."""
import numpy as np

from repro.core.workloads import tpcc_waves

from .simcost import DEFAULT_WAVES, KEYS_PER_NODE, print_table, simulate, wave_size

SCHEDS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")


def run(fast: bool = True, dist_frac: float = 0.2):
    nodes = (4, 8, 16, 29) if fast else (2, 4, 8, 16, 24, 29)
    rows = []
    for n in nodes:
        rng = np.random.RandomState(42)
        waves = tpcc_waves(rng, DEFAULT_WAVES, wave_size(n), n, KEYS_PER_NODE,
                           dist_frac=dist_frac)
        for sched in SCHEDS:
            hs = None
            if sched == "clocksi":
                hs = np.round(np.linspace(0, 2, n)).astype(np.int32)  # Clock20
            r = simulate(waves, sched, n, host_skew=hs)
            r["dist_pct"] = int(dist_frac * 100)
            rows.append(r)
    return rows


def main():
    for dist in (0.2, 0.5):
        rows = run(dist_frac=dist)
        print_table(rows, ["sched", "n_nodes", "throughput_tps", "abort_pct",
                           "msgs_per_txn"],
                    f"Fig {'7' if dist == 0.2 else '8'}: TPC-C scaling "
                    f"({int(dist*100)}% distributed)")


if __name__ == "__main__":
    main()
