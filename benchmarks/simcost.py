"""Shared cost model + sweep driver for the paper-figure benchmarks.

The wave engine (repro.core) executes transactions and counts the paper's
cost drivers: cross-node messages, coordinator messages, clock-skew waits,
commits/aborts.  This module turns counts into a simulated MPP wall-time via
an explicit cost model (constants below — an InfiniBand-class cluster like
the paper's §V-A testbed):

  t_op     per-op execution on a worker           (parallel across nodes)
  t_msg    per cross-node message                 (parallel across nodes)
  t_coord  per coordinator message                (SERIALIZED at the master —
           this is the bottleneck the paper eliminates)
  t_wait   per Clock-SI skew wait unit (1 unit ~ 10 ms of skew)

wave_time = max(exec + cross + waits, coord_serial);   tput = commits / time.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import make_store, run_workload, run_workload_fused

T_OP = 20.0          # us
T_MSG = 100.0        # us
T_COORD = 25.0       # us (master service time per message)
T_WAIT = 1_000.0     # us per skew unit

WORKERS_PER_NODE = 8  # paper §V-A: 8 worker threads per slave
DEFAULT_WAVES = 3
KEYS_PER_NODE = 400


def wave_size(n_nodes: int) -> int:
    """Offered load scales with the cluster (8 workers/node, as in the
    paper's testbed) so per-node contention stays constant."""
    return WORKERS_PER_NODE * n_nodes


def simulate(waves, sched: str, n_nodes: int, host_skew=None,
             n_versions: int = 8, fused: bool = True) -> Dict:
    """``fused=True`` (default) measures the single-dispatch scan executor —
    the device-resident hot path; ``fused=False`` falls back to the per-wave
    debug driver (bit-identical history, one host sync per wave)."""
    n_keys = n_nodes * KEYS_PER_NODE
    driver = run_workload_fused if fused else run_workload
    t0 = time.perf_counter()
    _, hist, stats = driver(make_store(n_keys, n_versions), waves,
                            sched=sched, n_nodes=n_nodes,
                            host_skew=host_skew)
    wall = time.perf_counter() - t0
    n_txn = sum(len(t) for t, _ in hist)
    n_ops = sum(int((o.read_key >= 0).sum() + (o.write_key >= 0).sum())
                for _, o in hist)
    exec_us = n_txn * waves[0].op_kind.shape[1] * T_OP / n_nodes
    cross_us = stats.msgs_cross * T_MSG / n_nodes
    coord_us = stats.msgs_coord * T_COORD
    wait_us = stats.waits * T_WAIT / n_nodes
    total_us = max(exec_us + cross_us + wait_us, coord_us)
    tput = stats.committed / (total_us / 1e6) if total_us else 0.0
    return {
        "sched": sched, "n_nodes": n_nodes,
        "committed": stats.committed, "aborted": stats.aborted,
        "abort_pct": 100.0 * stats.aborted / max(stats.committed + stats.aborted, 1),
        "msgs_cross": stats.msgs_cross, "msgs_coord": stats.msgs_coord,
        "waits": stats.waits,
        "sim_time_us": total_us, "throughput_tps": tput,
        "engine_wall_s": wall,
        "msgs_per_txn": (stats.msgs_cross + stats.msgs_coord) / max(n_txn, 1),
    }


def print_table(rows: List[Dict], cols: List[str], title: str) -> None:
    print(f"\n== {title} ==")
    print(" | ".join(f"{c:>14s}" for c in cols))
    for r in rows:
        print(" | ".join(
            f"{r[c]:>14.1f}" if isinstance(r[c], float) else f"{str(r[c]):>14s}"
            for c in cols))
