"""Paper Fig. 13: (a) throughput vs transaction length (extra read ops);
(b) throughput vs fraction of distributed transactions.  20 nodes."""
import numpy as np

from repro.core.workloads import micro_waves

from .simcost import DEFAULT_WAVES, KEYS_PER_NODE, print_table, simulate, wave_size

SCHEDS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")


def run_length(fast: bool = True):
    n = 20
    rows = []
    for n_ops in (2, 4, 8, 16):
        rng = np.random.RandomState(5)
        waves = micro_waves(rng, DEFAULT_WAVES, wave_size(n), n, KEYS_PER_NODE,
                            n_ops=n_ops, read_ratio=0.8, dist_frac=0.3)
        for sched in SCHEDS:
            hs = np.round(np.linspace(0, 2, n)).astype(np.int32) \
                if sched == "clocksi" else None
            r = simulate(waves, sched, n, host_skew=hs)
            r["n_ops"] = n_ops
            rows.append(r)
    return rows


def run_dist(fast: bool = True):
    n = 20
    rows = []
    for dist in (0.05, 0.2, 0.4, 0.6, 0.8):
        rng = np.random.RandomState(6)
        waves = micro_waves(rng, DEFAULT_WAVES, wave_size(n), n, KEYS_PER_NODE,
                            n_ops=4, read_ratio=0.8, dist_frac=dist)
        for sched in SCHEDS:
            hs = np.round(np.linspace(0, 2, n)).astype(np.int32) \
                if sched == "clocksi" else None
            r = simulate(waves, sched, n, host_skew=hs)
            r["dist_pct"] = int(dist * 100)
            rows.append(r)
    return rows


def main():
    print_table(run_length(), ["sched", "n_ops", "throughput_tps", "abort_pct"],
                "Fig 13a: varying transaction length (20 nodes, 30% dist)")
    print_table(run_dist(), ["sched", "dist_pct", "throughput_tps", "abort_pct"],
                "Fig 13b: varying distributed fraction (20 nodes)")


if __name__ == "__main__":
    main()
