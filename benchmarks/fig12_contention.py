"""Paper Fig. 12: throughput/abort rate vs degree of contention
(SmallBank, 20 nodes, 30% distributed; contention = fraction of transactions
hitting the per-node hotspot of 20 keys)."""
import numpy as np

from repro.core.workloads import smallbank_waves

from .simcost import DEFAULT_WAVES, KEYS_PER_NODE, print_table, simulate, wave_size

SCHEDS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")


def run(fast: bool = True):
    n = 20
    rows = []
    for hot in (0.0, 0.2, 0.4, 0.6, 0.8):
        rng = np.random.RandomState(11)
        waves = smallbank_waves(rng, DEFAULT_WAVES, wave_size(n), n,
                                KEYS_PER_NODE, dist_frac=0.3, hot_frac=hot,
                                hot_per_node=20)
        for sched in SCHEDS:
            hs = np.round(np.linspace(0, 2, n)).astype(np.int32) \
                if sched == "clocksi" else None
            r = simulate(waves, sched, n, host_skew=hs)
            r["hot_pct"] = int(hot * 100)
            rows.append(r)
    return rows


def main():
    rows = run()
    print_table(rows, ["sched", "hot_pct", "throughput_tps", "abort_pct"],
                "Fig 12: varying contention (SmallBank, 20 nodes, 30% dist)")


if __name__ == "__main__":
    main()
