"""Paper Fig. 11: communication cost (messages/txn, split cross vs
coordinator) and abort rate (TPC-C, 8 nodes, 20% distributed)."""
import numpy as np

from repro.core.workloads import tpcc_waves

from .simcost import DEFAULT_WAVES, KEYS_PER_NODE, print_table, simulate, wave_size

SCHEDS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")


def run(fast: bool = True):
    n = 8
    rng = np.random.RandomState(3)
    waves = tpcc_waves(rng, DEFAULT_WAVES, wave_size(n), n, KEYS_PER_NODE,
                       dist_frac=0.2)
    rows = []
    for sched in SCHEDS:
        hs = np.round(np.linspace(0, 2, n)).astype(np.int32) \
            if sched == "clocksi" else None
        r = simulate(waves, sched, n, host_skew=hs)
        n_txn = wave_size(n) * DEFAULT_WAVES
        r["cross_per_txn"] = r["msgs_cross"] / n_txn
        r["coord_per_txn"] = r["msgs_coord"] / n_txn
        rows.append(r)
    return rows


def main():
    rows = run()
    print_table(rows, ["sched", "cross_per_txn", "coord_per_txn", "abort_pct"],
                "Fig 11: communication cost + abort rate "
                "(TPC-C, 8 nodes, 20% dist)")


if __name__ == "__main__":
    main()
