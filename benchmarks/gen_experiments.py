"""Regenerate the data tables of EXPERIMENTS.md from the dry-run JSONs.

Usage: PYTHONPATH=src python -m benchmarks.gen_experiments \
          [--baseline experiments/dryrun] [--final experiments/dryrun_final]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_dir(d):
    rows = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def fmt_row(r):
    if "skipped" in r:
        return None
    if "error" in r:
        return f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | |"
    rl = r["roofline"]
    uf = rl["useful_flops_frac"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rl['compute_s']:.4f} "
            f"| {rl['memory_s']:.4f} | {rl['collective_s']:.4f} | {rl['dominant']} "
            f"| {uf:.2f} |" if uf is not None else "")


def roofline_table(rows, mesh):
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | dominant | MODEL/HLO flops |",
           "|---|---|---|---|---|---|---|---|"]
    skips = []
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if "skipped" in r:
            skips.append((a, s))
            continue
        line = fmt_row(r)
        if line:
            out.append(line)
    if skips:
        out.append("")
        out.append(f"Skipped (long_500k, full-attention archs per assignment): "
                   + ", ".join(a for a, _ in skips))
    return "\n".join(out)


def dryrun_summary(rows):
    live = [r for r in rows.values() if "roofline" in r]
    err = [r for r in rows.values() if "error" in r]
    skip = [r for r in rows.values() if "skipped" in r]
    mem = [r for r in live if "memory" in r and r["memory"].get("temp_size_in_bytes")]
    out = [f"- cells compiled OK: **{len(live)}** (errors: {len(err)}, "
           f"assignment skips: {len(skip)})"]
    doms = {}
    for r in live:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    out.append(f"- dominant-term distribution: {doms}")
    if mem:
        worst = max(mem, key=lambda r: r["memory"]["temp_size_in_bytes"])
        out.append(f"- largest temp footprint: {worst['arch']}/{worst['shape']}"
                   f"/{worst['mesh']}: "
                   f"{worst['memory']['temp_size_in_bytes']/2**30:.1f} GiB/device")
    return "\n".join(out)


def compare_table(base, final, cells):
    out = ["| cell | term | paper-faithful baseline | optimized | gain |",
           "|---|---|---|---|---|"]
    for (a, s, m) in cells:
        b = base.get((a, s, m))
        f = final.get((a, s, m))
        if not b or not f or "roofline" not in b or "roofline" not in f:
            continue
        for t in ("compute_s", "memory_s", "collective_s"):
            bv, fv = b["roofline"][t], f["roofline"][t]
            gain = bv / fv if fv else float("inf")
            out.append(f"| {a}/{s} | {t[:-2]} | {bv:.4f}s | {fv:.4f}s | {gain:.2f}x |")
        bb = max(b["roofline"][t] for t in ("compute_s", "memory_s", "collective_s"))
        fb = max(f["roofline"][t] for t in ("compute_s", "memory_s", "collective_s"))
        out.append(f"| {a}/{s} | **bound** | **{bb:.4f}s** | **{fb:.4f}s** | "
                   f"**{bb/fb:.2f}x** |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--final", default="experiments/dryrun_final")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    base = load_dir(args.baseline)
    final = load_dir(args.final) if os.path.isdir(args.final) else {}

    print("### Dry-run summary (paper-faithful baseline)\n")
    print(dryrun_summary(base))
    if final:
        print("\n### Dry-run summary (optimized)\n")
        print(dryrun_summary(final))
    print("\n### Roofline — baseline, single-pod 16x16 (256 chips)\n")
    print(roofline_table(base, "16x16"))
    print("\n### Roofline — baseline, multi-pod 2x16x16 (512 chips)\n")
    print(roofline_table(base, "2x16x16"))
    if final:
        print("\n### Roofline — optimized, single-pod 16x16\n")
        print(roofline_table(final, "16x16"))
        print("\n### Baseline vs optimized — full-sweep deltas (16x16)\n")
        cells = [(a, s, "16x16") for (a, s, m) in final if m == "16x16"]
        print(compare_table(base, final, sorted(set(cells))))


if __name__ == "__main__":
    main()
