"""Closed-loop service benchmark: offered load vs goodput/latency/retries.

Runs the ``repro.service.TxnService`` end-to-end on CPU for every scheduler:
a Poisson SmallBank request stream at several offered-load factors (fraction
of wave capacity ``T`` arriving per tick), with contention high enough that
aborts and retries actually happen.  Records, per (scheduler, load):

  * sustained txns/sec (all executions, wall) and goodput (committed/sec)
  * retry rate (retries / admitted) and drop/reject counts
  * end-to-end latency percentiles p50/p95/p99 (ticks, admission -> commit)
  * the GC watermark's ``evicted_visible`` counter (0 == V is large enough)

plus a GC ring-depth section (a blind-write-heavy replay swept over V shows
the still-visible-eviction counter rising as the ring shrinks, and
``gc_block=True`` trading those corruptions for aborts) and the **streaming
sweep**: the pipelined plane (``run_streaming``) against the per-wave step
loop at equal offered load on the zipfian YCSB stream, over pipeline depth
K × block size B × skew θ, with goodput speedups reported honestly (both
sides pay host-side wave forming; what the pipeline removes is the
per-wave dispatch + host sync, so the speedup is the dispatch-overhead
share — largest for small waves on CPU, not a device-compute win).

plus the **durability sweep**: the same zipfian streaming session served
with the WAL off, durable-before-ack (``fsync_every=1``), group commit
(``fsync_every=8``), and two snapshot cadences — the §9 durability tax at
the block-retire point, reported relative to the wal-off row of the same
served stream (an honest host-side overhead share: fsync + pickling on
this host's filesystem, CPU backend — not a paper absolute).

plus the **tenancy section** (DESIGN.md §12): a θ=0.99 write-hot tenant
flooding next to a read-heavy light tenant, served solo / shared-FIFO /
weighted-DRR (light-tenant p99, demand-aware Jain index) and the same-key
RMW folding on/off pair on a single-op write-hot stream — commit-set
equality between the folded and unfolded runs is checked, and the goodput
ratio reported honestly as a CPU/jnp scheduling win.

Writes ``BENCH_service.json`` at the repo root.

Run:  PYTHONPATH=src python -m benchmarks.bench_service [--smoke]
      PYTHONPATH=src python -m benchmarks.bench_service --streaming-only
      PYTHONPATH=src python -m benchmarks.bench_service --durability-only
      PYTHONPATH=src python -m benchmarks.bench_service --tenancy-only
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import SCHEDULERS, make_store, run_workload_fused
from repro.core.workloads import micro_waves, poisson_arrivals
from repro.service import (AdaptiveWaveSizer, RetryPolicy, TxnService,
                           rmw_txn_gen, smallbank_txn_gen, ycsb_txn_gen)

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_service.json")

N_TICKS = 24
WAVE_T = 64
N_NODES = 8
KEYS_PER_NODE = 100
LOAD_FACTORS = (0.5, 0.9, 1.3)      # offered arrivals per tick / T
HOT_FRAC = 0.5
HOT_PER_NODE = 4

SMOKE = dict(n_ticks=6, T=16, n_nodes=4, keys_per_node=40,
             load_factors=(0.9,), scheds=("postsi", "si"))

# streaming sweep: pipeline shapes (B waves/block, K blocks in flight) ×
# zipf skew; each theta is measured against the step loop on the SAME
# arrival stream (the acceptance bar is >= 1.3x goodput at equal load).
# Offered load is ABOVE the step loop's hard service ceiling of one wave
# per tick (STREAM_LOAD * T arrivals/tick): the step loop sheds the excess
# at admission while the pipeline serves up to B waves per tick — which is
# precisely the claim under test, that per-wave dispatch, not the CC
# rules, bounds the step loop's goodput.
STREAM_SHAPES = ((1, 1), (2, 2), (4, 2), (8, 3))
STREAM_THETAS = (0.0, 0.9, 1.2)
STREAM_LOAD = 2.0
STREAM_SMOKE = dict(shapes=((2, 2),), thetas=(0.9,), n_ticks=10)

# durability sweep (DESIGN.md §9): WAL off vs durable-before-ack vs group
# commit vs snapshot cadences, all serving the identical zipfian stream
DUR_VARIANTS = (("wal-off", None, None),
                ("wal-fsync1", 1, None),
                ("wal-fsync8", 8, None),
                ("wal-fsync1-snap2", 1, 2),
                ("wal-fsync1-snap8", 1, 8))
ART_DIR = os.path.join(os.path.dirname(OUT_PATH),
                       "artifacts", "durability_smoke")

# tenancy section (DESIGN.md §12): a θ=0.99 write-hot tenant flooding at
# TEN_HOT_LOAD×T/tick next to a read-heavy light tenant at TEN_LIGHT_LOAD×T,
# served solo / shared-FIFO / weighted-DRR — plus the same-key RMW folding
# on/off pair on a single-op write-hot stream (commit-set equality is a gate,
# not an assumption)
TEN_CFG = dict(n_ticks=20, T=32, n_nodes=4, keys_per_node=50)
TEN_SMOKE = dict(n_ticks=10, T=16, n_nodes=4, keys_per_node=40)
TEN_HOT_LOAD = 2.5
TEN_LIGHT_LOAD = 0.25
TEN_THETA = 0.99
TEN_ART_DIR = os.path.join(os.path.dirname(OUT_PATH),
                           "artifacts", "tenancy_smoke")


def _host_skew(sched: str, n_nodes: int):
    return (np.round(np.linspace(0, 2, n_nodes)).astype(np.int32)
            if sched == "clocksi" else None)


def _run_one(sched: str, load: float, n_ticks: int, T: int, n_nodes: int,
             keys_per_node: int, seed: int = 0) -> Dict:
    """One closed-loop session.  ``verify_errors`` counts post-hoc SI
    violations — 0 for every scheduler except clocksi, whose skewed hosts
    read stale snapshots by design (the paper §II anomaly the waits model)."""
    hs = _host_skew(sched, n_nodes)
    svc = TxnService(n_keys=n_nodes * keys_per_node, n_versions=8, T=T,
                     sched=sched, n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=8), host_skew=hs,
                     seed=seed)
    arr_rng = np.random.RandomState(100 + seed)
    gen = smallbank_txn_gen(np.random.RandomState(200 + seed), n_nodes,
                            keys_per_node, dist_frac=0.2, hot_frac=HOT_FRAC,
                            hot_per_node=HOT_PER_NODE)
    report = svc.run_stream(poisson_arrivals(arr_rng, load * T, n_ticks), gen)
    row = report.as_dict()
    row["load_factor"] = load
    row["verify_errors"] = len(svc.verify())
    return row


def _gc_ring_sweep(n_ticks: int, T: int, n_nodes: int,
                   keys_per_node: int) -> Dict:
    """Blind-write contention replay over ring depths: the counter reports
    when V is too small, and gc_block converts corruption into aborts."""
    rng = np.random.RandomState(5)
    waves = micro_waves(rng, n_ticks, T, n_nodes, keys_per_node, n_ops=4,
                        read_ratio=0.2, hot_frac=0.8, hot_per_node=2,
                        blind_frac=0.9)
    n_keys = n_nodes * keys_per_node
    sweep = []
    for V in (2, 3, 4, 8, 16):
        _, _, st = run_workload_fused(make_store(n_keys, V), waves,
                                      sched="postsi", n_nodes=n_nodes,
                                      gc_track=True)
        sweep.append({"n_versions": V, "committed": st.committed,
                      "aborted": st.aborted,
                      "evicted_visible": st.evicted_visible})
    _, _, st = run_workload_fused(make_store(n_keys, 2), waves,
                                  sched="postsi", n_nodes=n_nodes,
                                  gc_block=True)
    blocked = {"n_versions": 2, "committed": st.committed,
               "aborted": st.aborted, "evicted_visible": st.evicted_visible}
    return {"ring_sweep": sweep, "gc_block": blocked}


def _stream_one(theta: float, shape: Optional[Tuple[int, int]], n_ticks: int,
                T: int, n_nodes: int, keys_per_node: int, sched: str,
                sizer=None, seed: int = 0, read_frac: float = 0.5) -> Dict:
    """One served session on the zipfian YCSB stream: ``shape=None`` is the
    per-wave step loop baseline, ``shape=(B, K)`` the streaming plane.
    Arrival and request RNGs depend only on (theta, seed): every shape at a
    given skew serves the identical offered stream."""
    svc = TxnService(n_keys=n_nodes * keys_per_node, n_versions=8, T=T,
                     sched=sched, n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=8), seed=seed)
    arr = poisson_arrivals(np.random.RandomState(300 + seed),
                           STREAM_LOAD * T, n_ticks)
    gen = ycsb_txn_gen(np.random.RandomState(400 + seed), n_nodes,
                       keys_per_node, theta=theta, read_frac=read_frac,
                       dist_frac=0.2)
    if shape is None:
        report = svc.run_stream(arr, gen)
    else:
        report = svc.run_streaming(arr, gen, B=shape[0], K=shape[1],
                                   sizer=sizer)
    row = report.as_dict()
    row["theta"] = theta
    row["mode"] = "step" if shape is None else f"B{shape[0]}K{shape[1]}"
    row["verify_errors"] = len(svc.verify())
    return row


def _warm_block_shapes(n_keys: int, sized_shapes, sched: str = "postsi"):
    """Compile every [b, T', O] block program the sweep sessions can
    dispatch — ``sized_shapes`` maps wave size T' to its largest block
    size, and each gets its power-of-two chunk ladder — so the timed runs
    never absorb jit compilation (and nothing compiles shapes no session
    dispatches)."""
    import jax.numpy as jnp
    from repro.core import Wave, make_store, run_block
    store = make_store(n_keys, 8)
    for T_, b_max in sorted(sized_shapes.items()):
        b = 1
        while b <= b_max:
            wv = Wave(op_kind=jnp.zeros((b, T_, 4), jnp.int32),
                      op_key=jnp.zeros((b, T_, 4), jnp.int32),
                      op_val=jnp.zeros((b, T_, 4), jnp.int32),
                      host=jnp.zeros((b, T_), jnp.int32),
                      tid=jnp.broadcast_to(
                          1 + jnp.arange(T_, dtype=jnp.int32), (b, T_)))
            run_block(store, wv, 1, jnp.int32(1), sched=sched, n_nodes=8)
            b *= 2


def _stream_sweep(n_ticks: int, T: int, n_nodes: int, keys_per_node: int,
                  shapes=STREAM_SHAPES, thetas=STREAM_THETAS,
                  sched: str = "postsi", adaptive: bool = True) -> Dict:
    """Streaming-vs-step at equal offered load, over B × K × θ, plus (with
    ``adaptive=True``) one contention-adaptive session at the heaviest
    skew — skipping it also skips the warm compile of its T ladder."""
    ladder = [max(T * i // 4, 4) for i in (1, 2, 3, 4)]  # adaptive T rungs
    # grid sessions dispatch only wave size T (up to the largest B); the
    # adaptive session dispatches the ladder rungs at B=4 chunks
    sized = {T: max(B for B, _ in shapes)}
    if adaptive:
        sized[T] = max(sized[T], 4)
        for rung in ladder:
            sized[rung] = max(sized.get(rung, 1), 4)
    _warm_block_shapes(n_nodes * keys_per_node, sized, sched)
    _stream_one(0.9, None, 2, T, n_nodes, keys_per_node, sched)  # step warm
    rows = []
    for theta in thetas:
        base = _stream_one(theta, None, n_ticks, T, n_nodes, keys_per_node,
                           sched)
        base["speedup_vs_step"] = 1.0
        rows.append(base)
        for shape in shapes:
            r = _stream_one(theta, shape, n_ticks, T, n_nodes,
                            keys_per_node, sched)
            r["speedup_vs_step"] = round(
                r["goodput_tps"] / max(base["goodput_tps"], 1e-9), 3)
            rows.append(r)
    if not adaptive:
        return {"sched": sched, "load": STREAM_LOAD, "read_frac": 0.5,
                "sweep": rows, "adaptive": None}
    # §V-D contention regulation: bounded-AIMD wave sizing on the most
    # skewed, write-heavy stream (its own row, not part of the B×K grid).
    # The T ladder is the pre-warmed quarter-rung one; B stays fixed so the
    # compiled-shape set is exactly ladder × pow2-chunks.
    sizer = AdaptiveWaveSizer(T0=T, B0=4, t_min=ladder[0],
                              quantum=ladder[0], window=2 * T)
    a_row = _stream_one(max(thetas), (4, 2), n_ticks, T, n_nodes,
                        keys_per_node, sched, sizer=sizer, seed=1,
                        read_frac=0.1)   # write-heavy on purpose; the B×K
                                         # grid runs at the section's 0.5
    a_row.update(mode="adaptive-B4K2", read_frac=0.1,
                 wave_T_final=sizer.T, wave_B_final=sizer.B,
                 md_events=sizer.decreases, ai_events=sizer.increases)
    return {"sched": sched, "load": STREAM_LOAD, "read_frac": 0.5,
            "sweep": rows, "adaptive": a_row}


def _durability_one(label: str, fsync_every: Optional[int],
                    snapshot_every: Optional[int], directory: Optional[str],
                    theta: float, shape: Tuple[int, int], n_ticks: int,
                    T: int, n_nodes: int, keys_per_node: int,
                    check_recovery: bool = False, seed: int = 0) -> Dict:
    """One streaming session with (or without) the §9 durability plane
    attached at the retire point.  ``check_recovery=True`` additionally
    replays the WAL it just wrote and demands the recovered store match
    the live one bit for bit — the smoke's correctness gate."""
    from repro.durability import DurabilityManager, recover, wal, wal_path
    mgr = (DurabilityManager(directory, fsync_every=fsync_every,
                             snapshot_every=snapshot_every)
           if fsync_every is not None else None)
    svc = TxnService(n_keys=n_nodes * keys_per_node, n_versions=8, T=T,
                     sched="postsi", n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=8), seed=seed,
                     durability=mgr)
    arr = poisson_arrivals(np.random.RandomState(300 + seed),
                           STREAM_LOAD * T, n_ticks)
    gen = ycsb_txn_gen(np.random.RandomState(400 + seed), n_nodes,
                       keys_per_node, theta=theta, read_frac=0.5,
                       dist_frac=0.2)
    report = svc.run_streaming(arr, gen, B=shape[0], K=shape[1])
    row = report.as_dict()
    row.update(mode=f"B{shape[0]}K{shape[1]}", durability=label,
               fsync_every=fsync_every, snapshot_every=snapshot_every,
               verify_errors=len(svc.verify()))
    if mgr is not None:
        mgr.close()
        scan = wal.scan(wal_path(directory))
        row.update(wal_records=len(scan.blocks), wal_bytes=scan.valid_bytes,
                   snapshots=mgr.snapshots_taken)
        if check_recovery:
            st = recover(directory)
            for f in ("val", "tid", "cid", "sid", "head", "wave"):
                if not np.array_equal(np.asarray(getattr(st.store, f)),
                                      np.asarray(getattr(svc.store, f))):
                    raise SystemExit(
                        f"durability smoke ({label}): recovered store "
                        f"field {f!r} diverges from the live service")
            row["recover_matches_live"] = True
    return row


def _durability_sweep(n_ticks: int, T: int, n_nodes: int, keys_per_node: int,
                      shape: Tuple[int, int] = (4, 2), theta: float = 0.9,
                      artifacts_dir: Optional[str] = None,
                      check_recovery: bool = False) -> Dict:
    """WAL/snapshot tax at the block-retire point over DUR_VARIANTS, all
    serving the identical stream.  With ``artifacts_dir`` the WAL +
    snapshot directories are kept (CI uploads them); otherwise tmpdirs."""
    import shutil
    import tempfile
    rows = []
    for label, fsync_every, snapshot_every in DUR_VARIANTS:
        d, cleanup = None, False
        if fsync_every is not None:
            if artifacts_dir is not None:
                d = os.path.join(artifacts_dir, label)
                shutil.rmtree(d, ignore_errors=True)
                os.makedirs(d, exist_ok=True)
            else:
                d, cleanup = tempfile.mkdtemp(), True
        rows.append(_durability_one(label, fsync_every, snapshot_every, d,
                                    theta, shape, n_ticks, T, n_nodes,
                                    keys_per_node,
                                    check_recovery=check_recovery))
        if cleanup:
            shutil.rmtree(d, ignore_errors=True)
    base = rows[0]["goodput_tps"]
    for r in rows:
        r["goodput_vs_wal_off"] = round(r["goodput_tps"] / max(base, 1e-9), 3)
    return {
        "sched": "postsi", "theta": theta, "shape": list(shape),
        "n_ticks": n_ticks, "wave_size": T, "load": STREAM_LOAD,
        "note": ("durability tax at the block-retire point on THIS host's "
                 "filesystem (CPU backend, tmpdir/artifacts dir): fsync + "
                 "pickle cost per retired block, relative to the wal-off "
                 "row of the SAME served stream — a host-side overhead "
                 "share, not a paper absolute"),
        "sweep": rows,
    }


def _waterfill(demands: Dict[int, float], weights: Dict[int, float],
               capacity: float) -> Dict[int, float]:
    """Weighted max-min (water-filling) entitlements: each tenant's fair
    share of ``capacity`` given its demand — an under-demand tenant is
    capped at its demand and the surplus flows to the others."""
    ent = dict.fromkeys(demands, 0.0)
    active = {t for t in demands if demands[t] > 0}
    cap = float(capacity)
    while active and cap > 1e-9:
        w_sum = sum(weights[t] for t in active)
        sat = {t for t in active
               if demands[t] - ent[t] <= cap * weights[t] / w_sum + 1e-9}
        if sat:
            for t in sat:
                cap -= demands[t] - ent[t]
                ent[t] = demands[t]
            active -= sat
        else:
            for t in active:
                ent[t] += cap * weights[t] / w_sum
            cap = 0.0
    return ent


def _jain(xs) -> float:
    xs = [float(x) for x in xs]
    denom = len(xs) * sum(x * x for x in xs)
    return round(sum(xs) ** 2 / denom, 4) if denom > 0 else 1.0


def _fairness_run(mode: str, n_ticks: int, T: int, n_nodes: int,
                  keys_per_node: int, seed: int = 0) -> Dict:
    """One two-tenant session.  ``mode``: ``solo`` — the light tenant's
    stream alone (its p99 baseline); ``fifo`` — both streams through the
    single shared admission queue (everything tenant 0, arrival order);
    ``drr`` — per-tenant queues at equal weight.  Arrival and request RNGs
    depend only on ``seed``, so all three modes serve identical streams.
    Light-tenant latency is attributed through the request handles that
    ``submit`` returns — so the FIFO run needs no per-tenant queues to be
    measured."""
    tenants = {0: 1.0, 1: 1.0} if mode == "drr" else None
    svc = TxnService(n_keys=n_nodes * keys_per_node, n_versions=8, T=T,
                     sched="postsi", n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=12), max_queue=4 * T,
                     tenants=tenants, seed=seed)
    hot_arr = poisson_arrivals(np.random.RandomState(500 + seed),
                               TEN_HOT_LOAD * T, n_ticks)
    light_arr = poisson_arrivals(np.random.RandomState(501 + seed),
                                 TEN_LIGHT_LOAD * T, n_ticks)
    hot_gen = ycsb_txn_gen(np.random.RandomState(502 + seed), n_nodes,
                           keys_per_node, theta=TEN_THETA, read_frac=0.1)
    light_gen = ycsb_txn_gen(np.random.RandomState(503 + seed), n_nodes,
                             keys_per_node, theta=TEN_THETA, read_frac=0.9)
    by_tenant = {0: [], 1: []}
    for t in range(n_ticks):
        if mode != "solo":
            for _ in range(int(hot_arr[t])):
                by_tenant[0].append(svc.submit(*hot_gen(), tenant=0))
        for _ in range(int(light_arr[t])):
            by_tenant[1].append(svc.submit(
                *light_gen(), tenant=1 if mode == "drr" else 0))
        svc.step()
    svc.drain()
    row = svc.report().as_dict()
    row.update(mode=mode, verify_errors=len(svc.verify()))
    for tag, label in ((0, "hot"), (1, "light")):
        reqs = by_tenant[tag]
        lat = [r.latency for r in reqs if r.status == "committed"]
        row[label] = {
            "offered": len(reqs),
            "committed": len(lat),
            "rejected": sum(r.status == "rejected" for r in reqs),
            "dropped": sum(r.status == "dropped" for r in reqs),
            "latency_p50": round(float(np.percentile(lat, 50)), 1)
            if lat else None,
            "latency_p99": round(float(np.percentile(lat, 99)), 1)
            if lat else None,
        }
    # demand-aware Jain: achieved commits vs weighted max-min entitlement
    # of what the run actually delivered (a tenant fully served within its
    # entitlement scores 1; a flood-squeezed one scores < 1)
    demands = {t: row[l]["offered"] for t, l in ((0, "hot"), (1, "light"))}
    achieved = {t: row[l]["committed"] for t, l in ((0, "hot"), (1, "light"))}
    if mode != "solo":
        ent = _waterfill(demands, {0: 1.0, 1: 1.0}, sum(achieved.values()))
        row["jain"] = _jain([achieved[t] / max(ent[t], 1.0) for t in ent])
    return row


def _fold_run(fold: bool, n_ticks: int, T: int, keys_per_node: int,
              seed: int = 0):
    """One single-op RMW θ=0.99 write-hot session with folding on or off —
    single-owner on purpose: the tentpole's batching is OWNER-SIDE, so the
    stress case is one node's hot key range absorbing the whole stream
    (spreading over hosts dilutes per-wave same-key multiplicity and with
    it both the serialization pain and the fold win).  Generous retry
    budget + deep queues so neither run sheds or drops — the commit SETS
    must match, making the goodput ratio a pure scheduling comparison
    (fold-off serializes the hot key through lost-update retries; fold-on
    batches the same deltas into one engine txn)."""
    n_keys = keys_per_node
    svc = TxnService(n_keys=n_keys, n_versions=8, T=T, sched="postsi",
                     n_nodes=1, fold_rmw=fold, max_queue=10_000,
                     retry=RetryPolicy(max_attempts=30, jitter=False),
                     seed=seed)
    arr = poisson_arrivals(np.random.RandomState(600 + seed),
                           TEN_HOT_LOAD * T, n_ticks)
    gen = rmw_txn_gen(np.random.RandomState(601 + seed), 1,
                      keys_per_node, theta=TEN_THETA)
    rep = svc.run_stream(arr, gen)
    row = rep.as_dict()
    row.update(fold=fold, verify_errors=len(svc.verify()))
    committed = sorted(r.req_id for r in svc.requests
                       if r.status == "committed")
    head = np.asarray(svc.store.head)
    val = np.asarray(svc.store.val)
    finals = [int(val[k, head[k]]) for k in range(n_keys)]
    return row, committed, finals


def _tenancy_section(n_ticks: int, T: int, n_nodes: int, keys_per_node: int,
                     artifacts_dir: Optional[str] = None) -> Dict:
    """Fairness (solo / shared-FIFO / weighted-DRR) + RMW-folding on/off,
    with the acceptance gates evaluated and RECORDED (the --tenancy-only CI
    leg additionally fails on them).  Kernel backend is the CPU jnp default
    — the speedup is a scheduling win, not a device-compute claim."""
    # warm the (T, O) jit signature so mode-to-mode wall clocks compare
    TxnService(n_keys=n_nodes * keys_per_node, T=T, sched="postsi",
               n_nodes=n_nodes).run_stream(
        [T], ycsb_txn_gen(np.random.RandomState(0), n_nodes, keys_per_node))
    modes = {m: _fairness_run(m, n_ticks, T, n_nodes, keys_per_node)
             for m in ("solo", "fifo", "drr")}
    solo_p99 = modes["solo"]["light"]["latency_p99"]
    drr_p99 = modes["drr"]["light"]["latency_p99"]
    # warm the single-node signature the fold pair dispatches
    TxnService(n_keys=keys_per_node, T=T, sched="postsi",
               n_nodes=1).run_stream(
        [T], rmw_txn_gen(np.random.RandomState(0), 1, keys_per_node))
    off, set_off, vals_off = _fold_run(False, n_ticks, T, keys_per_node)
    on, set_on, vals_on = _fold_run(True, n_ticks, T, keys_per_node)
    speedup = round(on["goodput_tps"] / max(off["goodput_tps"], 1e-9), 3)
    equal = set_off == set_on and vals_off == vals_on
    gates = {
        "light_p99_le_2x_solo": (drr_p99 is not None and solo_p99 is not None
                                 and drr_p99 <= 2.0 * solo_p99),
        "goodput_within_10pct_of_fifo": (
            modes["drr"]["goodput_tps"]
            >= 0.9 * modes["fifo"]["goodput_tps"]),
        "jain_drr_ge_0.9": modes["drr"]["jain"] >= 0.9,
        "fold_speedup_ge_1.5x": speedup >= 1.5,
        "fold_commit_set_equal": equal,
    }
    section = {
        "config": {"n_ticks": n_ticks, "wave_size": T, "n_nodes": n_nodes,
                   "keys_per_node": keys_per_node, "theta": TEN_THETA,
                   "hot_load": TEN_HOT_LOAD, "light_load": TEN_LIGHT_LOAD,
                   "weights": {"hot": 1.0, "light": 1.0},
                   "fold_n_nodes": 1, "fold_n_keys": keys_per_node},
        "fairness": modes,
        "fold": {"off": off, "on": on, "speedup": speedup,
                 "commit_set_equal": equal,
                 "committed_each": [len(set_off), len(set_on)]},
        "gates": gates,
    }
    if artifacts_dir is not None:
        os.makedirs(artifacts_dir, exist_ok=True)
        with open(os.path.join(artifacts_dir, "tenancy.json"), "w") as f:
            json.dump(section, f, indent=2)
            f.write("\n")
    return section


def _print_tenancy(ten: Dict) -> None:
    for mode, r in ten["fairness"].items():
        light = r["light"]
        print(f"bench_service/tenancy/{mode}: "
              f"goodput {r['goodput_tps']:.0f}/s "
              f"light p99 {light['latency_p99']} ticks "
              f"(committed {light['committed']}/{light['offered']}, "
              f"rejected {light['rejected']}) "
              f"jain {r.get('jain', '-')} "
              f"verify_errors {r['verify_errors']}")
    f = ten["fold"]
    print(f"bench_service/tenancy/fold: {f['speedup']:.2f}x goodput "
          f"(on {f['on']['goodput_tps']:.0f}/s vs "
          f"off {f['off']['goodput_tps']:.0f}/s) "
          f"fold_groups {f['on']['fold_groups']} "
          f"folded {f['on']['folded_requests']} "
          f"commit_set_equal {f['commit_set_equal']}")
    print(f"bench_service/tenancy/gates: {ten['gates']}")


def run(smoke: bool = False) -> Dict:
    if smoke:
        n_ticks, T = SMOKE["n_ticks"], SMOKE["T"]
        n_nodes, kpn = SMOKE["n_nodes"], SMOKE["keys_per_node"]
        loads, scheds = SMOKE["load_factors"], SMOKE["scheds"]
    else:
        n_ticks, T, n_nodes, kpn = N_TICKS, WAVE_T, N_NODES, KEYS_PER_NODE
        loads, scheds = LOAD_FACTORS, SCHEDULERS
    sweep = {}
    for sched in scheds:
        # warmup: populate the jit cache for this (sched, T, O) signature so
        # the first timed load does not absorb compilation
        TxnService(n_keys=n_nodes * kpn, T=T, sched=sched, n_nodes=n_nodes,
                   host_skew=_host_skew(sched, n_nodes)).run_stream(
            [T], smallbank_txn_gen(np.random.RandomState(0), n_nodes, kpn))
        sweep[sched] = [_run_one(sched, load, n_ticks, T, n_nodes, kpn)
                        for load in loads]
    s_kw = STREAM_SMOKE if smoke else dict(shapes=STREAM_SHAPES,
                                           thetas=STREAM_THETAS,
                                           n_ticks=n_ticks)
    return {
        "config": {
            "workload": "smallbank-poisson", "n_ticks": n_ticks,
            "wave_size": T, "n_nodes": n_nodes, "keys_per_node": kpn,
            "hot_frac": HOT_FRAC, "hot_per_node": HOT_PER_NODE,
            "load_factors": list(loads), "smoke": smoke,
        },
        "sweep": sweep,
        "gc": _gc_ring_sweep(max(n_ticks // 4, 4), T, n_nodes, kpn),
        "streaming": _stream_sweep(s_kw["n_ticks"], T, n_nodes, kpn,
                                   shapes=s_kw["shapes"],
                                   thetas=s_kw["thetas"]),
        # after the streaming sweep on purpose: its warm compile covers the
        # durability shape, so these rows time the WAL, not the jit cache
        "durability": _durability_sweep(s_kw["n_ticks"], T, n_nodes, kpn,
                                        shape=(2, 2) if smoke else (4, 2)),
        "tenancy": _tenancy_section(**(TEN_SMOKE if smoke else TEN_CFG)),
    }


def write_report(report: Dict) -> None:
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def _print_streaming(streaming: Dict) -> None:
    for r in streaming["sweep"]:
        print(f"bench_service/streaming/{r['mode']}/theta{r['theta']}: "
              f"goodput {r['goodput_tps']:.0f}/s "
              f"({r['speedup_vs_step']:.2f}x vs step) "
              f"retry {r['retry_rate']:.2f} waves {r['waves']} "
              f"blocks {r['blocks']} p99 {r['latency_p99']:.0f} ticks "
              f"verify_errors {r['verify_errors']}")
    a = streaming["adaptive"]
    if a is not None:
        print(f"bench_service/streaming/{a['mode']}/theta{a['theta']}: "
              f"goodput {a['goodput_tps']:.0f}/s retry {a['retry_rate']:.2f} "
              f"T {a['wave_T_final']} B {a['wave_B_final']} "
              f"md/ai {a['md_events']}/{a['ai_events']} "
              f"verify_errors {a['verify_errors']}")


def _print_durability(dur: Dict) -> None:
    for r in dur["sweep"]:
        extra = ("" if r["durability"] == "wal-off" else
                 f" wal_records {r['wal_records']} "
                 f"wal_kb {r['wal_bytes'] // 1024} snaps {r['snapshots']}")
        print(f"bench_service/durability/{r['durability']}: "
              f"goodput {r['goodput_tps']:.0f}/s "
              f"({r['goodput_vs_wal_off']:.2f}x vs wal-off){extra} "
              f"verify_errors {r['verify_errors']}")


def main(write_json: bool = True, smoke: bool = False,
         streaming_only: bool = False, durability_only: bool = False,
         tenancy_only: bool = False) -> Dict:
    if tenancy_only:
        # CI tenancy smoke: the section at smoke size with its JSON kept
        # under artifacts/ (CI uploads it) and every acceptance gate
        # enforced, not just recorded
        ten = _tenancy_section(**TEN_SMOKE, artifacts_dir=TEN_ART_DIR)
        _print_tenancy(ten)
        bad_verify = [m for m, r in ten["fairness"].items()
                      if r["verify_errors"]]
        bad_verify += [f"fold-{k}" for k in ("off", "on")
                       if ten["fold"][k]["verify_errors"]]
        if bad_verify:
            raise SystemExit(f"tenancy smoke: verify errors in {bad_verify}")
        failed = [g for g, ok in ten["gates"].items() if not ok]
        if failed:
            raise SystemExit(f"tenancy smoke: gates failed: {failed}")
        return {"tenancy": ten}
    if durability_only:
        # CI durability smoke: the sweep at smoke size with WAL + snapshot
        # directories kept under artifacts/ (CI uploads them) and every
        # WAL-backed row's recovery cross-checked against the live store
        _warm_block_shapes(SMOKE["n_nodes"] * SMOKE["keys_per_node"],
                           {SMOKE["T"]: 2})
        dur = _durability_sweep(STREAM_SMOKE["n_ticks"], SMOKE["T"],
                                SMOKE["n_nodes"], SMOKE["keys_per_node"],
                                shape=(2, 2), artifacts_dir=ART_DIR,
                                check_recovery=True)
        _print_durability(dur)
        bad = [r for r in dur["sweep"] if r["verify_errors"]]
        if bad:
            raise SystemExit(f"durability smoke: verify errors in {bad}")
        return {"durability": dur}
    if streaming_only:
        # CI streaming smoke (both kernel backends): the pipelined plane at
        # B=2, theta=0.9 against its step baseline — no adaptive session,
        # no T-ladder warm compile, no JSON write (the full run owns those)
        s_kw = STREAM_SMOKE
        streaming = _stream_sweep(
            s_kw["n_ticks"], SMOKE["T"], SMOKE["n_nodes"],
            SMOKE["keys_per_node"], shapes=s_kw["shapes"],
            thetas=s_kw["thetas"], adaptive=False)
        _print_streaming(streaming)
        bad = [r for r in streaming["sweep"] if r["verify_errors"]]
        if bad:
            raise SystemExit(f"streaming smoke: verify errors in {bad}")
        return {"streaming": streaming}
    report = run(smoke=smoke)
    if write_json:
        write_report(report)
    for sched, rows in report["sweep"].items():
        for r in rows:
            print(f"bench_service/{sched}/load{r['load_factor']}: "
                  f"goodput {r['goodput_tps']:.0f}/s "
                  f"sustained {r['txns_per_sec']:.0f}/s "
                  f"retry {r['retry_rate']:.2f} "
                  f"p50/p95/p99 {r['latency_p50']:.0f}/"
                  f"{r['latency_p95']:.0f}/{r['latency_p99']:.0f} ticks "
                  f"dropped {r['dropped']} rejected {r['rejected']} "
                  f"evicted {r['evicted_visible']} "
                  f"verify_errors {r['verify_errors']}")
    for row in report["gc"]["ring_sweep"]:
        print(f"bench_service/gc/V{row['n_versions']}: "
              f"evicted_visible={row['evicted_visible']} "
              f"committed={row['committed']}")
    b = report["gc"]["gc_block"]
    print(f"bench_service/gc/V{b['n_versions']}+block: "
          f"evicted_visible={b['evicted_visible']} aborted={b['aborted']}")
    _print_streaming(report["streaming"])
    _print_durability(report["durability"])
    _print_tenancy(report["tenancy"])
    return report


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:],
         streaming_only="--streaming-only" in sys.argv[1:],
         durability_only="--durability-only" in sys.argv[1:],
         tenancy_only="--tenancy-only" in sys.argv[1:])
