"""Closed-loop service benchmark: offered load vs goodput/latency/retries.

Runs the ``repro.service.TxnService`` end-to-end on CPU for every scheduler:
a Poisson SmallBank request stream at several offered-load factors (fraction
of wave capacity ``T`` arriving per tick), with contention high enough that
aborts and retries actually happen.  Records, per (scheduler, load):

  * sustained txns/sec (all executions, wall) and goodput (committed/sec)
  * retry rate (retries / admitted) and drop/reject counts
  * end-to-end latency percentiles p50/p95/p99 (ticks, admission -> commit)
  * the GC watermark's ``evicted_visible`` counter (0 == V is large enough)

plus a GC ring-depth section: a blind-write-heavy replay swept over V shows
the still-visible-eviction counter rising as the ring shrinks, and
``gc_block=True`` trading those corruptions for aborts (counter pinned to 0).

Writes ``BENCH_service.json`` at the repo root.

Run:  PYTHONPATH=src python -m benchmarks.bench_service [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict

import numpy as np

from repro.core import SCHEDULERS, make_store, run_workload_fused
from repro.core.workloads import micro_waves, poisson_arrivals
from repro.service import RetryPolicy, TxnService, smallbank_txn_gen

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_service.json")

N_TICKS = 24
WAVE_T = 64
N_NODES = 8
KEYS_PER_NODE = 100
LOAD_FACTORS = (0.5, 0.9, 1.3)      # offered arrivals per tick / T
HOT_FRAC = 0.5
HOT_PER_NODE = 4

SMOKE = dict(n_ticks=6, T=16, n_nodes=4, keys_per_node=40,
             load_factors=(0.9,), scheds=("postsi", "si"))


def _host_skew(sched: str, n_nodes: int):
    return (np.round(np.linspace(0, 2, n_nodes)).astype(np.int32)
            if sched == "clocksi" else None)


def _run_one(sched: str, load: float, n_ticks: int, T: int, n_nodes: int,
             keys_per_node: int, seed: int = 0) -> Dict:
    """One closed-loop session.  ``verify_errors`` counts post-hoc SI
    violations — 0 for every scheduler except clocksi, whose skewed hosts
    read stale snapshots by design (the paper §II anomaly the waits model)."""
    hs = _host_skew(sched, n_nodes)
    svc = TxnService(n_keys=n_nodes * keys_per_node, n_versions=8, T=T,
                     sched=sched, n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=8), host_skew=hs,
                     seed=seed)
    arr_rng = np.random.RandomState(100 + seed)
    gen = smallbank_txn_gen(np.random.RandomState(200 + seed), n_nodes,
                            keys_per_node, dist_frac=0.2, hot_frac=HOT_FRAC,
                            hot_per_node=HOT_PER_NODE)
    report = svc.run_stream(poisson_arrivals(arr_rng, load * T, n_ticks), gen)
    row = report.as_dict()
    row["load_factor"] = load
    row["verify_errors"] = len(svc.verify())
    return row


def _gc_ring_sweep(n_ticks: int, T: int, n_nodes: int,
                   keys_per_node: int) -> Dict:
    """Blind-write contention replay over ring depths: the counter reports
    when V is too small, and gc_block converts corruption into aborts."""
    rng = np.random.RandomState(5)
    waves = micro_waves(rng, n_ticks, T, n_nodes, keys_per_node, n_ops=4,
                        read_ratio=0.2, hot_frac=0.8, hot_per_node=2,
                        blind_frac=0.9)
    n_keys = n_nodes * keys_per_node
    sweep = []
    for V in (2, 3, 4, 8, 16):
        _, _, st = run_workload_fused(make_store(n_keys, V), waves,
                                      sched="postsi", n_nodes=n_nodes,
                                      gc_track=True)
        sweep.append({"n_versions": V, "committed": st.committed,
                      "aborted": st.aborted,
                      "evicted_visible": st.evicted_visible})
    _, _, st = run_workload_fused(make_store(n_keys, 2), waves,
                                  sched="postsi", n_nodes=n_nodes,
                                  gc_block=True)
    blocked = {"n_versions": 2, "committed": st.committed,
               "aborted": st.aborted, "evicted_visible": st.evicted_visible}
    return {"ring_sweep": sweep, "gc_block": blocked}


def run(smoke: bool = False) -> Dict:
    if smoke:
        n_ticks, T = SMOKE["n_ticks"], SMOKE["T"]
        n_nodes, kpn = SMOKE["n_nodes"], SMOKE["keys_per_node"]
        loads, scheds = SMOKE["load_factors"], SMOKE["scheds"]
    else:
        n_ticks, T, n_nodes, kpn = N_TICKS, WAVE_T, N_NODES, KEYS_PER_NODE
        loads, scheds = LOAD_FACTORS, SCHEDULERS
    sweep = {}
    for sched in scheds:
        # warmup: populate the jit cache for this (sched, T, O) signature so
        # the first timed load does not absorb compilation
        TxnService(n_keys=n_nodes * kpn, T=T, sched=sched, n_nodes=n_nodes,
                   host_skew=_host_skew(sched, n_nodes)).run_stream(
            [T], smallbank_txn_gen(np.random.RandomState(0), n_nodes, kpn))
        sweep[sched] = [_run_one(sched, load, n_ticks, T, n_nodes, kpn)
                        for load in loads]
    return {
        "config": {
            "workload": "smallbank-poisson", "n_ticks": n_ticks,
            "wave_size": T, "n_nodes": n_nodes, "keys_per_node": kpn,
            "hot_frac": HOT_FRAC, "hot_per_node": HOT_PER_NODE,
            "load_factors": list(loads), "smoke": smoke,
        },
        "sweep": sweep,
        "gc": _gc_ring_sweep(max(n_ticks // 4, 4), T, n_nodes, kpn),
    }


def write_report(report: Dict) -> None:
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def main(write_json: bool = True, smoke: bool = False) -> Dict:
    report = run(smoke=smoke)
    if write_json:
        write_report(report)
    for sched, rows in report["sweep"].items():
        for r in rows:
            print(f"bench_service/{sched}/load{r['load_factor']}: "
                  f"goodput {r['goodput_tps']:.0f}/s "
                  f"sustained {r['txns_per_sec']:.0f}/s "
                  f"retry {r['retry_rate']:.2f} "
                  f"p50/p95/p99 {r['latency_p50']:.0f}/"
                  f"{r['latency_p95']:.0f}/{r['latency_p99']:.0f} ticks "
                  f"dropped {r['dropped']} rejected {r['rejected']} "
                  f"evicted {r['evicted_visible']} "
                  f"verify_errors {r['verify_errors']}")
    for row in report["gc"]["ring_sweep"]:
        print(f"bench_service/gc/V{row['n_versions']}: "
              f"evicted_visible={row['evicted_visible']} "
              f"committed={row['committed']}")
    b = report["gc"]["gc_block"]
    print(f"bench_service/gc/V{b['n_versions']}+block: "
          f"evicted_visible={b['evicted_visible']} aborted={b['aborted']}")
    return report


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
