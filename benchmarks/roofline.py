"""Roofline table from the dry-run JSON records (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and prints
the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck and the MODEL_FLOPS/HLO_FLOPS usefulness ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load(mesh: str | None = None) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(r)
    return rows


def table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful_flops | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - |"
                       f" - | - | - | SKIP: {r['skipped'][:40]} |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - |"
                       f" - | - | - | ERROR |")
            continue
        rl = r["roofline"]
        uf = rl["useful_flops_frac"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {rl['compute_s']:.4f} | {rl['memory_s']:.4f} |"
            f" {rl['collective_s']:.4f} | {rl['dominant']} |"
            f" {uf:.2f} |  |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | - |  |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> List[Dict]:
    """Headline numbers for run.py CSV."""
    live = [r for r in rows if "roofline" in r]
    out = []
    for r in live:
        rl = r["roofline"]
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "dominant": rl["dominant"],
            "bound_s": max(rl["compute_s"], rl["memory_s"], rl["collective_s"]),
            "compute_s": rl["compute_s"],
            "useful": rl["useful_flops_frac"],
        })
    return out


def main():
    rows = load()
    print(table(rows))
    live = [r for r in rows if "roofline" in r]
    dom = {}
    for r in live:
        dom[r["roofline"]["dominant"]] = dom.get(r["roofline"]["dominant"], 0) + 1
    print(f"\ncells: {len(live)} live, "
          f"{sum(1 for r in rows if 'skipped' in r)} skipped; dominant terms: {dom}")


if __name__ == "__main__":
    main()
