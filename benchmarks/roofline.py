"""Roofline tables: dry-run records + the compiled wave-engine audit.

Two sources:

* experiments/dryrun/*.json (produced by repro.launch.dryrun) — the
  per-(arch x shape x mesh) three-term roofline with the dominant
  bottleneck and the MODEL_FLOPS/HLO_FLOPS usefulness ratio;
* ``engine_roofline()`` — lowers + compiles the fused wave executor
  (``engine._scan_waves``) per scheduler x kernel config and walks the
  optimized HLO with ``repro.launch.hlo_analysis`` for bytes / FLOPs /
  arithmetic intensity per compiled program.  Labels are honest: every
  row names the platform the program was compiled for, so a CPU run
  audits the jnp and interpreted-Pallas lowerings, not TPU Mosaic.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")

ROOF_WAVES = 4
ROOF_T = 64
ROOF_KEYS = 256
ROOF_V = 8


def engine_roofline(smoke: bool = False) -> Dict:
    """Static HLO audit of the fused executor, per scheduler x config.

    Each cell lowers ``engine._scan_waves`` (the measured hot path: one
    lax.scan program over the wave axis) for one scheduler under one
    ``KernelConfig``, compiles it for the current platform, and feeds the
    optimized HLO text through ``hlo_analysis.analyze`` — the while-loop
    trip multiplier means the W scanned waves count W times.  Reported per
    cell: FLOPs, HBM-proxy bytes, collective bytes (0 single-device) and
    arithmetic intensity (FLOPs/byte).  The fused megakernel config should
    show fewer bytes per wave than the three-dispatch path — intermediate
    [T,O] gathers never round-trip through HBM."""
    import jax
    import numpy as np

    from repro.core import SCHEDULERS, make_store
    from repro.core import engine
    from repro.core.workloads import smallbank_waves
    from repro.kernels import BACKENDS, KernelConfig, can_compile_pallas
    from repro.launch import hlo_analysis

    scheds = ("postsi", "cv") if smoke else SCHEDULERS
    base = tuple(bk for bk in BACKENDS
                 if bk != "pallas" or can_compile_pallas())
    configs = base + tuple(bk + "+fused" for bk in base)
    n_nodes = 4
    waves = smallbank_waves(np.random.RandomState(17), ROOF_WAVES, ROOF_T,
                            n_nodes, ROOF_KEYS // n_nodes, dist_frac=0.3)
    stacked = engine.stack_waves(waves)
    store = make_store(ROOF_KEYS, ROOF_V)
    rows = []
    for sched in scheds:
        hs = (jax.numpy.arange(n_nodes, dtype=jax.numpy.int32)
              if sched == "clocksi" else None)
        for spec in configs:
            cfg = KernelConfig(spec)
            lowered = engine._scan_waves.lower(
                store, stacked, jax.numpy.int32(1), jax.numpy.int32(n_nodes),
                sched=sched, host_skew=hs, kernels=cfg)
            txt = lowered.compile().as_text()
            t = hlo_analysis.analyze(txt, n_devices=1)
            rows.append({
                "sched": sched, "backend": cfg.name,
                "platform": jax.default_backend(),
                "flops": t["flops"], "bytes": t["bytes"],
                "collective_bytes": t["collective_bytes"],
                "arith_intensity": round(t["flops"] / t["bytes"], 6)
                                   if t["bytes"] else None,
                "bytes_per_wave": round(t["bytes"] / ROOF_WAVES, 1),
            })
    return {
        "config": {"n_waves": ROOF_WAVES, "wave_size": ROOF_T,
                   "n_keys": ROOF_KEYS, "n_versions": ROOF_V,
                   "n_nodes": n_nodes, "schedulers": list(scheds),
                   "backends": list(configs), "smoke": smoke,
                   "platform": jax.default_backend(),
                   "note": ("static audit of the compiled HLO for THIS "
                            "platform; pallas_interpret rows audit the "
                            "interpreter lowering, not Mosaic; 'flops' "
                            "counts dot/conv ops only — the wave engine "
                            "is integer/compare-bound, so AI ~ 0 is the "
                            "expected honest answer and 'bytes' is the "
                            "roofline term that differentiates configs")},
        "rows": rows,
    }


def load(mesh: str | None = None) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(r)
    return rows


def table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful_flops | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - |"
                       f" - | - | - | SKIP: {r['skipped'][:40]} |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - |"
                       f" - | - | - | ERROR |")
            continue
        rl = r["roofline"]
        uf = rl["useful_flops_frac"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {rl['compute_s']:.4f} | {rl['memory_s']:.4f} |"
            f" {rl['collective_s']:.4f} | {rl['dominant']} |"
            f" {uf:.2f} |  |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | - |  |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> List[Dict]:
    """Headline numbers for run.py CSV."""
    live = [r for r in rows if "roofline" in r]
    out = []
    for r in live:
        rl = r["roofline"]
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "dominant": rl["dominant"],
            "bound_s": max(rl["compute_s"], rl["memory_s"], rl["collective_s"]),
            "compute_s": rl["compute_s"],
            "useful": rl["useful_flops_frac"],
        })
    return out


def main():
    import sys
    if "--engine" in sys.argv:
        rep = engine_roofline(smoke="--smoke" in sys.argv)
        print("| sched | backend | platform | flops | bytes | AI |")
        print("|---|---|---|---|---|---|")
        for r in rep["rows"]:
            print(f"| {r['sched']} | {r['backend']} | {r['platform']} |"
                  f" {r['flops']:.3g} | {r['bytes']:.3g} |"
                  f" {r['arith_intensity']} |")
        return
    rows = load()
    print(table(rows))
    live = [r for r in rows if "roofline" in r]
    dom = {}
    for r in live:
        dom[r["roofline"]["dominant"]] = dom.get(r["roofline"]["dominant"], 0) + 1
    print(f"\ncells: {len(live)} live, "
          f"{sum(1 for r in rows if 'skipped' in r)} skipped; dominant terms: {dom}")


if __name__ == "__main__":
    main()
