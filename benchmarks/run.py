"""Benchmark aggregator: one block per paper table/figure + roofline + kernel
micro-benchmarks + the closed-loop service.  Prints ``name,us_per_call,
derived`` CSV (per assignment).

Run all blocks, or name the ones you want:

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python benchmarks/run.py service     # one block
    PYTHONPATH=src python -m benchmarks.run figures engine
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np


def _csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)


def _engine_figures() -> None:
    from . import (fig06_clock_skew, fig07_08_tpcc, fig09_10_smallbank,
                   fig11_comm_abort, fig12_contention, fig13_length_dist)
    from .simcost import DEFAULT_WAVES

    def n_txn_of(r):
        return DEFAULT_WAVES * (r["committed"] + r["aborted"])

    for r in fig06_clock_skew.run():
        _csv(f"fig06/clocksi/skew{r['skew_ms']}ms",
             r["engine_wall_s"] * 1e6 / n_txn_of(r),
             f"tput={r['throughput_tps']:.0f}tps abort={r['abort_pct']:.1f}%")

    for dist, tag in ((0.2, "fig07"), (0.5, "fig08")):
        for r in fig07_08_tpcc.run(dist_frac=dist):
            _csv(f"{tag}/tpcc/{r['sched']}/n{r['n_nodes']}",
                 r["engine_wall_s"] * 1e6 / n_txn_of(r),
                 f"tput={r['throughput_tps']:.0f}tps abort={r['abort_pct']:.1f}%")

    for dist, tag in ((0.2, "fig09"), (0.5, "fig10")):
        for r in fig09_10_smallbank.run(dist_frac=dist):
            _csv(f"{tag}/smallbank/{r['sched']}/n{r['n_nodes']}",
                 r["engine_wall_s"] * 1e6 / n_txn_of(r),
                 f"tput={r['throughput_tps']:.0f}tps abort={r['abort_pct']:.1f}%")

    for r in fig11_comm_abort.run():
        _csv(f"fig11/{r['sched']}", r["engine_wall_s"] * 1e6 / n_txn_of(r),
             f"cross/txn={r['cross_per_txn']:.2f} coord/txn="
             f"{r['coord_per_txn']:.2f} abort={r['abort_pct']:.1f}%")

    for r in fig12_contention.run():
        _csv(f"fig12/{r['sched']}/hot{r['hot_pct']}",
             r["engine_wall_s"] * 1e6 / n_txn_of(r),
             f"tput={r['throughput_tps']:.0f}tps abort={r['abort_pct']:.1f}%")

    for r in fig13_length_dist.run_length():
        _csv(f"fig13a/{r['sched']}/ops{r['n_ops']}",
             r["engine_wall_s"] * 1e6 / n_txn_of(r),
             f"tput={r['throughput_tps']:.0f}tps")
    for r in fig13_length_dist.run_dist():
        _csv(f"fig13b/{r['sched']}/dist{r['dist_pct']}",
             r["engine_wall_s"] * 1e6 / n_txn_of(r),
             f"tput={r['throughput_tps']:.0f}tps")


def _engine_executor() -> None:
    """Fused-scan vs per-wave executor comparison plus the wave-commit
    megakernel sweep; also refreshes BENCH_engine.json (the perf-trajectory
    datapoint, ``fused_kernel`` section included)."""
    from . import bench_engine
    report = bench_engine.run()
    report["fused_kernel"] = bench_engine.run_fused_kernel()
    bench_engine.write_report(report)     # quiet: keep stdout pure CSV
    for sched, r in report["schedulers"].items():
        n_txn = r["committed"] + r["aborted"]
        _csv(f"engine/fused/{sched}", r["fused_wall_s"] * 1e6 / n_txn,
             f"speedup={r['speedup']:.2f}x waves/s={r['waves_per_sec']:.0f} "
             f"abort={100 * r['abort_rate']:.1f}%")
        for bk, scheds in report["backends"].items():
            b = scheds[sched]
            _csv(f"engine/fused/{sched}/{bk}",
                 b["fused_wall_s"] * 1e6 / n_txn,
                 f"waves/s={b['waves_per_sec']:.0f} "
                 f"vs_default={b['vs_default']:.2f}x")
    for r in report["fused_kernel"]["rows"]:
        _csv(f"engine/wave_commit/T{r['T']}/{r['backend']}",
             r["fused_1launch_us"],
             f"vs_3op={r['speedup']:.2f}x measured={r['measured']}")


def _service() -> None:
    """Closed-loop transaction service (DESIGN.md §8); also refreshes
    BENCH_service.json (goodput/latency/retry trajectory datapoint)."""
    from . import bench_service
    report = bench_service.run()
    bench_service.write_report(report)    # quiet: keep stdout pure CSV
    for sched, rows in report["sweep"].items():
        for r in rows:
            _csv(f"service/{sched}/load{r['load_factor']}",
                 r["wall_s"] * 1e6 / max(r["executions"], 1),
                 f"goodput={r['goodput_tps']:.0f}tps retry={r['retry_rate']:.2f} "
                 f"p99={r['latency_p99']:.0f}ticks dropped={r['dropped']} "
                 f"evicted={r['evicted_visible']}")
    for row in report["gc"]["ring_sweep"]:
        _csv(f"service/gc/V{row['n_versions']}", 0.0,
             f"evicted_visible={row['evicted_visible']}")
    for r in report["streaming"]["sweep"]:
        _csv(f"service/streaming/{r['mode']}/theta{r['theta']}",
             r["wall_s"] * 1e6 / max(r["executions"], 1),
             f"goodput={r['goodput_tps']:.0f}tps "
             f"speedup={r['speedup_vs_step']:.2f}x retry={r['retry_rate']:.2f}")
    a = report["streaming"]["adaptive"]
    _csv(f"service/streaming/{a['mode']}/theta{a['theta']}",
         a["wall_s"] * 1e6 / max(a["executions"], 1),
         f"goodput={a['goodput_tps']:.0f}tps T={a['wave_T_final']} "
         f"md={a['md_events']} ai={a['ai_events']}")


def _planner() -> None:
    """Planned-vs-optimistic goodput crossover (DESIGN.md §10); merges the
    ``planned_crossover`` section into BENCH_engine.json.  With ``--smoke``
    a trimmed sweep also lands in artifacts/planner_smoke/ for CI upload."""
    import json

    from . import bench_engine
    smoke = "--smoke" in _FLAGS
    cross = bench_engine.run_planned_crossover(smoke=smoke)
    bench_engine.write_crossover(cross)   # quiet: keep stdout pure CSV
    for r in cross["rows"]:
        p = r["planned"]
        _csv(f"planner/planned/theta{r['theta']}/T{r['T']}",
             p["wall_s"] * 1e6 / r["n_txn"],
             f"goodput={p['goodput_tps']:.0f}tps lanes={p['lane_waves']} "
             f"plan={p['plan_s']*1e3:.1f}ms wins={r['planned_wins']}")
        for sched in cross["config"]["baselines"]:
            b = r[sched]
            _csv(f"planner/{sched}/theta{r['theta']}/T{r['T']}",
                 b["wall_s"] * 1e6 / r["n_txn"],
                 f"goodput={b['goodput_tps']:.0f}tps "
                 f"abort={100 * b['abort_rate']:.1f}%")
    if smoke:
        out_dir = os.path.join("artifacts", "planner_smoke")
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "planner_crossover.json"), "w") as f:
            json.dump(cross, f, indent=2)
            f.write("\n")


def _dist() -> None:
    """Distributed wave engine on an 8-virtual-device mesh; also refreshes
    BENCH_dist.json.  Runs in a child python: the XLA device count is locked
    at jax init, and this process may already have initialized jax with one
    device — only a fresh interpreter can see the forced 8."""
    import subprocess

    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("PYTHONPATH", "src")
    args = [sys.executable, "-m", "benchmarks.bench_dist"]
    if "--smoke" in _FLAGS:
        args.append("--smoke")
    out = subprocess.run(args, env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise SystemExit(f"benchmarks.bench_dist failed:\n{out.stderr[-3000:]}")
    for line in out.stdout.splitlines():
        if line.startswith("dist/"):        # pass through the CSV rows
            print(line, flush=True)


def _kernel_micro() -> None:
    """XLA-path kernel micro-benchmarks (CPU wall time; derived = ideal
    throughput class).  The Pallas path is validated in tests."""
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.RandomState(0)

    def bench(fn, *args, reps=5):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
            (out[0] if isinstance(out, tuple) else out).block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    B, S, H, KH, D = 1, 1024, 8, 4, 128
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.bfloat16)
    k = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, jnp.bfloat16)
    v = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, jnp.bfloat16)
    us = bench(lambda a, b, c: ops.flash_attention(a, b, c, causal=True), q, k, v)
    fl = 4 * B * H * S * S * D / 2
    _csv("kernel/flash_attention/xla_ref/1k", us, f"{fl/us/1e3:.1f}GFLOPs")

    BH, Sx, P, N = 8, 2048, 64, 128
    x = jnp.asarray(rng.randn(BH, Sx, P) * 0.3, jnp.float32)
    dA = -jnp.asarray(np.abs(rng.rand(BH, Sx)) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.randn(2, Sx, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(2, Sx, N) * 0.3, jnp.float32)
    us = bench(lambda *a: ops.ssd(*a, n_heads_per_group=4), x, dA, Bm, Cm)
    _csv("kernel/ssd_scan/xla_ref/2k", us,
         f"{BH*Sx*P*N*4/us/1e3:.1f}GFLOPs-class")

    # version_scan across every backend the platform can run (the engine
    # read-path hot spot); the label names the backend actually dispatched
    import jax
    from repro.kernels import BACKENDS, KernelConfig

    V = 8
    for bk in BACKENDS:
        if bk == "pallas" and jax.default_backend() != "tpu":
            continue                       # Mosaic cannot lower off-TPU
        # interpret mode pays per-block grid emulation — bench it at the
        # engine's wave-read size instead of stalling the block for minutes
        M, tag = (4096, "4k") if bk == "pallas_interpret" else (65536, "64k")
        cids = jnp.asarray(np.sort(rng.randint(0, 1 << 20, (M, V)), 1),
                           jnp.int32)
        tids = jnp.asarray(rng.randint(-1, 1000, (M, V)), jnp.int32)
        mc = jnp.asarray(rng.randint(0, 1 << 20, (M,)), jnp.int32)
        cfg = KernelConfig(bk)
        us = bench(lambda *a: ops.version_scan(
            *a, use_pallas=cfg.use_pallas, interpret=cfg.interpret),
            cids, tids, mc)
        _csv(f"kernel/version_scan/{bk}/{tag}", us,
             f"{M*V*8/us/1e3:.2f}GB/s-scan")

    T, O = 256, 8
    rk = jnp.asarray(rng.randint(-1, 4000, (T, O)), jnp.int32)
    wk = jnp.asarray(rng.randint(-1, 4000, (T, O)), jnp.int32)
    us = bench(lambda *a: ops.potential_matrix(*a), rk, wk)
    _csv("kernel/potential_matrix/xla_ref/256", us, f"{T*T*O*O/us/1e3:.1f}Gcmp/s")


def _roofline_headlines() -> None:
    """Dry-run roofline headlines + the compiled wave-engine HLO audit
    (bytes / FLOPs / arithmetic intensity per scheduler x kernel config).
    The engine audit lands as the ``roofline`` section of BENCH_engine.json
    and as artifacts/roofline/engine_roofline.json for CI upload."""
    import json

    from . import bench_engine, roofline
    rep = roofline.engine_roofline(smoke="--smoke" in _FLAGS)
    bench_engine.write_section("roofline", rep)   # quiet: stdout stays CSV
    out_dir = os.path.join("artifacts", "roofline")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "engine_roofline.json"), "w") as f:
        json.dump(rep, f, indent=2)
        f.write("\n")
    for r in rep["rows"]:
        _csv(f"roofline/engine/{r['sched']}/{r['backend']}", 0.0,
             f"flops={r['flops']:.3g} bytes={r['bytes']:.3g} "
             f"AI={r['arith_intensity']} platform={r['platform']}")
    try:
        rows = roofline.load()
    except Exception:
        return
    for s in roofline.summary(rows):
        u = s["useful"]
        _csv(s["name"], s["bound_s"] * 1e6,
             f"dominant={s['dominant']} useful={u if u is None else round(u, 2)}")


BLOCKS = {
    "figures": _engine_figures,
    "engine": _engine_executor,
    "service": _service,
    "planner": _planner,
    "dist": _dist,
    "kernels": _kernel_micro,
    "roofline": _roofline_headlines,
}


_FLAGS: list = []      # dash-flags of the current invocation (for blocks)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    _FLAGS[:] = [a for a in argv if a.startswith("-")]
    names = [a for a in argv if not a.startswith("-")] or list(BLOCKS)
    unknown = [n for n in names if n not in BLOCKS]
    if unknown:
        raise SystemExit(f"unknown block(s) {unknown}; pick from {list(BLOCKS)}")
    print("name,us_per_call,derived")
    for n in names:
        BLOCKS[n]()


if __name__ == "__main__":
    if __package__ in (None, ""):          # `python benchmarks/run.py ...`
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        __package__ = "benchmarks"
    main()
