"""Paper Fig. 6: Clock-SI throughput/abort rate vs time skew
(TPC-C, 8 nodes, 20% distributed).  Skew unit ~ 10 ms."""
import numpy as np

from repro.core.workloads import tpcc_waves

from .simcost import DEFAULT_WAVES, KEYS_PER_NODE, print_table, simulate, wave_size


def run(fast: bool = True):
    n_nodes = 8
    rng = np.random.RandomState(0)
    waves = tpcc_waves(rng, DEFAULT_WAVES, wave_size(n_nodes), n_nodes, KEYS_PER_NODE,
                       dist_frac=0.2)
    rows = []
    for skew_units in (0, 1, 2, 4):
        hs = np.round(np.linspace(0, skew_units, n_nodes)).astype(np.int32)
        r = simulate(waves, "clocksi", n_nodes, host_skew=hs)
        r["skew_ms"] = skew_units * 10
        rows.append(r)
    return rows


def main():
    rows = run()
    print_table(rows, ["skew_ms", "throughput_tps", "abort_pct", "waits"],
                "Fig 6: Clock-SI vs time skew (TPC-C, 8 nodes, 20% dist)")


if __name__ == "__main__":
    main()
