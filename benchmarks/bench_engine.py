"""Engine executor benchmark: fused lax.scan executor vs per-wave driver.

Runs a multi-wave SmallBank workload through both drivers for every
scheduler, checks the histories are bit-identical, and records wave
throughput (txns/sec, waves/sec, abort rate) plus fused vs per-wave
wall-clock into ``BENCH_engine.json`` at the repo root — the perf
trajectory datapoint for the device-resident hot loop (DESIGN.md §7).

Wall-clock excludes compilation: each driver is warmed up once on the same
shapes, then timed over ``reps`` fresh stores (the workload itself is
identical, so the comparison isolates dispatch/host-sync overhead — exactly
what the fused executor removes).

Run:  PYTHONPATH=src python -m benchmarks.bench_engine
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import (SCHEDULERS, make_store, potential_backend,
                        run_workload, run_workload_fused)
from repro.core.workloads import smallbank_waves

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_engine.json")

N_WAVES = 32
WAVE_T = 64
N_NODES = 8
KEYS_PER_NODE = 200
REPS = 3


def _time(driver, waves, sched, host_skew, reps=REPS):
    mk = lambda: make_store(N_NODES * KEYS_PER_NODE, 8)
    out = driver(mk(), waves, sched=sched, n_nodes=N_NODES,
                 host_skew=host_skew)          # warmup: compile + first run
    best = float("inf")
    for _ in range(reps):
        store = mk()
        t0 = time.perf_counter()
        out = driver(store, waves, sched=sched, n_nodes=N_NODES,
                     host_skew=host_skew)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(scheds=SCHEDULERS) -> Dict:
    rng = np.random.RandomState(11)
    waves = smallbank_waves(rng, N_WAVES, WAVE_T, N_NODES, KEYS_PER_NODE,
                            dist_frac=0.2)
    n_txn = N_WAVES * WAVE_T
    rows = {}
    for sched in scheds:
        hs = (np.round(np.linspace(0, 2, N_NODES)).astype(np.int32)
              if sched == "clocksi" else None)
        t_fused, (_, h_f, st_f) = _time(run_workload_fused, waves, sched, hs)
        t_wave, (_, h_w, st_w) = _time(run_workload, waves, sched, hs)
        for (t1, o1), (t2, o2) in zip(h_f, h_w):
            np.testing.assert_array_equal(t1, t2)
            for f1, f2 in zip(o1, o2):
                np.testing.assert_array_equal(f1, f2)
        rows[sched] = {
            "fused_wall_s": round(t_fused, 6),
            "perwave_wall_s": round(t_wave, 6),
            "speedup": round(t_wave / t_fused, 3),
            "txns_per_sec": round(n_txn / t_fused, 1),
            "waves_per_sec": round(N_WAVES / t_fused, 1),
            "committed": st_f.committed,
            "aborted": st_f.aborted,
            "abort_rate": round(st_f.aborted / n_txn, 4),
        }
    return {
        "config": {
            "workload": "smallbank", "n_waves": N_WAVES, "wave_size": WAVE_T,
            "n_nodes": N_NODES, "keys_per_node": KEYS_PER_NODE,
            "dist_frac": 0.2, "reps": REPS,
            "potential_backend": potential_backend(),
        },
        "schedulers": rows,
    }


def write_report(report: Dict) -> None:
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def main(write_json: bool = True) -> Dict:
    report = run()
    if write_json:
        write_report(report)
    for sched, r in report["schedulers"].items():
        print(f"bench_engine/{sched}: fused {r['fused_wall_s']*1e3:.1f}ms "
              f"vs per-wave {r['perwave_wall_s']*1e3:.1f}ms "
              f"({r['speedup']:.2f}x)  {r['txns_per_sec']:.0f} txn/s "
              f"{r['waves_per_sec']:.0f} waves/s abort={r['abort_rate']:.2%}")
    return report


if __name__ == "__main__":
    main()
