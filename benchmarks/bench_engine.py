"""Engine executor benchmark: fused lax.scan executor vs per-wave driver.

Runs a multi-wave SmallBank workload through both drivers for every
scheduler, checks the histories are bit-identical, and records wave
throughput (txns/sec, waves/sec, abort rate) plus fused vs per-wave
wall-clock into ``BENCH_engine.json`` at the repo root — the perf
trajectory datapoint for the device-resident hot loop (DESIGN.md §7).

Wall-clock excludes compilation: each driver is warmed up once on the same
shapes, then timed over ``reps`` fresh stores (the workload itself is
identical, so the comparison isolates dispatch/host-sync overhead — exactly
what the fused executor removes).

Run:  PYTHONPATH=src python -m benchmarks.bench_engine
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import (SCHEDULERS, default_backend, make_store,
                        run_workload, run_workload_fused)
from repro.core.workloads import smallbank_waves, ycsb_waves

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_engine.json")

N_WAVES = 32
WAVE_T = 64
N_NODES = 8
KEYS_PER_NODE = 200
REPS = 3


def _sweep_backends():
    """Backend configs the platform can actually run end-to-end: every
    runnable base backend plus its ``+fused`` wave-commit-megakernel
    variant (compiled 'pallas' needs a platform the probe accepts)."""
    from repro.kernels import BACKENDS, can_compile_pallas
    base = tuple(bk for bk in BACKENDS
                 if bk != "pallas" or can_compile_pallas())
    return base + tuple(bk + "+fused" for bk in base)


def _time(driver, waves, sched, host_skew, reps=REPS, kernels=None):
    """(best wall, warmup wall, out).  Honest timing: each timed region ends
    with ``jax.block_until_ready`` on the driver's actual outputs (the
    returned store leaves — the histories are already host-synced by the
    drivers), the per-rep store build + device sync happens *before* the
    timer starts, and the warmup (compile + first run) wall is returned
    separately so the JSON records it instead of silently dropping it."""
    import jax
    mk = lambda: make_store(N_NODES * KEYS_PER_NODE, 8)
    t0 = time.perf_counter()
    out = driver(mk(), waves, sched=sched, n_nodes=N_NODES,
                 host_skew=host_skew,
                 kernels=kernels)              # warmup: compile + first run
    jax.block_until_ready(out[0])
    warmup = time.perf_counter() - t0
    best = float("inf")
    for _ in range(reps):
        store = jax.block_until_ready(mk())
        t0 = time.perf_counter()
        out = driver(store, waves, sched=sched, n_nodes=N_NODES,
                     host_skew=host_skew, kernels=kernels)
        jax.block_until_ready(out[0])
        best = min(best, time.perf_counter() - t0)
    return best, warmup, out


def run(scheds=SCHEDULERS, backends=None) -> Dict:
    rng = np.random.RandomState(11)
    waves = smallbank_waves(rng, N_WAVES, WAVE_T, N_NODES, KEYS_PER_NODE,
                            dist_frac=0.2)
    n_txn = N_WAVES * WAVE_T
    backends = _sweep_backends() if backends is None else backends
    rows = {}
    backend_rows = {bk: {} for bk in backends}
    for sched in scheds:
        hs = (np.round(np.linspace(0, 2, N_NODES)).astype(np.int32)
              if sched == "clocksi" else None)
        t_fused, w_fused, (_, h_f, st_f) = _time(run_workload_fused, waves,
                                                 sched, hs)
        t_wave, w_wave, (_, h_w, st_w) = _time(run_workload, waves, sched, hs)
        for (t1, o1), (t2, o2) in zip(h_f, h_w):
            np.testing.assert_array_equal(t1, t2)
            for f1, f2 in zip(o1, o2):
                np.testing.assert_array_equal(f1, f2)
        rows[sched] = {
            "fused_wall_s": round(t_fused, 6),
            "perwave_wall_s": round(t_wave, 6),
            "fused_warmup_s": round(w_fused, 6),
            "perwave_warmup_s": round(w_wave, 6),
            "speedup": round(t_wave / t_fused, 3),
            "txns_per_sec": round(n_txn / t_fused, 1),
            "waves_per_sec": round(N_WAVES / t_fused, 1),
            "committed": st_f.committed,
            "aborted": st_f.aborted,
            "abort_rate": round(st_f.aborted / n_txn, 4),
        }
        # backend sweep (fused hot path, explicit KernelConfig per run):
        # the trajectory datapoint gains the backend dimension, and every
        # backend's history must stay bit-identical to the default run's
        for bk in backends:
            t_bk, w_bk, (_, h_bk, st_bk) = _time(run_workload_fused, waves,
                                                 sched, hs, kernels=bk)
            for (t1, o1), (t2, o2) in zip(h_f, h_bk):
                np.testing.assert_array_equal(t1, t2)
                for f1, f2 in zip(o1, o2):
                    np.testing.assert_array_equal(f1, f2)
            backend_rows[bk][sched] = {
                "fused_wall_s": round(t_bk, 6),
                "warmup_s": round(w_bk, 6),
                "txns_per_sec": round(n_txn / t_bk, 1),
                "waves_per_sec": round(N_WAVES / t_bk, 1),
                "vs_default": round(t_fused / t_bk, 3),
            }
    return {
        "config": {
            "workload": "smallbank", "n_waves": N_WAVES, "wave_size": WAVE_T,
            "n_nodes": N_NODES, "keys_per_node": KEYS_PER_NODE,
            "dist_frac": 0.2, "reps": REPS,
            "kernel_backend": default_backend(),
            "backend_sweep": list(backends),
        },
        "schedulers": rows,
        "backends": backend_rows,
    }


# ------------------------------------------------ fused megakernel sweep
FUSED_TS = (64, 128, 256)
FUSED_O = 8
FUSED_V = 8
FUSED_REPS = 5


def run_fused_kernel() -> Dict:
    """Op-level sweep: the single-launch ``ops.wave_commit`` megakernel vs
    the three-dispatch unfused read phase (version_scan + s_lo reduction +
    potential_matrix) at wave sizes T, per runnable backend config, over
    rings populated by a real SmallBank prefix.

    Labels are honest: every row names the platform that actually executed
    and marks the Pallas interpreter as emulation (NOT a perf datapoint) —
    the compiled claim is only made where a compiled backend really ran.
    Fused and unfused outputs are asserted bit-identical before timing
    counts."""
    import jax
    import jax.numpy as jnp

    from repro.core.substrate import LocalSubstrate
    from repro.kernels import BACKENDS, KernelConfig, can_compile_pallas

    base = tuple(bk for bk in BACKENDS
                 if bk != "pallas" or can_compile_pallas())
    rng = np.random.RandomState(5)
    n_keys = N_NODES * KEYS_PER_NODE
    store, _, _ = run_workload(
        make_store(n_keys, FUSED_V),
        smallbank_waves(rng, 8, 64, N_NODES, KEYS_PER_NODE, dist_frac=0.2),
        sched="postsi", n_nodes=N_NODES)
    INF = jnp.int32(1 << 30)
    rows = []
    for T in FUSED_TS:
        keys = jnp.asarray(rng.randint(0, n_keys, (T, FUSED_O)), jnp.int32)
        is_r = jnp.asarray(rng.rand(T, FUSED_O) < 0.6)
        is_w = jnp.asarray(rng.rand(T, FUSED_O) < 0.4)
        mc = jnp.broadcast_to(INF, keys.shape)

        def timed(sub):
            out = jax.block_until_ready(
                sub.read_phase(store, keys, mc, is_r, is_w))   # warmup
            best = float("inf")
            for _ in range(FUSED_REPS):
                t0 = time.perf_counter()
                out = sub.read_phase(store, keys, mc, is_r, is_w)
                jax.block_until_ready(out)
                best = min(best, time.perf_counter() - t0)
            return best, out

        for bk in base:
            t_u, o_u = timed(LocalSubstrate(KernelConfig(bk)))
            t_f, o_f = timed(LocalSubstrate(KernelConfig(bk + "+fused")))
            for a, b in zip(o_u, o_f):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            rows.append({
                "T": T, "ops_per_txn": FUSED_O, "backend": bk,
                "platform": jax.default_backend(),
                "measured": ("interpreted (Pallas interpreter; emulation, "
                             "not a perf datapoint)"
                             if bk == "pallas_interpret" else
                             f"compiled ({jax.default_backend()})"),
                "unfused_3op_us": round(t_u * 1e6, 2),
                "fused_1launch_us": round(t_f * 1e6, 2),
                "speedup": round(t_u / t_f, 3),
            })
    return {
        "config": {"wave_sizes": list(FUSED_TS), "n_ops": FUSED_O,
                   "n_versions": FUSED_V, "n_keys": n_keys,
                   "reps": FUSED_REPS, "backends": list(base),
                   "platform": jax.default_backend()},
        "rows": rows,
        "fused_wins_1p3x": any(r["speedup"] >= 1.3 and r["T"] >= 64
                               for r in rows),
    }


# ---------------------------------------------------- planner crossover
# zipfian write-heavy YCSB: where does the planned scheduler's abort-free
# execution overtake optimistic retry-burn?  (DESIGN.md §10)
CROSS_THETAS = (0.6, 0.9, 0.99)
CROSS_TS = (16, 64, 128)
CROSS_WAVES = 8
CROSS_KPN = 8            # 64 hot keys total: the retry-burn regime
CROSS_READ_FRAC = 0.1
CROSS_BASES = ("postsi", "cv")


def _time_goodput(driver, waves, n_keys, reps, **kw):
    """(best wall, warmup wall, stats); goodput is committed/wall — aborted
    work counts in the denominator only.  Same honesty contract as
    ``_time``: timed regions end with ``block_until_ready`` on the returned
    store, warmup (compile + first run) is reported, not hidden."""
    import jax
    mk = lambda: make_store(n_keys, 8)
    t0 = time.perf_counter()
    out = driver(mk(), waves, **kw)           # warmup: compile + first run
    jax.block_until_ready(out[0])
    warmup = time.perf_counter() - t0
    best = float("inf")
    for _ in range(reps):
        store = jax.block_until_ready(mk())
        t0 = time.perf_counter()
        st_out, _, st = driver(store, waves, **kw)
        jax.block_until_ready(st_out)
        best = min(best, time.perf_counter() - t0)
    return best, warmup, st


def run_planned_crossover(smoke: bool = False) -> Dict:
    """Goodput (committed txns/sec) of ``"planned"`` vs the fused optimistic
    baselines across skew theta x wave size.  The planned wall honestly
    includes the host-side conflict-graph + coloring cost every rep —
    planning is not amortized away."""
    from repro.planner import run_workload_planned

    thetas = (0.9, 0.99) if smoke else CROSS_THETAS
    ts = (64,) if smoke else CROSS_TS
    n_waves = 2 if smoke else CROSS_WAVES
    reps = 1 if smoke else REPS
    rows = []
    for T in ts:
        for theta in thetas:
            waves = ycsb_waves(np.random.RandomState(23), n_waves, T,
                               N_NODES, CROSS_KPN, theta=theta,
                               read_frac=CROSS_READ_FRAC, dist_frac=0.1,
                               n_ops=4)
            n_txn = n_waves * T
            n_keys = N_NODES * CROSS_KPN
            row = {"theta": theta, "T": T, "n_txn": n_txn}
            for sched in CROSS_BASES:
                wall, warm, st = _time_goodput(run_workload_fused, waves,
                                               n_keys, reps, sched=sched,
                                               n_nodes=N_NODES)
                row[sched] = {
                    "wall_s": round(wall, 6),
                    "warmup_s": round(warm, 6),
                    "committed": st.committed,
                    "abort_rate": round(st.aborted / n_txn, 4),
                    "goodput_tps": round(st.committed / wall, 1),
                }
            wall, warm, st = _time_goodput(run_workload_planned, waves,
                                           n_keys, reps, sched="postsi",
                                           n_nodes=N_NODES)
            assert st.aborted == 0 and st.committed == n_txn
            row["planned"] = {
                "wall_s": round(wall, 6),
                "warmup_s": round(warm, 6),
                "committed": st.committed,
                "abort_rate": 0.0,
                "lane_waves": st.lane_waves,
                "plan_s": round(st.plan_s, 6),
                "goodput_tps": round(st.committed / wall, 1),
            }
            row["planned_wins"] = row["planned"]["goodput_tps"] > max(
                row[s]["goodput_tps"] for s in CROSS_BASES)
            rows.append(row)
    return {
        "config": {
            "workload": "ycsb", "thetas": list(thetas), "wave_sizes": list(ts),
            "n_waves": n_waves, "n_nodes": N_NODES,
            "keys_per_node": CROSS_KPN, "read_frac": CROSS_READ_FRAC,
            "n_ops": 4, "reps": reps, "smoke": smoke,
            "kernel_backend": default_backend(),
            "baselines": list(CROSS_BASES),
        },
        "rows": rows,
        "planned_wins_high_skew": any(
            r["planned_wins"] for r in rows if r["theta"] >= 0.99 or smoke),
    }


# sections that independent bench blocks own and refresh on their own
# cadence — rewriting the file for one block must not drop the others
_MERGE_SECTIONS = ("planned_crossover", "fused_kernel", "roofline")


def write_section(name: str, payload: Dict) -> None:
    """Merge one named section into BENCH_engine.json, preserving whatever
    the other blocks already wrote there."""
    report = {}
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            report = json.load(f)
    report[name] = payload
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def write_crossover(cross: Dict) -> None:
    write_section("planned_crossover", cross)


def write_report(report: Dict) -> None:
    # the executor block refreshes the whole file — carry over every
    # independently-owned section it did not itself produce
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            old = json.load(f)
        for k in _MERGE_SECTIONS:
            if k not in report and k in old:
                report = dict(report, **{k: old[k]})
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def main(write_json: bool = True) -> Dict:
    report = run()
    report["fused_kernel"] = run_fused_kernel()
    if write_json:
        write_report(report)
    for sched, r in report["schedulers"].items():
        print(f"bench_engine/{sched}: fused {r['fused_wall_s']*1e3:.1f}ms "
              f"vs per-wave {r['perwave_wall_s']*1e3:.1f}ms "
              f"({r['speedup']:.2f}x)  {r['txns_per_sec']:.0f} txn/s "
              f"{r['waves_per_sec']:.0f} waves/s abort={r['abort_rate']:.2%}")
    for bk, scheds in report["backends"].items():
        for sched, r in scheds.items():
            print(f"bench_engine/{sched}/{bk}: fused "
                  f"{r['fused_wall_s']*1e3:.1f}ms "
                  f"{r['txns_per_sec']:.0f} txn/s "
                  f"(vs default {r['vs_default']:.2f}x)")
    for r in report["fused_kernel"]["rows"]:
        print(f"bench_engine/wave_commit/T{r['T']}/{r['backend']}: "
              f"fused {r['fused_1launch_us']:.0f}us vs 3-op "
              f"{r['unfused_3op_us']:.0f}us ({r['speedup']:.2f}x) "
              f"[{r['measured']}]")
    return report


if __name__ == "__main__":
    main()
