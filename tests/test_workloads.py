"""Workload-generator unit tests (core/workloads.py).

Direct coverage for the batch generators the benchmarks lean on — until now
``tpcc_waves`` and ``micro_waves`` were only ever exercised through whole
engine runs, so a generator regression (keys off-partition, op-count
overflow, malformed NOP padding, seed drift) would surface as a mysterious
benchmark shift instead of a failing unit.  Checks per generator:

* key-partition invariant: every emitted key belongs to the node the txn
  meant to touch (``node = key % n_nodes``, ``store.node_of_key``) and
  stays inside ``[0, n_nodes * keys_per_node)``;
* op-count bounds and NOP-padding well-formedness (non-NOP ops carry the
  declared kinds, padded slots are exactly ``op_kind == NOP``, duplicate
  keys inside a txn are NOP-ed out);
* TID layout: contiguous ``arange`` per wave, waves non-overlapping;
* reproducibility: same seed → bit-identical waves, fresh seed → different.

Plus the zipfian YCSB generator added for the streaming plane: CDF sanity,
skew actually skews, knobs (read_frac / dist_frac) act.
"""
import numpy as np
import pytest

from repro.core import NOP, READ, RMW, WRITE
from repro.core.workloads import (chain_txn, chain_waves, micro_waves,
                                  smallbank_waves, tpcc_waves, ycsb_txn,
                                  ycsb_waves, zipf_cdf, zipf_rank)

N_NODES, KPN = 4, 50
N_KEYS = N_NODES * KPN
KINDS = {NOP, READ, WRITE, RMW}


def _np_wave(w):
    return (np.asarray(w.op_kind), np.asarray(w.op_key),
            np.asarray(w.op_val), np.asarray(w.host), np.asarray(w.tid))


def _check_common(waves, T, O, max_ops, tid0=1):
    """Shape/kind/key/TID/padding invariants shared by every generator."""
    next_tid = tid0
    for w in waves:
        op_kind, op_key, op_val, host, tid = _np_wave(w)
        assert op_kind.shape == (T, O) and op_key.shape == (T, O)
        assert host.shape == (T,) and tid.shape == (T,)
        assert set(np.unique(op_kind)) <= KINDS
        assert ((host >= 0) & (host < N_NODES)).all()
        active = op_kind != NOP
        # key-partition invariant: active keys live inside the key space
        assert ((op_key[active] >= 0) & (op_key[active] < N_KEYS)).all()
        # op-count bounds: every txn fits its declared budget
        assert (active.sum(axis=1) <= max_ops).all()
        # NOP padding well-formed: padded slots carry no value payload
        assert (op_val[op_kind == NOP] == 0).all()
        assert (op_val[op_kind == READ] == 0).all()
        # engine precondition: distinct non-NOP keys inside each txn
        for t in range(T):
            ks = op_key[t][active[t]]
            assert len(ks) == len(set(ks.tolist())), f"dup keys in txn {t}"
        # TIDs: contiguous arange per wave, consecutive across waves
        np.testing.assert_array_equal(tid, next_tid + np.arange(T))
        next_tid += T


def _assert_reproducible(gen_fn):
    a = gen_fn(np.random.RandomState(7))
    b = gen_fn(np.random.RandomState(7))
    c = gen_fn(np.random.RandomState(8))
    for wa, wb in zip(a, b):
        for fa, fb in zip(_np_wave(wa), _np_wave(wb)):
            np.testing.assert_array_equal(fa, fb)
    assert any((fa != fc).any()
               for wa, wc in zip(a, c)
               for fa, fc in zip(_np_wave(wa), _np_wave(wc)))


# ------------------------------------------------------------------ tpcc
def test_tpcc_waves_invariants():
    rng = np.random.RandomState(0)
    waves = tpcc_waves(rng, 4, 16, N_NODES, KPN, dist_frac=0.4,
                       districts_per_node=20, tid0=1)
    _check_common(waves, 16, 12, max_ops=9)   # new-order: 1+5+3 ops max
    for w in waves:
        op_kind, op_key, _, host, _ = _np_wave(w)
        for t in range(16):
            active = op_kind[t] != NOP
            assert 2 <= active.sum() <= 9     # payment=2 .. new-order=9
            # op 0 (district / warehouse row) is host-local by construction
            assert op_kind[t, 0] == RMW
            assert op_key[t, 0] % N_NODES == host[t]


def test_tpcc_waves_reproducible():
    _assert_reproducible(
        lambda rng: tpcc_waves(rng, 3, 8, N_NODES, KPN, dist_frac=0.3,
                               districts_per_node=20))


# ----------------------------------------------------------------- micro
def test_micro_waves_invariants_and_locality():
    rng = np.random.RandomState(1)
    waves = micro_waves(rng, 4, 16, N_NODES, KPN, n_ops=6, read_ratio=0.5,
                        dist_frac=0.0, blind_frac=0.5)
    _check_common(waves, 16, 6, max_ops=6)
    for w in waves:
        op_kind, op_key, _, host, _ = _np_wave(w)
        # dist_frac=0: the key-partition invariant in its sharpest form —
        # every active key resolves to the issuing host (node = key % n)
        active = op_kind != NOP
        node = op_key % N_NODES
        assert (node[active] == np.broadcast_to(host[:, None],
                                                op_key.shape)[active]).all()


def test_micro_waves_knobs():
    rng = np.random.RandomState(2)
    all_reads = micro_waves(rng, 2, 16, N_NODES, KPN, n_ops=4,
                            read_ratio=1.0)
    for w in all_reads:
        op_kind = np.asarray(w.op_kind)
        assert set(np.unique(op_kind)) <= {NOP, READ}
    blind = micro_waves(np.random.RandomState(3), 2, 16, N_NODES, KPN,
                        n_ops=4, read_ratio=0.0, blind_frac=1.0)
    kinds = np.unique(np.concatenate(
        [np.asarray(w.op_kind).ravel() for w in blind]))
    assert WRITE in kinds and RMW not in kinds


def test_micro_waves_reproducible():
    _assert_reproducible(
        lambda rng: micro_waves(rng, 3, 8, N_NODES, KPN, n_ops=4,
                                hot_frac=0.5, hot_per_node=3))


# ------------------------------------------------------------- smallbank
def test_smallbank_waves_invariants():
    rng = np.random.RandomState(4)
    waves = smallbank_waves(rng, 4, 16, N_NODES, KPN, dist_frac=0.3)
    _check_common(waves, 16, 4, max_ops=2)    # every SmallBank txn has <= 2
    _assert_reproducible(
        lambda r: smallbank_waves(r, 3, 8, N_NODES, KPN))


# ------------------------------------------------------------------ ycsb
def test_zipf_cdf_sane():
    cdf = zipf_cdf(100, 0.9)
    assert cdf.shape == (100,)
    assert (np.diff(cdf) > 0).all() and cdf[-1] == 1.0
    uniform = zipf_cdf(100, 0.0)
    np.testing.assert_allclose(np.diff(uniform), 1 / 100, atol=1e-12)
    # rank 0 is the hottest and skew concentrates it
    assert zipf_cdf(100, 1.2)[0] > cdf[0] > uniform[0]
    rng = np.random.RandomState(0)
    ranks = [zipf_rank(rng, cdf) for _ in range(500)]
    assert min(ranks) >= 0 and max(ranks) < 100


def test_ycsb_txn_knobs_and_partition():
    rng = np.random.RandomState(5)
    for _ in range(50):
        host = int(rng.randint(0, N_NODES))
        op_kind, op_key, op_val = ycsb_txn(rng, host, N_NODES, KPN,
                                           theta=0.9, read_frac=1.0,
                                           dist_frac=0.0)
        active = op_kind != NOP
        assert set(np.unique(op_kind)) <= {NOP, READ}
        assert (op_key[active] % N_NODES == host).all()   # local txn
        assert (op_val == 0).all()
        ks = op_key[active]
        assert len(ks) == len(set(ks.tolist()))
    # write-heavy: RMWs appear and carry values
    op_kind, op_key, op_val = ycsb_txn(np.random.RandomState(6), 0, N_NODES,
                                       KPN, theta=0.0, read_frac=0.0,
                                       dist_frac=0.0)
    assert (op_kind[op_kind != NOP] == RMW).all()
    assert (op_val[op_kind == RMW] > 0).all()


def test_ycsb_skew_concentrates_traffic():
    """theta=1.2 must hit each node's rank-0 key far more often than the
    uniform stream does — the §V-D contention knob actually turns."""
    def hot_share(theta):
        rng = np.random.RandomState(7)
        hot = total = 0
        for _ in range(300):
            host = int(rng.randint(0, N_NODES))
            op_kind, op_key, _ = ycsb_txn(rng, host, N_NODES, KPN,
                                          theta=theta, read_frac=0.5)
            active = op_kind != NOP
            hot += int((op_key[active] // N_NODES == 0).sum())
            total += int(active.sum())
        return hot / total
    assert hot_share(1.2) > 0.2 > 5 / KPN > hot_share(0.0)


def test_ycsb_waves_invariants_and_reproducible():
    rng = np.random.RandomState(8)
    waves = ycsb_waves(rng, 4, 16, N_NODES, KPN, theta=0.9, n_ops=4)
    _check_common(waves, 16, 4, max_ops=4)
    _assert_reproducible(
        lambda r: ycsb_waves(r, 3, 8, N_NODES, KPN, theta=1.1))


# ----------------------------------------------------------------- chains
def test_chain_txn_links():
    # head raw link: no read, one RMW of its own key
    op_kind, op_key, op_val = chain_txn(None, 13, "raw", val=5)
    assert op_kind.tolist() == [NOP, RMW]
    assert op_key[1] == 13 and op_val[1] == 5
    # interior raw link: reads the predecessor, RMWs its own fresh key
    op_kind, op_key, _ = chain_txn(13, 17, "raw")
    assert op_kind.tolist() == [READ, RMW]
    assert op_key.tolist() == [13, 17]
    # waw link: single RMW of the shared chain key
    op_kind, op_key, _ = chain_txn(13, 13, "waw")
    assert op_kind.tolist() == [NOP, RMW] and op_key[1] == 13
    with pytest.raises(ValueError):
        chain_txn(1, 2, "zigzag")
    with pytest.raises(ValueError):
        chain_txn(1, 2, "raw", n_ops=1)


@pytest.mark.parametrize("kind", ["raw", "waw", "mixed"])
def test_chain_waves_invariants(kind):
    rng = np.random.RandomState(9)
    waves = chain_waves(rng, 3, 16, N_NODES, KPN, chain_len=4, kind=kind)
    _check_common(waves, 16, 2, max_ops=2)
    for w in waves:
        op_kind, op_key, _, host, _ = _np_wave(w)
        active = op_kind != NOP
        # every chain stays on one host partition (key % n == host)
        node = op_key % N_NODES
        assert (node[active] == np.broadcast_to(
            host[:, None], op_key.shape)[active]).all()
        for t in range(16):
            pos = t % 4
            if pos == 0:
                continue
            # the deliberate intra-wave dependency: each interior link
            # touches the key its predecessor wrote (reads it on a raw
            # link, RMWs the same shared key on a waw link)
            prev_write = op_key[t - 1, 1]
            if kind == "raw":
                assert op_kind[t, 0] == READ and op_key[t, 0] == prev_write
            elif kind == "waw":
                assert op_kind[t, 1] == RMW and op_key[t, 1] == prev_write
    # chains are key-disjoint from each other (raw/mixed draw without
    # replacement), so the conflict components are exactly the chains
    if kind == "raw":
        for w in waves:
            op_key, op_kind = np.asarray(w.op_key), np.asarray(w.op_kind)
            writes = op_key[:, 1][op_kind[:, 1] == RMW]
            assert len(writes) == len(set(writes.tolist()))


def test_chain_waves_reproducible_and_capacity():
    _assert_reproducible(
        lambda r: chain_waves(r, 3, 8, N_NODES, KPN, chain_len=3,
                              kind="mixed"))
    # partition exhaustion is a loud error, not silent key reuse
    with pytest.raises(ValueError):
        chain_waves(np.random.RandomState(0), 1, 64, 1, 8, chain_len=64,
                    kind="raw")
