"""Planner-plane conformance (src/repro/planner/, DESIGN.md §10).

Three layers:

* **graph/lanes units + hypothesis properties** — the conflict graph finds
  exactly the declared WW/WR/RW edges (NOP-aware, dense == grouped), and
  the layered coloring's invariants hold on arbitrary op arrays: lanes are
  pairwise conflict-free, lane union + spill covers the wave exactly once,
  every conflict edge is oriented forward (topological in lane order), and
  nothing spills without a budget.
* **planned-vs-oracle differential** — the ``"planned"`` scheduler commits
  with ZERO aborts and lands in exactly the sequential oracle's state
  (``core/seq.py`` replayed in tid order: same commit set — everything —
  and same final store values) on random zipfian and deliberate chain
  workloads, for every base scheduler, on both kernel backends and both
  substrates (the mesh case runs in a subprocess with 8 virtual devices,
  bit-identical to local).
* **hybrid service** — the switch enters planned mode when the trailing
  abort rate crosses the AIMD ceiling, leaves it when the planned waves'
  conflict fraction drops, and served histories stay verifier-clean.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import ABORTED, COMMITTED, NOP, READ, RMW, WRITE, make_store
from repro.core.engine import SCHEDULERS
from repro.core.seq import SeqScheduler
from repro.core.verify import final_values_ok, verify_cv, verify_si
from repro.core.workloads import chain_waves, ycsb_waves
from repro.planner import (ALL_SCHEDULERS, PLANNED, HybridSwitch, Plan,
                           PlannerError, color_lanes, conflict_graph,
                           plan_wave, run_workload_any,
                           run_workload_planned)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_NODES, KPN = 4, 32
N_KEYS = N_NODES * KPN


# ------------------------------------------------------------------ graph
def test_conflict_graph_edges_by_hand():
    # t0 writes 5; t1 reads 5; t2 RMWs 5; t3 touches 9 only; t4 all-NOP
    op_kind = np.array([[WRITE, NOP], [READ, NOP], [RMW, NOP],
                        [READ, WRITE], [NOP, NOP]], np.int32)
    op_key = np.array([[5, 0], [5, 0], [5, 0], [9, 9], [5, 5]], np.int32)
    g = conflict_graph(op_kind, op_key)
    assert g.rw[1, 0] and g.rw[2, 0]        # 1,2 read what 0 writes
    assert g.wr[0, 1] and not g.rw[0, 1]    # 0 reads nothing
    assert g.ww[0, 2] and g.ww[2, 0]        # WRITE vs RMW on key 5
    # t3 reads its own write key — not a conflict with anyone
    assert not g.conflict[3].any()
    # all-NOP row: isolated even though its padded key slots say 5
    assert not g.conflict[4].any() and not g.active[4]
    assert (g.conflict == g.conflict.T).all()
    assert not g.conflict.diagonal().any()


def test_conflict_graph_dense_equals_grouped():
    rng = np.random.RandomState(0)
    for _ in range(30):
        T, O = int(rng.randint(1, 24)), int(rng.randint(1, 6))
        op_kind = rng.randint(0, 4, (T, O)).astype(np.int32)
        op_key = rng.randint(0, 10, (T, O)).astype(np.int32)
        gd = conflict_graph(op_kind, op_key, method="dense")
        gg = conflict_graph(op_kind, op_key, method="grouped")
        np.testing.assert_array_equal(gd.conflict, gg.conflict)
        np.testing.assert_array_equal(gd.rw, gg.rw)
        np.testing.assert_array_equal(gd.ww, gg.ww)


# ------------------------------------------------------------------ lanes
def _assert_plan_invariants(plan: Plan, conflict: np.ndarray,
                            max_lanes=None):
    T = conflict.shape[0]
    # partition: lane union + spill covers every row exactly once
    cover = np.concatenate([*plan.lanes, plan.spill]) if T else np.arange(0)
    assert sorted(cover.tolist()) == list(range(T))
    # lanes pairwise conflict-free
    for lane in plan.lanes:
        assert not conflict[np.ix_(lane, lane)].any()
    # topological: conflicting laned pairs execute in row (tid) order
    lane_of = plan.lane_of
    for i, j in zip(*np.nonzero(np.triu(conflict, 1))):
        if lane_of[i] >= 0 and lane_of[j] >= 0:
            assert lane_of[i] < lane_of[j]
    if max_lanes is None:
        assert plan.n_spilled == 0
    else:
        assert plan.n_lanes <= max_lanes


def test_color_lanes_budget_and_spill():
    # a pure WAW chain of depth 6: one txn per lane, budget 3 spills 3
    op_kind = np.full((6, 1), RMW, np.int32)
    op_key = np.zeros((6, 1), np.int32)
    g = conflict_graph(op_kind, op_key)
    full = color_lanes(g)
    assert full.n_lanes == 6 and full.n_spilled == 0
    _assert_plan_invariants(full, g.conflict)
    bounded = color_lanes(g, max_lanes=3)
    assert bounded.n_lanes == 3 and bounded.n_spilled == 3
    _assert_plan_invariants(bounded, g.conflict, max_lanes=3)
    assert full.conflicted == bounded.conflicted == 6


def test_plan_invariants_random_sweep():
    """Seeded stand-in for the hypothesis property below — always runs,
    even where hypothesis is absent (it skips)."""
    rng = np.random.RandomState(42)
    for _ in range(60):
        T, O = int(rng.randint(1, 24)), int(rng.randint(1, 5))
        op_kind = rng.randint(0, 4, (T, O)).astype(np.int32)
        op_key = rng.randint(0, int(rng.randint(2, 12)), (T, O)).astype(
            np.int32)
        max_lanes = None if rng.rand() < 0.5 else int(rng.randint(1, 6))
        plan = plan_wave(op_kind, op_key, max_lanes=max_lanes)
        g = conflict_graph(op_kind, op_key)
        _assert_plan_invariants(plan, g.conflict, max_lanes)


def test_plan_invariants_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=120, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 24), st.integers(1, 5),
           st.integers(2, 12),
           st.one_of(st.none(), st.integers(1, 6)))
    def check(seed, T, O, n_keys, max_lanes):
        rng = np.random.RandomState(seed)
        op_kind = rng.randint(0, 4, (T, O)).astype(np.int32)
        op_key = rng.randint(0, n_keys, (T, O)).astype(np.int32)
        plan = plan_wave(op_kind, op_key, max_lanes=max_lanes)
        g = conflict_graph(op_kind, op_key)
        _assert_plan_invariants(plan, g.conflict, max_lanes)

    check()


# ------------------------------------------- planned vs sequential oracle
def _oracle_replay(waves):
    """Drive core/seq.py one txn at a time in tid order; return final
    per-key values (the serial baseline everything must commit into)."""
    seq = SeqScheduler(N_KEYS)
    for w in waves:
        op_kind = np.asarray(w.op_kind)
        op_key = np.asarray(w.op_key)
        op_val = np.asarray(w.op_val)
        for t in range(op_kind.shape[0]):
            tid = seq.begin()
            for o in range(op_kind.shape[1]):
                kind, k, v = (int(op_kind[t, o]), int(op_key[t, o]),
                              int(op_val[t, o]))
                if kind == NOP:
                    continue
                if kind == READ:
                    seq.read(tid, k)
                elif kind == WRITE:
                    seq.write(tid, k, v)
                else:
                    seq.write(tid, k, seq.read(tid, k) + v)
            seq.commit(tid)
    return {k: seq.versions[k][-1].value
            for k in range(N_KEYS) if seq.versions[k]}


def _mixed_workload(seed):
    rng = np.random.RandomState(seed)
    waves = ycsb_waves(rng, 2, 12, N_NODES, KPN, theta=0.95, read_frac=0.3,
                      dist_frac=0.2, n_ops=4)
    waves += chain_waves(rng, 2, 12, N_NODES, KPN, chain_len=4, kind="mixed",
                         tid0=1 + 2 * 12)
    return waves


def _assert_matches_oracle(store, history, waves):
    # zero aborts, everything commits
    for tids, out in history:
        assert (out.status == COMMITTED).all()
    # SI-valid history, store consistent with it
    assert verify_si(history) == []
    assert final_values_ok(store, history, N_KEYS) == []
    # final values equal the serial tid-order oracle: planned execution is
    # conflict-equivalent to program order (lanes.py layering argument)
    expect = _oracle_replay(waves)
    val = np.asarray(store.val)
    head = np.asarray(store.head)
    for k, v in expect.items():
        assert int(val[k, head[k]]) == v, f"key {k}"


@pytest.mark.parametrize("base", ["postsi", "cv", "si"])
def test_planned_matches_oracle_local(base):
    waves = _mixed_workload(seed=1)
    store = make_store(N_KEYS, 8)
    store, history, stats = run_workload_planned(
        store, waves, sched=base, n_nodes=N_NODES, kernels="jnp")
    assert stats.aborted == 0 and stats.spilled_txns == 0
    _assert_matches_oracle(store, history, waves)
    if base == "cv":
        assert verify_cv(history) == []


def test_planned_zero_abort_all_base_scheds():
    """WAW chains abort hard optimistically; planned lanes must commit
    them abort-free under every one of the six base schedulers."""
    rng = np.random.RandomState(2)
    waves = chain_waves(rng, 1, 8, N_NODES, KPN, chain_len=4, kind="waw")
    for base in SCHEDULERS:
        store = make_store(N_KEYS, 8)
        _, history, stats = run_workload_planned(
            store, waves, sched=base, n_nodes=N_NODES, kernels="jnp")
        assert stats.aborted == 0, base
        assert stats.committed == 8, base


def test_planned_matches_oracle_pallas_interpret():
    waves = _mixed_workload(seed=3)
    store = make_store(N_KEYS, 8)
    store, history, stats = run_workload_planned(
        store, waves, n_nodes=N_NODES, kernels="pallas_interpret")
    assert stats.aborted == 0
    _assert_matches_oracle(store, history, waves)


def test_planned_spill_partition_and_validity():
    """Bounded lane budget: deep WAW chains overflow into the optimistic
    spill wave — every row still executes exactly once, spilled rows may
    abort, the history stays SI-valid."""
    rng = np.random.RandomState(4)
    waves = chain_waves(rng, 2, 12, N_NODES, KPN, chain_len=6, kind="waw")
    plan = plan_wave(waves[0].op_kind, waves[0].op_key, max_lanes=3)
    assert plan.n_spilled > 0
    store = make_store(N_KEYS, 8)
    store, history, stats = run_workload_planned(
        store, waves, n_nodes=N_NODES, kernels="jnp", max_lanes=3)
    assert stats.spilled_txns > 0
    assert stats.committed + stats.aborted == 24    # exactly once each
    # aborts only among spilled rows
    assert stats.aborted <= stats.spilled_txns
    assert verify_si(history) == []
    assert final_values_ok(store, history, N_KEYS) == []


def test_planned_registry_dispatch():
    assert PLANNED in ALL_SCHEDULERS and len(ALL_SCHEDULERS) == 7
    waves = ycsb_waves(np.random.RandomState(5), 2, 8, N_NODES, KPN,
                       theta=0.9, read_frac=0.5)
    store = make_store(N_KEYS, 8)
    _, _, st_planned = run_workload_any(store, waves, PLANNED,
                                        n_nodes=N_NODES, kernels="jnp")
    assert st_planned.aborted == 0
    store = make_store(N_KEYS, 8)
    _, _, st_opt = run_workload_any(store, waves, "postsi",
                                    n_nodes=N_NODES, kernels="jnp")
    assert st_opt.committed + st_opt.aborted == st_planned.committed
    with pytest.raises(ValueError):
        run_workload_any(make_store(N_KEYS, 8), waves, "nope")


def test_planned_mesh_matches_local():
    """Mesh substrate: same plan, same lanes, bit-identical outcomes to the
    local run, zero aborts (subprocess: device count locks at jax init)."""
    code = r"""
import numpy as np
from repro.core import make_store
from repro.core.dist_engine import make_node_mesh, shard_store
from repro.core.workloads import chain_waves, ycsb_waves
from repro.core.verify import verify_si, final_values_ok
from repro.planner import run_workload_planned

N, KPN = 8, 16
rng = np.random.RandomState(11)
waves = ycsb_waves(rng, 2, 8, N, KPN, theta=0.95, read_frac=0.3,
                   dist_frac=0.2, n_ops=4)
waves += chain_waves(rng, 1, 8, N, KPN, chain_len=4, kind="waw", tid0=17)
mesh = make_node_mesh(8)
store_m = shard_store(make_store(N * KPN, 8), mesh)
store_m, hist_m, st_m = run_workload_planned(
    store_m, waves, n_nodes=N, mesh=mesh, kernels="jnp")
store_l = make_store(N * KPN, 8)
store_l, hist_l, st_l = run_workload_planned(
    store_l, waves, n_nodes=N, kernels="jnp")
assert st_m.aborted == st_l.aborted == 0
for (t1, o1), (t2, o2) in zip(hist_m, hist_l):
    assert (t1 == t2).all()
    for f1, f2 in zip(o1, o2):
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
assert verify_si(hist_m) == []
assert final_values_ok(store_m, hist_m, N * KPN) == []
print("MESH-PLANNED-OK", st_m.committed)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH-PLANNED-OK" in out.stdout


# ----------------------------------------------------------------- hybrid
def test_hybrid_switch_policy_units():
    sw = HybridSwitch(enter_high=0.3, exit_low=0.2, window=10)
    assert not sw.planned
    sw.observe_optimistic(10, 2)           # 0.2 <= 0.3: stay optimistic
    assert not sw.planned
    sw.observe_optimistic(10, 5)           # 0.5 > 0.3: enter planned
    assert sw.planned and sw.to_planned == 1
    sw.observe_planned(10, 8)              # conflict frac 0.8: stay
    assert sw.planned
    sw.observe_planned(10, 1)              # 0.1 < 0.2: exit
    assert not sw.planned and sw.to_optimistic == 1
    assert sw.switches == 2
    pinned = HybridSwitch.from_name("planned")
    assert pinned.planned
    pinned.observe_planned(1000, 0)        # conflict-free forever: stays
    assert pinned.planned
    with pytest.raises(ValueError):
        HybridSwitch.from_name("sometimes")
    with pytest.raises(ValueError):
        HybridSwitch(window=0)


def _hot_gen(rng):
    from repro.service.service import ycsb_txn_gen
    return ycsb_txn_gen(rng, N_NODES, KPN, theta=0.99, read_frac=0.1,
                        n_ops=4)


def test_hybrid_service_switches_and_verifies():
    from repro.service import TxnService
    svc = TxnService(n_keys=N_KEYS, T=16, O=4, sched="postsi",
                     n_nodes=N_NODES, kernels="jnp", planner="hybrid")
    rep = svc.run_stream([8] * 40, _hot_gen(np.random.RandomState(6)))
    assert rep.planned_waves > 0 and rep.planner_switches >= 1
    assert rep.committed + rep.dropped == rep.admitted
    assert svc.verify() == []
    # pinned planned mode: abort-free end to end (no spill at this depth)
    svc2 = TxnService(n_keys=N_KEYS, T=16, O=4, sched="postsi",
                      n_nodes=N_NODES, kernels="jnp", planner="planned")
    rep2 = svc2.run_stream([8] * 20, _hot_gen(np.random.RandomState(7)))
    assert rep2.planned_waves > 0
    assert rep2.retries == rep2.planned_spilled == 0
    assert svc2.verify() == []


def test_hybrid_streaming_driver():
    from repro.service import TxnService
    svc = TxnService(n_keys=N_KEYS, T=16, O=4, sched="postsi",
                     n_nodes=N_NODES, kernels="jnp", planner="hybrid")
    rep = svc.run_streaming([8] * 40, _hot_gen(np.random.RandomState(8)),
                            B=2, K=2)
    assert rep.planned_waves > 0
    assert rep.committed + rep.dropped == rep.admitted
    assert svc.verify() == []
