"""Property-based tests (hypothesis) for the closed-loop service.

The retry-pipeline contract (DESIGN.md §8), over randomized arrival
processes, contention mixes and schedulers:

* **commit-or-drop** — every admitted transaction reaches a terminal state
  within the retry bound: committed (with a latency inside the worst-case
  backoff horizon) or dropped after exactly ``max_attempts`` executions;
  nothing is lost or left in flight after drain.
* **serial-replay equivalence** — the served history (including aborted
  attempts) is snapshot-isolated and the final store state matches a serial
  replay of the committed transactions (``repro.core.verify``).
* **watermark safety** — the GC watermark rule never reclaims a version
  readable by a transaction live at reclamation time, for arbitrary
  sequential interleavings (the randomized twin of
  ``test_gc_watermark.py``).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.workloads import poisson_arrivals
from repro.service import RetryPolicy, TxnService, smallbank_txn_gen

T = 8
N_NODES, KPN = 4, 30


def _run_session(seed: int, sched: str, hot: float, rate: float,
                 max_attempts: int):
    """One closed-loop session; returns (service, report)."""
    rng = np.random.RandomState(seed)
    svc = TxnService(n_keys=N_NODES * KPN, T=T, sched=sched,
                     n_nodes=N_NODES,
                     retry=RetryPolicy(max_attempts=max_attempts),
                     max_queue=2 * T, seed=seed)
    gen = smallbank_txn_gen(rng, N_NODES, KPN, dist_frac=0.3, hot_frac=hot,
                            hot_per_node=2)
    report = svc.run_stream(poisson_arrivals(rng, rate, 8), gen)
    return svc, report


def check_commit_or_drop(seed: int, sched: str, hot: float, rate: float,
                         max_attempts: int) -> None:
    svc, rep = _run_session(seed, sched, hot, rate, max_attempts)
    assert svc.former.pending() == 0                 # fully drained
    assert rep.committed + rep.dropped == rep.admitted
    assert rep.offered == rep.admitted + rep.rejected
    horizon = svc.retry.worst_case_ticks() + svc.tick
    for r in svc.requests:
        assert r.status in ("committed", "dropped", "rejected")
        if r.status == "committed":
            assert 1 <= r.attempts <= max_attempts
            assert 1 <= r.latency <= horizon
        elif r.status == "dropped":
            assert r.attempts == max_attempts        # budget fully spent
    # serial-replay equivalence of the committed history
    assert svc.verify() == [], svc.verify()[:3]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["postsi", "si"]),
       st.floats(0.0, 0.9), st.floats(2.0, 14.0), st.integers(1, 6))
def test_admitted_txns_commit_or_drop(seed, sched, hot, rate, max_attempts):
    check_commit_or_drop(seed, sched, hot, rate, max_attempts)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.3, 0.9))
def test_cv_service_serial_replay(seed, hot):
    svc, rep = _run_session(seed, "cv", hot, 10.0, 4)
    assert rep.committed + rep.dropped == rep.admitted
    assert svc.verify() == []


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 100_000))
def test_watermark_safety_random_interleavings(seed):
    from test_gc_watermark import _drive_with_gc
    _drive_with_gc(seed, n_keys=4, n_slots=3, n_actions=50)
