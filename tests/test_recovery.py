"""Crash-restart differential conformance suite for the durability plane
(DESIGN.md §9), extending the tests/test_streaming.py style.

The claims under test:

* **Recovery is exact**: after a clean shutdown, ``recover()`` (snapshot +
  WAL-suffix replay, and equally full-WAL replay) reconstructs the store,
  version rings, clock, wave index, GC watermark and TID counter
  bit-identically to the live service — for all six schedulers.
* **The retire point is the durability boundary**: an injected mid-stream
  kill leaves a WAL that is a bit-identical *prefix* of the uninterrupted
  run's WAL (pure-kill schedules), and replay through ``engine.run_block``
  reproduces the logged outcomes exactly (``recover`` refuses to serve a
  forked history otherwise).  Blocks in flight at the kill are absent from
  the log: they replay (client resubmission) or drop — never double-commit.
  With ``fsync_every=1`` every *acked* commit is durable (log-before-ack),
  and a kill between log and ack (the durable-but-unacked window) is
  resolved by the resubmission rule: resubmit only what is neither acked
  nor committed in the recovered WAL.
* **Substrate/backend freedom**: the same WAL recovers bit-identically
  through the local engine, the mesh engine (child process, 8 virtual
  devices), and either kernel backend (jnp / pallas_interpret).
* **Watermark rules survive recovery** (paper §IV-B): per WAL record the
  GC clock is monotone non-decreasing and the engine clock strictly
  increases; the recovered watermark equals the live one.

Plus a pinned-seed chaos test (CI runs seeds 11/23/47 via
``REPRO_FAULT_SEED``) and a hypothesis property (slow leg) asserting
commit-exactly-once-or-dropped and watermark monotonicity across random
failure schedules.
"""
import os

import numpy as np
import pytest

from repro.core import COMMITTED, SCHEDULERS
from repro.core.workloads import poisson_arrivals
from repro.durability import (DurabilityManager, RecoveryError, WalError,
                              recover, wal, wal_path)
from repro.durability.snapshot import SnapshotStore
from repro.runtime.faults import Fault, FaultSchedule, InjectedCrash
from repro.service import RetryPolicy, TxnService, ycsb_txn_gen

T, N_NODES, KPN = 8, 4, 16
N_KEYS = N_NODES * KPN
STORE_FIELDS = ("val", "tid", "cid", "sid", "head", "wave")


def _host_skew(sched):
    return (np.round(np.linspace(0, 2, N_NODES)).astype(np.int32)
            if sched == "clocksi" else None)


def _service(d, sched="postsi", fsync_every=1, snapshot_every=None,
             faults=None, kernels=None, seed=0, max_attempts=6,
             max_queue=None):
    mgr = (DurabilityManager(str(d), fsync_every=fsync_every,
                             snapshot_every=snapshot_every)
           if d is not None else None)
    svc = TxnService(n_keys=N_KEYS, T=T, sched=sched, n_nodes=N_NODES,
                     retry=RetryPolicy(max_attempts=max_attempts),
                     host_skew=_host_skew(sched), seed=seed,
                     max_queue=max_queue, kernels=kernels, durability=mgr,
                     faults=faults)
    return svc, mgr


def _serve(svc, mgr, n_ticks=10, rate=6.0, seed=3, B=2, K=2):
    """Serve one YCSB stream; on an injected crash, model the kill (drop
    the unsynced group-commit tail, apply scheduled WAL tears) and report
    it.  Returns True when the session crashed."""
    gen = ycsb_txn_gen(np.random.RandomState(seed + 100), N_NODES, KPN,
                       theta=0.6, read_frac=0.5, dist_frac=0.3)
    arr = poisson_arrivals(np.random.RandomState(seed + 200), rate, n_ticks)
    try:
        svc.run_streaming(arr, gen, B=B, K=K)
    except InjectedCrash:
        mgr.crash()
        svc.faults.mutilate_wal(mgr.wal_path, mgr.crash_synced_bytes)
        return True
    mgr.close()
    return False


def _assert_store_equal(a, b, msg=""):
    for f in STORE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}store.{f}")


def _assert_state_matches_live(st, svc):
    """Recovered state ≡ the live service: store bits + every meta scalar
    the engine resumes from (incl. the GC watermark clock)."""
    _assert_store_equal(st.store, svc.store)
    assert st.clock == int(np.asarray(svc.clock))
    assert st.wave_idx == svc.wave_idx
    assert st.gc_clock == svc.gc.clock
    assert st.next_tid == svc.former.next_tid


def _assert_wal_invariants(blocks):
    """Per-record §IV-B survivals: GC watermark monotone non-decreasing,
    engine clock monotone non-decreasing (the clock is the high-water
    mark of commit timestamps: an all-abort block leaves it unchanged,
    and PostSI's decentralized interval commits may land at c_i <= clk,
    so even a committing block need not advance it), wave indices
    contiguous."""
    prev_gc, prev_clock, next_wave = -1, 0, 1
    for rec in blocks:
        assert rec["gc_clock"] >= prev_gc, "GC watermark went backwards"
        assert rec["clock"] >= prev_clock, "engine clock went backwards"
        assert rec["wave_idx0"] == next_wave, "wave origin not contiguous"
        next_wave = rec["wave_idx0"] + rec["tid"].shape[0]
        prev_gc, prev_clock = rec["gc_clock"], rec["clock"]


def _committed_tids(blocks):
    C = set()
    for rec in blocks:
        C.update(int(t) for t, s in zip(rec["tid"].ravel(),
                                        rec["status"].ravel())
                 if s == COMMITTED)
    return C


_PREFIX_KEYS = ("op_kind", "op_key", "op_val", "host", "tid",
                "status", "s", "c")


def _assert_wal_prefix(crashed_blocks, ref_blocks):
    """Pure-kill conformance: the crashed WAL is a bit-identical prefix of
    the uninterrupted run's WAL — inputs, outcomes, clocks, watermarks."""
    assert len(crashed_blocks) <= len(ref_blocks)
    for i, (a, b) in enumerate(zip(crashed_blocks, ref_blocks)):
        for k in _PREFIX_KEYS:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"block {i} field {k}")
        assert (a["wave_idx0"], a["wm"], a["clock"], a["gc_clock"]) == \
               (b["wave_idx0"], b["wm"], b["clock"], b["gc_clock"]), i


def _restart_exactly_once(d, crashed, sched="postsi", seed=0):
    """The resubmission harness: restart on the recovered directory,
    resubmit exactly the requests that are neither acked nor committed in
    the durable log, drain, and assert every offered request committed
    exactly once across the crash — or ended dropped/rejected."""
    C = _committed_tids(wal.scan(wal_path(str(d))).blocks)
    for r in crashed.requests:
        if r.status == "committed":       # durable-before-ack (fsync=1)
            assert r.tid in C, f"acked commit req {r.req_id} not durable"
    # a burst of resubmissions arrives at once: admission must take it all
    svc2, mgr2 = _service(d, sched, seed=seed, max_queue=10_000)
    resub = {}
    for r in crashed.requests:
        if r.status in ("committed", "dropped", "rejected"):
            continue
        if any(t in C for t in r.tids):
            continue                      # durable-but-unacked: no resubmit
        resub[r.req_id] = svc2.submit(r.op_kind, r.op_key, r.op_val, r.host)
    svc2.drain()
    for r in crashed.requests:
        pre = any(t in C for t in r.tids)
        r2 = resub.get(r.req_id)
        post = r2 is not None and r2.status == "committed"
        assert not (pre and post), f"req {r.req_id} double-committed"
        if r2 is not None:
            assert r2.status in ("committed", "dropped")
        if r.status == "committed":
            assert pre
        if r.status not in ("dropped", "rejected") and r2 is None:
            assert pre                    # skipped resubmit ⇒ already durable
    assert svc2.verify() == []
    mgr2.close()
    return svc2


# ----------------------------------------------- clean-shutdown conformance
@pytest.mark.parametrize("sched", SCHEDULERS)
def test_recover_reconstructs_live_state(sched, tmp_path):
    """All six schedulers: snapshot+suffix replay AND full-WAL replay both
    reconstruct the live store/rings/watermark bit-identically."""
    svc, mgr = _service(tmp_path, sched, snapshot_every=3)
    assert not _serve(svc, mgr)
    assert svc.committed > 0 and mgr.seq > 0
    st = recover(str(tmp_path))
    assert st.snapshot_seq is not None          # the snapshot was exercised
    assert st.n_replayed < st.n_blocks
    _assert_state_matches_live(st, svc)
    full = recover(str(tmp_path), use_snapshot=False)
    assert full.n_replayed == full.n_blocks
    _assert_state_matches_live(full, svc)
    assert len(full.history) == len(svc.history)
    _assert_wal_invariants(wal.scan(wal_path(str(tmp_path))).blocks)


def test_reattach_resumes_and_verifies_across_restart(tmp_path):
    """A fresh service attached to an existing log comes back as the old
    one (store, TID counter, history) and keeps serving verifiably."""
    svc, mgr = _service(tmp_path, "postsi", snapshot_every=4)
    assert not _serve(svc, mgr)
    svc2, mgr2 = _service(tmp_path, "postsi")
    _assert_store_equal(svc.store, svc2.store)
    assert svc2.former.next_tid == svc.former.next_tid
    assert mgr2.last_recovery is not None
    assert not _serve(svc2, mgr2, seed=9)
    assert svc2.committed > 0
    assert svc2.verify() == []          # suffix history + snapshot rings


# ------------------------------------------------- crash-restart conformance
@pytest.mark.parametrize("sched", SCHEDULERS)
def test_crash_restart_prefix_conformance(sched, tmp_path):
    """All six schedulers: a mid-stream kill leaves a WAL that is a
    bit-identical prefix of the uninterrupted run's, replay reproduces the
    logged outcomes (recover's internal determinism check), and the
    watermark rules hold on every surviving record."""
    ref_d, c_d = tmp_path / "ref", tmp_path / "crashed"
    ref, ref_mgr = _service(ref_d, sched)
    assert not _serve(ref, ref_mgr, n_ticks=12)
    faults = FaultSchedule([Fault("kill", "dispatch", 3)])
    svc, mgr = _service(c_d, sched, faults=faults)
    assert _serve(svc, mgr, n_ticks=12)
    st = recover(str(c_d))                       # verify_outcomes=True
    ref_blocks = wal.scan(wal_path(str(ref_d))).blocks
    assert 0 < st.n_blocks < len(ref_blocks)     # genuinely mid-stream
    crashed_blocks = wal.scan(wal_path(str(c_d))).blocks
    _assert_wal_prefix(crashed_blocks, ref_blocks)
    _assert_wal_invariants(crashed_blocks)
    _assert_wal_invariants(ref_blocks)


def test_k_gt_1_inflight_blocks_replay_or_drop(tmp_path):
    """K=3 pipeline killed at a retire: only retired blocks are durable
    (dispatched > durable), and the in-flight blocks' transactions commit
    exactly once via resubmission — never twice, never silently."""
    faults = FaultSchedule([Fault("kill", "retire", 2)])
    svc, mgr = _service(tmp_path, "postsi", faults=faults)
    assert _serve(svc, mgr, n_ticks=12, rate=10.0, K=3)
    n_durable = len(wal.scan(wal_path(str(tmp_path))).blocks)
    assert svc.blocks > n_durable        # blocks were in flight at the kill
    st = recover(str(tmp_path))
    assert st.n_blocks == n_durable
    _restart_exactly_once(tmp_path, svc)


def test_post_log_kill_durable_but_unacked_window(tmp_path):
    """A kill between WAL append and ack: the block's commits are durable
    but its clients never heard — the resubmission rule must skip them
    (their tids are in the recovered log) and nothing double-commits."""
    faults = FaultSchedule([Fault("kill", "post_log", 1)])
    svc, mgr = _service(tmp_path, "postsi", faults=faults)
    assert _serve(svc, mgr, n_ticks=12)
    C = _committed_tids(wal.scan(wal_path(str(tmp_path))).blocks)
    windowed = [r for r in svc.requests
                if r.status not in ("committed", "dropped", "rejected")
                and any(t in C for t in r.tids)]
    assert windowed                      # the window actually opened
    _restart_exactly_once(tmp_path, svc)


def test_torn_wal_tail_absorbed_and_resumed(tmp_path):
    """A partial final write (torn tail) costs at most the at-risk suffix
    behind the last fsync barrier: scan stops at the intact prefix,
    recovery replays it, and a restarted writer truncates the tear so the
    resumed log is clean again.  Group commit (fsync_every>1) is what puts
    appended-but-unfsynced records at risk; at fsync_every=1 the barrier
    trails every append and a crash tear clamps to zero bytes — so this
    test runs the honest acked-but-lost window, and deliberately does NOT
    claim exactly-once (that guarantee belongs to fsync_every=1)."""
    faults = FaultSchedule([Fault("kill", "retire", 3),
                            Fault("torn_tail", "wal", 0, arg=10)])
    svc, mgr = _service(tmp_path, "postsi", fsync_every=4, faults=faults)
    assert _serve(svc, mgr, n_ticks=12)
    p = wal_path(str(tmp_path))
    damaged = wal.scan(p)
    assert damaged.torn_bytes > 0
    # fsync is a barrier: the tear never reaches behind the last fsync
    assert damaged.valid_bytes >= mgr.crash_synced_bytes
    st = recover(str(tmp_path))
    assert st.torn_bytes == damaged.torn_bytes
    assert st.n_blocks == len(damaged.blocks)
    # restart: the writer drops the tear, service resumes, log ends clean
    svc2, mgr2 = _service(tmp_path, "postsi")
    assert not _serve(svc2, mgr2, seed=9)
    final = wal.scan(p)
    assert final.torn_bytes == 0
    assert len(final.blocks) > len(damaged.blocks)
    _assert_wal_invariants(final.blocks)


def test_delayed_retirement_stalls_but_preserves_invariants(tmp_path):
    """The injected straggler (delay_retire) may hold blocks for ticks but
    every invariant — commit-or-drop, durable log shape, verification —
    still holds; the schedule is not pure-kill so no prefix claim."""
    faults = FaultSchedule([Fault("delay_retire", "retire", 0, arg=3)])
    svc, mgr = _service(tmp_path, "postsi", faults=faults)
    assert not _serve(svc, mgr, n_ticks=12)
    assert faults.delays_taken > 0
    assert not faults.pure_kill
    rep = svc.report()
    assert rep.committed + rep.dropped == rep.admitted
    assert svc.verify() == []
    st = recover(str(tmp_path))
    _assert_state_matches_live(st, svc)


# --------------------------------------------------- config & backend planes
def test_config_mismatch_rejected_with_clear_error(tmp_path):
    svc, mgr = _service(tmp_path, "postsi")
    assert not _serve(svc, mgr, n_ticks=4)
    with pytest.raises(WalError, match="sched='postsi' logged vs 'si'"):
        _service(tmp_path, "si")
    with pytest.raises(WalError, match="host_skew"):
        mgr2 = DurabilityManager(str(tmp_path))
        TxnService(n_keys=N_KEYS, T=T, sched="postsi", n_nodes=N_NODES,
                   host_skew=np.arange(N_NODES, dtype=np.int32),
                   durability=mgr2)


def test_wal_replay_equivalent_across_kernel_backends(tmp_path):
    """Satellite: a WAL written under one kernel backend recovers
    bit-identically through the other — replay determinism spans
    REPRO_KERNEL_BACKEND={jnp,pallas_interpret} (PR 4's equivalence,
    now load-bearing for durability)."""
    svc, mgr = _service(tmp_path, "postsi", kernels="jnp")
    assert not _serve(svc, mgr)
    st_jnp = recover(str(tmp_path), kernels="jnp")
    st_pal = recover(str(tmp_path), kernels="pallas_interpret")
    _assert_store_equal(st_jnp.store, st_pal.store, "jnp-vs-pallas ")
    _assert_state_matches_live(st_pal, svc)      # both checked vs logged
    _assert_state_matches_live(st_jnp, svc)


def test_step_loop_sessions_are_durable_too(tmp_path):
    """The per-wave step loop logs B=1 blocks at the same boundary; the
    same recover() covers it."""
    svc, mgr = _service(tmp_path, "si", snapshot_every=5)
    gen = ycsb_txn_gen(np.random.RandomState(7), N_NODES, KPN, theta=0.6)
    svc.run_stream(poisson_arrivals(np.random.RandomState(8), 5.0, 8), gen)
    mgr.close()
    st = recover(str(tmp_path))
    _assert_state_matches_live(st, svc)
    assert all(rec["tid"].shape[0] == 1
               for rec in wal.scan(wal_path(str(tmp_path))).blocks)


# ------------------------------------------------------------ wal unit tests
class TestWal:
    def _fill(self, p, n=4):
        w = wal.WalWriter(str(p))
        w.append(wal.REC_CONFIG, {"format": 1, "sched": "postsi"})
        for i in range(n):
            w.append(wal.REC_BLOCK, {"seq": i,
                                     "x": np.arange(6, dtype=np.int32) + i})
        w.close()

    def test_round_trip(self, tmp_path):
        p = tmp_path / "wal.log"
        self._fill(p)
        s = wal.scan(str(p))
        assert s.config["sched"] == "postsi" and len(s.blocks) == 4
        assert s.torn_bytes == 0 and s.valid_bytes == p.stat().st_size
        np.testing.assert_array_equal(s.blocks[2]["x"],
                                      np.arange(6, dtype=np.int32) + 2)

    def test_missing_file_scans_empty(self, tmp_path):
        s = wal.scan(str(tmp_path / "absent.log"))
        assert s.config is None and s.blocks == [] and s.valid_bytes == 0

    def test_torn_tail_tolerated_and_truncated_on_reopen(self, tmp_path):
        p = tmp_path / "wal.log"
        self._fill(p)
        whole = p.stat().st_size
        assert wal.torn_tail(str(p), 7) == 7
        s = wal.scan(str(p))
        assert len(s.blocks) == 3                 # last record destroyed
        assert s.valid_bytes < whole - 7 and s.torn_bytes > 0
        w = wal.WalWriter(str(p), valid_bytes=s.valid_bytes)
        w.append(wal.REC_BLOCK, {"seq": 3, "x": np.int32(9)})
        w.close()
        s2 = wal.scan(str(p))
        assert len(s2.blocks) == 4 and s2.torn_bytes == 0

    def test_midlog_bitrot_ends_the_trusted_prefix(self, tmp_path):
        p = tmp_path / "wal.log"
        self._fill(p)
        s = wal.scan(str(p))
        data = bytearray(p.read_bytes())
        # flip one payload byte inside the second block record
        off = s.valid_bytes - (s.valid_bytes // 3)
        data[off] ^= 0xFF
        p.write_bytes(bytes(data))
        damaged = wal.scan(str(p))
        assert len(damaged.blocks) < 4 and damaged.torn_bytes > 0

    def test_config_must_head_the_log(self, tmp_path):
        p = tmp_path / "wal.log"
        w = wal.WalWriter(str(p))
        w.append(wal.REC_BLOCK, {"seq": 0})
        w.append(wal.REC_CONFIG, {"format": 1})
        w.close()
        with pytest.raises(WalError, match="CONFIG record not at log head"):
            wal.scan(str(p))

    def test_noncontiguous_seq_rejected(self, tmp_path):
        p = tmp_path / "wal.log"
        w = wal.WalWriter(str(p))
        w.append(wal.REC_BLOCK, {"seq": 0})
        w.append(wal.REC_BLOCK, {"seq": 2})
        w.close()
        with pytest.raises(WalError, match="not a contiguous retire order"):
            wal.scan(str(p))

    def test_fsync_batching_and_simulated_crash(self, tmp_path):
        p = tmp_path / "wal.log"
        w = wal.WalWriter(str(p), fsync_every=3)
        w.append(wal.REC_BLOCK, {"seq": 0})
        w.append(wal.REC_BLOCK, {"seq": 1})
        assert w.unsynced_records == 2           # buffered, not in the OS
        assert len(wal.scan(str(p)).blocks) == 0
        assert w.drop_unsynced() == 2            # the crash loses them
        assert len(wal.scan(str(p)).blocks) == 0
        w2 = wal.WalWriter(str(p), fsync_every=3)
        w2.append(wal.REC_BLOCK, {"seq": 0})
        w2.append(wal.REC_BLOCK, {"seq": 1})
        w2.append(wal.REC_BLOCK, {"seq": 2})     # batch boundary: auto-sync
        assert w2.unsynced_records == 0
        assert len(wal.scan(str(p)).blocks) == 3
        w2.close()

    def test_fsync_barrier_bounds_the_tearable_suffix(self, tmp_path):
        """simulate_crash hands pending frames to the OS unfsynced: they
        are scannable (a gentle crash keeps them) but AT RISK — a torn
        tail may eat them, yet can never reach behind synced_bytes."""
        p = tmp_path / "wal.log"
        w = wal.WalWriter(str(p), fsync_every=4)
        w.append(wal.REC_BLOCK, {"seq": 0})
        w.sync()                                  # explicit barrier
        barrier = w.synced_bytes
        assert barrier == p.stat().st_size
        w.append(wal.REC_BLOCK, {"seq": 1})
        w.append(wal.REC_BLOCK, {"seq": 2})
        assert w.synced_bytes == barrier          # barrier did not move
        assert w.simulate_crash() == 2            # flushed, never fsynced
        assert len(wal.scan(str(p)).blocks) == 3  # gentle crash: all there
        at_risk = p.stat().st_size - barrier
        assert wal.torn_tail(str(p), at_risk) == at_risk
        s = wal.scan(str(p))                      # tear ate both at-risk recs
        assert len(s.blocks) == 1 and s.valid_bytes == barrier
        # with fsync_every=1 every seam leaves the pending buffer empty
        w1 = wal.WalWriter(str(p), fsync_every=1, valid_bytes=s.valid_bytes)
        w1.append(wal.REC_BLOCK, {"seq": 1})
        assert w1.simulate_crash() == 0           # nothing ever at risk

    def test_fsync_every_validated(self, tmp_path):
        with pytest.raises(ValueError, match="fsync_every"):
            wal.WalWriter(str(tmp_path / "w.log"), fsync_every=0)


# ------------------------------------------------------- snapshot unit tests
class TestSnapshots:
    def test_damaged_snapshot_degrades_to_full_replay(self, tmp_path):
        svc, mgr = _service(tmp_path, "postsi", snapshot_every=3)
        assert not _serve(svc, mgr)
        snap_meta = os.path.join(str(tmp_path), SnapshotStore.SUBDIR,
                                 "postsi_meta.pkl")
        with open(snap_meta, "wb") as f:
            f.write(b"rotten")
        st = recover(str(tmp_path))
        assert st.snapshot_seq is None           # fell back, did not die
        assert st.n_replayed == st.n_blocks
        _assert_state_matches_live(st, svc)

    def test_snapshot_ahead_of_wal_is_rejected(self, tmp_path):
        svc, mgr = _service(tmp_path, "postsi")
        assert not _serve(svc, mgr, n_ticks=4)
        snaps = SnapshotStore(str(tmp_path), N_KEYS, svc.store.n_versions)
        snaps.save(svc.store, int(np.asarray(svc.clock)), svc.wave_idx,
                   wal_seq=10_000, gc_clock=svc.gc.clock,
                   next_tid=svc.former.next_tid)
        with pytest.raises(RecoveryError, match="wal_seq=10000"):
            recover(str(tmp_path))

    def test_snapshots_only_at_pipeline_empty_boundaries(self, tmp_path):
        """maybe_snapshot refuses while blocks are in flight — the device
        store would include unretired (undurable) state."""
        mgr = DurabilityManager(str(tmp_path), snapshot_every=1)
        svc = TxnService(n_keys=N_KEYS, T=T, n_nodes=N_NODES,
                         durability=mgr)
        mgr._since_snap = 5
        assert not mgr.maybe_snapshot(svc, pipeline_empty=False)
        assert mgr.maybe_snapshot(svc, pipeline_empty=True)
        assert mgr.snapshots_taken == 1
        mgr.close()


# --------------------------------------------------------------- mesh twin
def test_recovery_mesh_conformance():
    """Mesh substrate (child process, 8 virtual devices): for every
    scheduler the mesh-served WAL recovers bit-identically to the live
    sharded store; the same WAL recovers identically through the LOCAL
    engine (substrate freedom); and a drop_node kill recovers onto a fresh
    mesh — the replacement-node story — leaving a WAL that is a prefix of
    the uninterrupted run's."""
    import test_distribution as td
    print(td._run(r"""
import shutil, tempfile
import numpy as np
from repro.core import SCHEDULERS
from repro.core.dist_engine import make_node_mesh
from repro.core.workloads import poisson_arrivals
from repro.durability import DurabilityManager, recover, wal, wal_path
from repro.runtime.faults import Fault, FaultSchedule, InjectedCrash
from repro.service import RetryPolicy, TxnService, ycsb_txn_gen

n_nodes, kpn, T = 8, 8, 8
mesh = make_node_mesh(n_nodes)
FIELDS = ("val", "tid", "cid", "sid", "head", "wave")

def session(d, sched, mesh_, faults=None, seed=3, n_ticks=6):
    hs = (np.round(np.linspace(0, 2, n_nodes)).astype(np.int32)
          if sched == "clocksi" else None)
    mgr = DurabilityManager(d, fsync_every=1, snapshot_every=3)
    svc = TxnService(n_keys=n_nodes*kpn, T=T, sched=sched, n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=6), host_skew=hs,
                     seed=0, mesh=mesh_, durability=mgr, faults=faults)
    gen = ycsb_txn_gen(np.random.RandomState(seed+100), n_nodes, kpn,
                       theta=0.6, read_frac=0.5)
    arr = poisson_arrivals(np.random.RandomState(seed+200), 0.8*T, n_ticks)
    try:
        svc.run_streaming(arr, gen, B=2, K=2)
    except InjectedCrash:
        mgr.crash()
        faults.mutilate_wal(mgr.wal_path, mgr.crash_synced_bytes)
        return svc, mgr, True
    mgr.close()
    return svc, mgr, False

def same_store(a, b, msg):
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=msg + f)

def same_meta(st, svc):
    assert st.clock == int(np.asarray(svc.clock))
    assert st.wave_idx == svc.wave_idx
    assert st.gc_clock == svc.gc.clock
    assert st.next_tid == svc.former.next_tid

for sched in SCHEDULERS:
    d = tempfile.mkdtemp()
    svc, mgr, crashed = session(d, sched, mesh)
    assert not crashed and svc.committed > 0
    st = recover(d, mesh=make_node_mesh(n_nodes))   # fresh mesh
    same_store(st.store, svc.store, sched + " mesh-recover ")
    same_meta(st, svc)
    st_local = recover(d)                           # local engine, same WAL
    same_store(st_local.store, st.store, sched + " local-vs-mesh ")
    same_meta(st_local, svc)
    shutil.rmtree(d)
    print("MESH-RECOVER-OK", sched, st.n_blocks)

# drop_node crash: prefix conformance + recovery onto a replacement mesh
ref_d, c_d = tempfile.mkdtemp(), tempfile.mkdtemp()
ref, ref_mgr, crashed = session(ref_d, "postsi", mesh, n_ticks=10)
assert not crashed
faults = FaultSchedule([Fault("drop_node", "retire", 3)])
svc, mgr, crashed = session(c_d, "postsi", mesh, faults=faults, n_ticks=10)
assert crashed
ref_blocks = wal.scan(wal_path(ref_d)).blocks
c_blocks = wal.scan(wal_path(c_d)).blocks
assert 0 < len(c_blocks) < len(ref_blocks)
for i, (a, b) in enumerate(zip(c_blocks, ref_blocks)):
    for k in ("op_kind", "op_key", "op_val", "host", "tid",
              "status", "s", "c"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{i}:{k}")
    assert (a["clock"], a["gc_clock"]) == (b["clock"], b["gc_clock"])
st = recover(c_d, mesh=make_node_mesh(n_nodes))     # replacement mesh
assert st.n_blocks == len(c_blocks)
# reattach on the replacement mesh and keep serving
mgr2 = DurabilityManager(c_d, fsync_every=1)
svc2 = TxnService(n_keys=n_nodes*kpn, T=T, sched="postsi", n_nodes=n_nodes,
                  retry=RetryPolicy(max_attempts=6), seed=0,
                  mesh=make_node_mesh(n_nodes), durability=mgr2)
gen = ycsb_txn_gen(np.random.RandomState(999), n_nodes, kpn, theta=0.6)
svc2.run_streaming([4]*4, gen, B=2, K=2)
assert svc2.verify() == []
mgr2.close()
shutil.rmtree(ref_d); shutil.rmtree(c_d)
print("MESH-DROPNODE-OK", len(c_blocks), "of", len(ref_blocks))
"""))


# ------------------------------------------------------- chaos (pinned seed)
def test_chaos_pinned_failure_schedule(tmp_path):
    """CI chaos leg: REPRO_FAULT_SEED ∈ {11, 23, 47} selects a pinned
    random failure schedule; whatever it injects, the durable log keeps
    the watermark rules, recovery replays it exactly, and the resubmission
    harness commits everything exactly once or drops it.  Pure-kill
    schedules additionally satisfy the prefix property."""
    seed = int(os.environ.get("REPRO_FAULT_SEED", "11"))
    ref_d, c_d = tmp_path / "ref", tmp_path / "chaos"
    ref, ref_mgr = _service(ref_d, "postsi", snapshot_every=4)
    assert not _serve(ref, ref_mgr, n_ticks=12)
    faults = FaultSchedule.random(seed)
    svc, mgr = _service(c_d, "postsi", snapshot_every=4, faults=faults)
    crashed = _serve(svc, mgr, n_ticks=12)
    blocks = wal.scan(wal_path(str(c_d))).blocks
    _assert_wal_invariants(blocks)
    st = recover(str(c_d))                       # replay determinism check
    assert st.n_blocks == len(blocks)
    if crashed:
        if faults.pure_kill:
            _assert_wal_prefix(blocks,
                               wal.scan(wal_path(str(ref_d))).blocks)
        _restart_exactly_once(c_d, svc)
    else:
        _assert_state_matches_live(st, svc)
        assert svc.verify() == []


# ------------------------------------------------- hypothesis (slow leg)
def _recovery_property_case(seed, snapshot_every, shape):
    """One property instance: commit-exactly-once-or-dropped holds across
    the crash (durable-before-ack, WAL-deduped resubmission), the GC
    watermark and engine clock are monotone over every durable record,
    replay reproduces the log, and pure-kill schedules leave a
    bit-identical prefix of the uninterrupted run's WAL."""
    import shutil
    import tempfile
    B, K = shape
    ref_d, c_d = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        ref, ref_mgr = _service(ref_d, "postsi",
                                snapshot_every=snapshot_every)
        assert not _serve(ref, ref_mgr, n_ticks=10, seed=seed, B=B, K=K)
        faults = FaultSchedule.random(seed)
        svc, mgr = _service(c_d, "postsi", snapshot_every=snapshot_every,
                            faults=faults)
        crashed = _serve(svc, mgr, n_ticks=10, seed=seed, B=B, K=K)
        blocks = wal.scan(wal_path(str(c_d))).blocks
        _assert_wal_invariants(blocks)
        recover(str(c_d))                        # raises on any divergence
        if crashed:
            if faults.pure_kill:
                _assert_wal_prefix(
                    blocks, wal.scan(wal_path(str(ref_d))).blocks)
            _restart_exactly_once(c_d, svc)
        else:
            rep = svc.report()
            assert rep.committed + rep.dropped == rep.admitted
            assert svc.verify() == []
    finally:
        shutil.rmtree(ref_d, ignore_errors=True)
        shutil.rmtree(c_d, ignore_errors=True)


@pytest.mark.slow
def test_recovery_property_exactly_once_and_monotone_watermark():
    """Random failure schedules × random streams (see
    _recovery_property_case for the property).  Hypothesis-driven where
    available; otherwise a pinned pseudo-random sweep of the same property
    — the image may not ship hypothesis, and the guarantee must not be
    skippable with it."""
    try:
        from hypothesis import given, settings, strategies as st_
    except ImportError:
        shapes = [(1, 1), (2, 2), (2, 3)]
        for i, seed in enumerate((11, 23, 47, 1009, 4099, 9001)):
            _recovery_property_case(seed, 2 + seed % 4, shapes[i % 3])
        return

    @settings(max_examples=8, deadline=None)
    @given(st_.integers(0, 10_000), st_.integers(2, 5),
           st_.sampled_from([(1, 1), (2, 2), (2, 3)]))
    def run(seed, snapshot_every, shape):
        _recovery_property_case(seed, snapshot_every, shape)

    run()
