"""Unit tests for the loop-aware HLO analyzer (the roofline numerator)."""
import textwrap

from repro.launch.hlo_analysis import analyze, parse_computations

SYNTHETIC = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[4,4]<=[16], to_apply=%add
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %limit = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %limit), direction=LT
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
      %arg = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %arg)
      %w2 = f32[16,4]{1,0} constant({...})
      %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
      %res = f32[8,16]{1,0} get-tuple-element(%loop), index=1
      %dot.2 = f32[8,4]{1,0} dot(%res, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[8,16]{1,0} all-gather(%dot.2), replica_groups=[4,4]<=[16], dimensions={1}
      ROOT %out = f32[8,16]{1,0} add(%res, %ag)
    }
""")


def test_trip_count_multiplication():
    r = analyze(SYNTHETIC, 16)
    # in-loop dot: 2*8*16*16 = 4096 flops x 12 trips; top-level: 2*8*4*16 = 1024
    assert r["flops"] == 12 * 4096 + 1024
    # all-reduce: 2x result (8*16*4 bytes) x 12 trips; all-gather: result once
    assert r["collective_bytes"] == 12 * 2 * 512 + 512
    assert r["collective_count"] == {"all-reduce": 12, "all-gather": 1}


def test_computation_parse():
    comps, types = parse_computations(SYNTHETIC)
    assert "body" in comps and "cond" in comps
    assert len(comps["__entry__"]) > 0
    assert types["body"]["dot.1"].startswith("f32[8,16]")
