"""Fused-executor and shared-commit-phase tests (DESIGN.md §7).

* the Pallas anti-dependency kernel (interpret=True on CPU) against the
  jnp oracle ``kernels.ref.potential_matrix_ref`` (the only jnp copy of the
  build) on randomized key sets, including all-NOP rows and the diagonal
  mask;
* the single-dispatch lax.scan executor against the per-wave debug driver:
  bit-identical WaveOut history over a multi-wave SmallBank workload for
  every scheduler.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (SCHEDULERS, make_store, run_workload,
                        run_workload_fused)
from repro.core.commit_phase import build_potential
from repro.core.workloads import smallbank_waves
from repro.kernels.interval_negotiate import potential_matrix_pallas
from repro.kernels.ref import potential_matrix_ref


def _oracle(keys, is_r, is_w):
    """bool oracle with the engine's mask convention."""
    return np.asarray(potential_matrix_ref(
        jnp.where(is_r, keys, -1), jnp.where(is_w, keys, -1))).astype(bool)


# ------------------------------------------------------- potential matrix
@pytest.mark.parametrize("T,O,n_keys", [(16, 4, 8), (64, 4, 30), (128, 8, 200)])
def test_potential_pallas_vs_engine_reference(T, O, n_keys):
    """Kernel (interpret) == dense [T,T,O,O] reference, with NOP masking."""
    rng = np.random.RandomState(42)
    keys = jnp.asarray(rng.randint(0, n_keys, (T, O)), jnp.int32)
    is_r = jnp.asarray(rng.rand(T, O) < 0.5)
    is_w = jnp.asarray(rng.rand(T, O) < 0.4)
    # a few all-NOP transactions (neither read nor write anything)
    nop_rows = rng.choice(T, size=max(1, T // 8), replace=False)
    is_r = is_r.at[nop_rows].set(False)
    is_w = is_w.at[nop_rows].set(False)

    ref = _oracle(keys, is_r, is_w)
    rk = jnp.where(is_r, keys, -1)
    wk = jnp.where(is_w, keys, -1)
    krn = np.asarray(potential_matrix_pallas(rk, wk, block_t=T // 2,
                                             interpret=True)).astype(bool)
    np.testing.assert_array_equal(ref, krn)
    assert not krn[nop_rows].any() and not krn[:, nop_rows].any()
    assert not np.diagonal(krn).any()      # diagonal masked even on self-hits


def test_build_potential_backends_agree():
    """The config escape hatch: jnp and pallas_interpret routes are
    bit-identical (int8 kernel output cast back to bool)."""
    rng = np.random.RandomState(3)
    T, O = 24, 4                           # T not a multiple of the block
    keys = jnp.asarray(rng.randint(0, 12, (T, O)), jnp.int32)
    is_r = jnp.asarray(rng.rand(T, O) < 0.6)
    is_w = jnp.asarray(rng.rand(T, O) < 0.6)
    a = np.asarray(build_potential(keys, is_r, is_w, backend="jnp"))
    b = np.asarray(build_potential(keys, is_r, is_w,
                                   backend="pallas_interpret"))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, _oracle(keys, is_r, is_w))


# ------------------------------------------------- fused scan vs per-wave
@pytest.mark.parametrize("sched", SCHEDULERS)
def test_fused_executor_bit_identical(sched):
    """>= 8-wave SmallBank run: one lax.scan dispatch == W per-wave
    dispatches, field for field."""
    rng = np.random.RandomState(0)
    n_nodes, kpn, n_waves, T = 4, 60, 8, 16
    waves = smallbank_waves(rng, n_waves, T, n_nodes, kpn, dist_frac=0.5,
                            hot_frac=0.4, hot_per_node=4)
    hs = np.array([0, 1, 1, 2], np.int32) if sched == "clocksi" else None
    st1, h1, s1 = run_workload(make_store(n_nodes * kpn, 8), waves,
                               sched=sched, n_nodes=n_nodes, host_skew=hs)
    st2, h2, s2 = run_workload_fused(make_store(n_nodes * kpn, 8), waves,
                                     sched=sched, n_nodes=n_nodes,
                                     host_skew=hs)
    assert s1 == s2
    assert len(h1) == len(h2) == n_waves
    for (t1, o1), (t2, o2) in zip(h1, h2):
        np.testing.assert_array_equal(t1, t2)
        for name, f1, f2 in zip(o1._fields, o1, o2):
            np.testing.assert_array_equal(f1, f2, err_msg=f"{sched}.{name}")
    for f1, f2 in zip(st1, st2):           # final stores agree too
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    assert s1.committed + s1.aborted == n_waves * T
