"""Closed-loop service tests (DESIGN.md §8): wave former, retry pipeline,
end-to-end loop.

Deterministic coverage for the new subsystem: admission control and
fixed-shape packing, the bounded-exponential backoff schedule, and full
closed-loop sessions where every admitted transaction terminates
(committed or dropped), aborted transactions retry under fresh TIDs and
eventually commit, and the served history verifies as snapshot-isolated
with the final store matching a serial replay (``repro.core.verify``).
The hypothesis generalization lives in ``test_service_properties.py``.
"""
import numpy as np
import pytest

from repro.core import COMMITTED, NOP
from repro.core.workloads import poisson_arrivals, bursty_arrivals
from repro.service import (RetryPolicy, TxnRequest, TxnService, WaveFormer,
                           smallbank_txn_gen)

T, O = 16, 4
N_NODES, KPN = 4, 40


def _req(i, key=0, host=0):
    op_kind = np.zeros(O, np.int32)
    op_kind[0] = 3                      # RMW
    op_key = np.full(O, key, np.int32)
    return TxnRequest(i, op_kind, op_key, np.ones(O, np.int32), host)


# ---------------------------------------------------------------- former
def test_former_packs_and_pads():
    f = WaveFormer(T, O)
    reqs = [_req(i, key=i) for i in range(5)]
    for r in reqs:
        assert f.offer(r, tick=1)
    wave, slots = f.form(tick=1)
    assert len(slots) == 5
    assert wave.op_kind.shape == (T, O)
    # padding rows are all-NOP and burn contiguous TIDs
    assert (np.asarray(wave.op_kind[5:]) == NOP).all()
    np.testing.assert_array_equal(np.asarray(wave.tid),
                                  1 + np.arange(T))
    assert all(r.tid == 1 + i for i, r in enumerate(slots))
    assert f.form(tick=2) is None        # queue drained


def test_former_admission_sheds_overflow():
    f = WaveFormer(T, O, max_queue=3)
    outcomes = [f.offer(_req(i), tick=1) for i in range(5)]
    assert outcomes == [True] * 3 + [False] * 2
    assert f.rejected == 2 and f.admitted == 3


def test_former_retries_have_priority_and_respect_backoff():
    f = WaveFormer(2, O)
    fresh = [_req(i) for i in range(3)]
    for r in fresh:
        f.offer(r, tick=1)
    late = _req(99)
    soon = _req(98)
    f.requeue(late, eligible_tick=9)     # not due yet
    f.requeue(soon, eligible_tick=2)     # due at tick 2
    wave, slots = f.form(tick=2)
    assert slots[0] is soon              # due retry outranks fresh arrivals
    assert slots[1] is fresh[0]
    assert f.backlog(2) == 2 and f.pending() == 3
    wave, slots = f.form(tick=9)
    assert late in slots                 # calendar releases it when due
    # retries get a FRESH tid on every execution
    assert soon.tid != late.tid and soon.tid > 0


# ----------------------------------------------------------------- retry
def test_backoff_schedule_bounded():
    p = RetryPolicy(max_attempts=5, base_backoff=2, max_backoff=8,
                    jitter=False)
    delays = [p.next_delay(a) for a in range(1, 6)]
    assert delays == [2, 4, 8, 8, None]      # doubled, capped, then dropped
    assert p.worst_case_ticks() >= sum(d for d in delays if d)


def test_backoff_jitter_stays_positive():
    p = RetryPolicy(max_attempts=9, base_backoff=1, max_backoff=4)
    rng = np.random.RandomState(0)
    for a in range(1, 9):
        for _ in range(20):
            d = p.next_delay(a, rng)
            assert d is not None and 1 <= d <= 5


# ------------------------------------------------------------ closed loop
def test_closed_loop_contended_stream_commits_or_drops():
    """Hot SmallBank stream: aborts happen, retries drive them to commit,
    every admitted request reaches a terminal state, history verifies."""
    svc = TxnService(n_keys=N_NODES * KPN, T=T, sched="postsi",
                     n_nodes=N_NODES, retry=RetryPolicy(max_attempts=6),
                     seed=3)
    gen = smallbank_txn_gen(np.random.RandomState(7), N_NODES, KPN,
                            dist_frac=0.3, hot_frac=0.7, hot_per_node=2)
    rep = svc.run_stream(poisson_arrivals(np.random.RandomState(8), 12.0, 12),
                         gen)
    assert rep.admitted > 50
    assert rep.retries > 0                       # contention really retried
    assert rep.committed > 0 and rep.goodput_tps > 0
    assert rep.committed + rep.dropped == rep.admitted
    for r in svc.requests:
        assert r.status in ("committed", "dropped", "rejected")
        if r.status == "committed":
            assert 1 <= r.latency <= svc.retry.worst_case_ticks() + 12
    assert svc.verify() == []
    assert rep.evicted_visible == 0              # V=8 respects the watermark


def test_closed_loop_retry_commits_after_abort():
    """Two same-key RMWs in one wave: one aborts (lost update), the retry
    pipeline re-runs it with a fresh TID and it commits."""
    svc = TxnService(n_keys=N_NODES * KPN, T=T, sched="postsi",
                     n_nodes=N_NODES,
                     retry=RetryPolicy(max_attempts=4, jitter=False))
    op_kind = np.zeros(O, np.int32)
    op_kind[0] = 3                       # RMW
    op_key = np.full(O, 5, np.int32)
    op_val = np.ones(O, np.int32)
    r1 = svc.submit(op_kind, op_key, op_val, 0)
    r2 = svc.submit(op_kind, op_key, op_val, 0)
    svc.step()
    assert {r1.status, r2.status} == {"committed", "queued"}
    first_tids = (r1.tid, r2.tid)
    svc.drain()
    assert r1.status == r2.status == "committed"
    loser = r1 if r1.commit_tick > r2.commit_tick else r2
    assert loser.attempts == 2                   # one abort, one commit
    assert loser.tid not in first_tids or loser.tid > min(first_tids)
    assert svc.verify() == []


def test_closed_loop_bursty_sheds_but_serves():
    svc = TxnService(n_keys=N_NODES * KPN, T=T, sched="cv", n_nodes=N_NODES,
                     max_queue=2 * T, seed=5)
    gen = smallbank_txn_gen(np.random.RandomState(11), N_NODES, KPN,
                            hot_frac=0.4, hot_per_node=4)
    arrivals = bursty_arrivals(np.random.RandomState(12), 10.0, 15,
                               burst_factor=8.0)
    rep = svc.run_stream(arrivals, gen)
    assert rep.offered == rep.admitted + rep.rejected
    assert rep.committed + rep.dropped == rep.admitted
    assert rep.committed > 0
    assert svc.verify() == []


def test_service_gc_block_small_ring():
    """With a too-small ring and blind writes, gc_block turns would-be
    corruptions into aborts: the eviction counter stays 0 and the retry
    pipeline still lands commits."""
    rng = np.random.RandomState(9)

    def blind_gen():
        host = int(rng.randint(0, N_NODES))
        op_kind = np.zeros(O, np.int32)
        op_key = np.zeros(O, np.int32)
        op_val = np.zeros(O, np.int32)
        op_kind[:2] = 2                  # two blind writes on 4 hot keys
        ks = rng.choice(4, size=2, replace=False)
        op_key[:2] = ks * N_NODES + host
        op_val[:2] = rng.randint(1, 10, 2)
        return op_kind, op_key, op_val, host

    svc = TxnService(n_keys=N_NODES * KPN, n_versions=2, T=T, sched="postsi",
                     n_nodes=N_NODES, gc_block=True,
                     retry=RetryPolicy(max_attempts=8), seed=13)
    rep = svc.run_stream([T] * 6, blind_gen)
    assert rep.evicted_visible == 0
    assert rep.committed > 0
    assert rep.committed + rep.dropped == rep.admitted
