"""Hypothesis property suite for the multi-tenant WaveFormer (DESIGN.md
§12) — the satellite sweep over packing invariants: contiguous per-wave
TIDs under adaptive-T resizing, retry-before-fresh priority within a
tenant, DRR quota conservation (no backlogged tenant starves, weights
respected over any window), and exactly-once fold/fan-out for batched
RMWs.  Skips cleanly when hypothesis is absent (CI installs it via
requirements-dev.txt), like tests/test_service_properties.py.
"""
import numpy as np
import pytest

from repro.core.commit_phase import NOP, RMW
from repro.service import (RetryPolicy, TxnRequest, TxnService, WaveFormer,
                           rmw_txn_gen)

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

O = 4


def _req(rid, key=0, kind=RMW, val=1, tenant=0, host=0):
    op_kind = np.full(O, NOP, np.int32)
    op_key = np.zeros(O, np.int32)
    op_val = np.zeros(O, np.int32)
    op_kind[0] = kind
    op_key[0] = key
    op_val[0] = val
    return TxnRequest(rid, op_kind, op_key, op_val, host, tenant=tenant)


def _final_vals(svc, n_keys):
    head = np.asarray(svc.store.head)
    val = np.asarray(svc.store.val)
    return [int(val[k, head[k]]) for k in range(n_keys)]


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_former_packing_properties(data):
    """Property sweep over tenant mixes, adaptive-T resizing and folding:
    per-wave TIDs stay contiguous, nothing is packed twice, a packed fresh
    arrival implies no due retry of the same tenant was left behind, and
    every admitted request is either packed exactly once or still queued."""
    n_tenants = data.draw(st.integers(1, 3), label="tenants")
    weights = {t: data.draw(st.floats(0.5, 4.0), label=f"w{t}")
               for t in range(n_tenants)}
    fold = data.draw(st.booleans(), label="fold")
    f = WaveFormer(8, O, max_queue=64, tenants=weights, fold_rmw=fold)
    rid = 0
    packed = set()
    admitted = set()
    for tick in range(1, data.draw(st.integers(2, 5), label="ticks") + 1):
        for _ in range(data.draw(st.integers(0, 12), label=f"arr{tick}")):
            rid += 1
            r = _req(rid, key=data.draw(st.integers(0, 3)),
                     tenant=data.draw(st.integers(0, n_tenants - 1)))
            if f.offer(r, tick):
                admitted.add(rid)
        T = data.draw(st.sampled_from([4, 8, 16]), label=f"T{tick}")
        formed = f.form(tick, T=T)
        if formed is None:
            continue
        wave, slots = formed
        np.testing.assert_array_equal(np.asarray(wave.tid),
                                      wave.tid[0] + np.arange(T))
        fresh_tenants = set()
        for s in slots:
            for r in (s, *s.folded):
                assert r.req_id not in packed, "packed twice"
                packed.add(r.req_id)
                if r.attempts == 1:
                    fresh_tenants.add(r.tenant)
        # retry-before-fresh within a tenant: a packed fresh arrival means
        # that tenant has no due retry left un-packed
        for t in fresh_tenants:
            q = f._tenants[t]
            assert not (q.retry and q.retry[0][0] <= tick), \
                "fresh packed over a due retry"
    assert packed <= admitted
    assert len(admitted - packed) == f.pending()


@settings(max_examples=25, deadline=None)
@given(weights=st.lists(st.floats(0.25, 4.0), min_size=2, max_size=4),
       n_waves=st.integers(2, 6))
def test_drr_quota_conservation(weights, n_waves):
    """Saturated tenants each collect at least their banked weighted quota
    over any window (DRR bound: shortfall < one slot per tenant), and
    every wave stays full (work conservation)."""
    T = 16
    wmap = {t: w for t, w in enumerate(weights)}
    f = WaveFormer(T, O, max_queue=10_000, tenants=wmap)
    rid = 0
    for t in wmap:
        for _ in range(n_waves * T + T):
            rid += 1
            f.offer(_req(rid, key=rid % 7, tenant=t), 0)
    counts = dict.fromkeys(wmap, 0)
    for w in range(n_waves):
        _, slots = f.form(w + 1)
        assert len(slots) == T
        for s in slots:
            counts[s.tenant] += 1
    w_sum = sum(weights)
    for t, w in wmap.items():
        floor = int(np.floor(n_waves * T * w / w_sum)) - len(weights)
        assert counts[t] >= max(floor, 1), (counts, weights)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), theta=st.sampled_from([0.6, 0.99]))
def test_fold_fanout_exactly_once_property(seed, theta):
    """Random write-hot streams, fold on: terminal-exactly-once + per-key
    delta conservation + clean verify, any seed."""
    n_keys = 24
    gen = rmw_txn_gen(np.random.RandomState(seed), 2, n_keys // 2,
                      theta=theta)
    svc = TxnService(n_keys, T=8, n_nodes=2, fold_rmw=True, max_queue=10_000,
                     retry=RetryPolicy(max_attempts=30, jitter=False),
                     seed=seed % 97)
    svc.run_stream([4] * 6, gen)
    assert svc.verify() == [], svc.verify()
    rep = svc.report()
    terminal = [r for r in svc.requests
                if r.status in ("committed", "dropped")]
    assert len(terminal) == rep.admitted
    assert len(svc.latencies) == rep.committed
    sums = np.zeros(n_keys, np.int64)
    for r in svc.requests:
        if r.status == "committed":
            sums[int(r.op_key[0])] += int(r.op_val[0])
    assert sums.tolist() == _final_vals(svc, n_keys)
