"""Distribution-layer tests.

These need more than one XLA device, and the device count is locked at jax
init — so each test runs a child python with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  Smoke tests and
benches keep seeing 1 device (per the assignment).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mini_dryrun_lower_compile_8dev():
    """Reduced config lowers + compiles on a (2,2,2) pod/data/model mesh;
    memory & cost analysis available; collectives present."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.launch.train import make_train_step, abstract_train_state
from repro.launch.inputs import _train_batch
from repro.launch.sharding import input_shardings
from repro.models.module import use_mesh_and_rules, param_shardings
from repro.optim import adamw_init
from repro.optim.adamw import AdamWState

cfg = get_reduced("qwen3-14b")
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2,2,2), ("pod","data","model"))
with use_mesh_and_rules(mesh):
    model, params, opt = abstract_train_state(cfg)
    _, step = make_train_step(cfg)
    p_sh = param_shardings(model.param_specs(), mesh)
    o_sh = AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
    batch = _train_batch(cfg, 8, 64, True)
    b_sh = input_shardings(batch, mesh)
    low = jax.jit(step, in_shardings=(p_sh,o_sh,b_sh),
                  out_shardings=(p_sh,o_sh,None),
                  donate_argnums=(0,1)).lower(params, opt, batch)
    comp = low.compile()
txt = comp.as_text()
assert "all-reduce" in txt or "all-gather" in txt
from repro.launch.hlo_analysis import analyze
r = analyze(txt, 8)
assert r["flops"] > 0 and r["collective_bytes"] > 0
print("MINI-DRYRUN-OK", int(r["flops"]), int(r["collective_bytes"]))
"""))


def test_real_execution_on_mesh_matches_single_device():
    """The same train step executed (a) on 1 device and (b) SPMD on a (2,2)
    mesh gives the same loss — numerics of the distribution layer."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.launch.train import make_train_step
from repro.launch.inputs import make_batch
from repro.launch.sharding import input_shardings
from repro.models.module import use_mesh_and_rules, param_shardings
from repro.optim import adamw_init
from repro.optim.adamw import AdamWState

cfg = get_reduced("yi-9b")
model, step = make_train_step(cfg, lr=1e-3)
params = model.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = make_batch(cfg, 4, 32, "train")
_,_, m1 = jax.jit(step)(params, opt, batch)

mesh = Mesh(np.array(jax.devices()[:4]).reshape(2,2), ("data","model"))
with use_mesh_and_rules(mesh):
    p_sh = param_shardings(model.param_specs(), mesh)
    o_sh = AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
    b_sh = input_shardings(batch, mesh)
    pd = jax.device_put(params, p_sh)
    od = jax.device_put(opt, o_sh)
    bd = jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), batch, b_sh)
    _,_, m2 = jax.jit(step, in_shardings=(p_sh,o_sh,b_sh),
                      out_shardings=(p_sh,o_sh,None))(pd, od, bd)
d = abs(float(m1['loss']) - float(m2['loss']))
assert d < 1e-2, (float(m1['loss']), float(m2['loss']))
print("SPMD-EXEC-OK", float(m1['loss']), float(m2['loss']))
"""))


def test_compressed_psum_and_elastic_reshard():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp, functools
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_psum

mesh = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("pod",))
x = jnp.asarray(np.random.RandomState(0).randn(4, 64), jnp.float32)

@functools.partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                   check_rep=False)
def f(xs):
    total, err = compressed_psum(xs, "pod")
    return total

out = f(x)
exact = x.sum(axis=0, keepdims=True)
rel = float(jnp.abs(out[0] - exact[0]).max() / jnp.abs(exact).max())
assert rel < 0.02, rel
print("COMPRESSED-PSUM-OK rel", rel)

# elastic reshard: state saved on a (2,2) mesh restores onto a (4,) mesh
from repro.checkpoint import PostSICheckpointer, reshard_tree
import tempfile
m1 = Mesh(np.array(jax.devices()[:4]).reshape(2,2), ("data","model"))
m2 = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("data",))
tree = {"w": jax.device_put(jnp.arange(16.0).reshape(4,4),
                            NamedSharding(m1, P("data","model")))}
with tempfile.TemporaryDirectory() as d:
    ck = PostSICheckpointer(d, tree)
    assert ck.save(1, tree)
    sh2 = {"w": NamedSharding(m2, P("data", None))}
    step, out = ck.restore(tree, sh2)
assert step == 1
np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(16.0).reshape(4,4))
assert out["w"].sharding.spec == P("data", None)
print("ELASTIC-RESHARD-OK")
"""))


def test_mesh_misconfiguration_rejected_in_parent():
    """Cheap in-process check (this process sees exactly 1 CPU device):
    asking for more mesh nodes than devices is a clear ValueError, never a
    silently under-provisioned mesh."""
    import jax

    from repro.core.dist_engine import make_node_mesh

    with pytest.raises(ValueError, match="device"):
        make_node_mesh(len(jax.devices()) + 1)


def test_dist_engine_all_schedulers_match_single_device():
    """The substrate-unified mesh engine (peer collectives, no coordinator,
    ONE commit loop shared with engine.py) commits the exact same
    transactions with the exact same induced intervals as the single-device
    engine — for ALL SIX schedulers, on both the per-wave and the fused
    lax.scan-under-shard_map paths, including the GC accounting — and the
    misconfiguration guards raise instead of silently mis-sharding."""
    print(_run(r"""
import numpy as np, jax
from repro.core import SCHEDULERS, make_store, run_workload, run_workload_fused
from repro.core.dist_engine import (make_node_mesh, run_workload_dist,
                                    run_workload_fused_dist, shard_store)
from repro.core.workloads import smallbank_waves

n_nodes, kpn, W, T = 8, 32, 2, 16
mesh = make_node_mesh(n_nodes)

# misconfiguration guard: an under-provisioned mesh is a loud error
try:
    make_node_mesh(9); raise AssertionError("expected ValueError (9 > 8)")
except ValueError: pass
# non-dividing key spaces PAD with empty rows instead of erroring
# (elastic-plane satellite): 100 keys on 8 nodes -> 104 physical rows,
# the 4 pad rows empty (tid == NO_TID), and a workload over the 100 real
# keys is bit-identical to the single-device run on the unpadded store
pad = shard_store(make_store(100, 4), mesh)
assert pad.head.shape[0] == 104, pad.head.shape
assert (np.asarray(pad.tid)[100:] == -1).all()
pw = smallbank_waves(np.random.RandomState(3), 2, 16, 4, 25,
                     dist_frac=0.5, hot_frac=0.5, hot_per_node=4)
pl_st, pl_h, pl_s = run_workload(make_store(100, 4), pw, sched="postsi",
                                 n_nodes=4)
pd_st, pd_h, pd_s = run_workload_dist(pad, pw, mesh, sched="postsi",
                                      n_nodes=4)
assert pl_s == pd_s, (pl_s, pd_s)
for (t1, o1), (t2, o2) in zip(pl_h, pd_h):
    for name, f1, f2 in zip(o1._fields, o1, o2):
        np.testing.assert_array_equal(f1, f2, err_msg=f"pad.{name}")
for name, f1, f2 in zip(pl_st._fields, pl_st, pd_st):
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2)[:100],
                                  err_msg=f"pad.store.{name}")
print("PAD-SHARD-OK rows:", pad.head.shape[0])

for sched in SCHEDULERS:
    waves = smallbank_waves(np.random.RandomState(7), W, T, n_nodes, kpn,
                            dist_frac=0.5, hot_frac=0.5, hot_per_node=4)
    hs = (np.array([0,1,1,2,0,1,2,0], np.int32) if sched == "clocksi"
          else None)
    st1, h1, s1 = run_workload(make_store(n_nodes*kpn, 8), waves,
                               sched=sched, n_nodes=n_nodes, host_skew=hs,
                               gc_track=True)
    st2, h2, s2 = run_workload_dist(
        shard_store(make_store(n_nodes*kpn, 8), mesh), waves, mesh,
        sched=sched, n_nodes=n_nodes, host_skew=hs, gc_track=True)
    st3, h3, s3 = run_workload_fused_dist(
        shard_store(make_store(n_nodes*kpn, 8), mesh), waves, mesh,
        sched=sched, n_nodes=n_nodes, host_skew=hs, gc_track=True)
    assert s1 == s2 == s3, (sched, s1, s2, s3)
    for (t1, o1), (t2, o2), (t3, o3) in zip(h1, h2, h3):
        np.testing.assert_array_equal(t1, t2)
        for name, f1, f2, f3 in zip(o1._fields, o1, o2, o3):
            np.testing.assert_array_equal(f1, f2,
                                          err_msg=f"{sched}.perwave.{name}")
            np.testing.assert_array_equal(f1, f3,
                                          err_msg=f"{sched}.fused.{name}")
    for name, f1, f2, f3 in zip(st1._fields, st1, st2, st3):
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2),
                                      err_msg=f"{sched}.store.{name}")
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f3),
                                      err_msg=f"{sched}.store.fused.{name}")
    print(f"DIST-{sched}-OK commits: {s1.committed} aborts: {s1.aborted}")
"""))


def test_dist_engine_hypothesis_differential():
    """Property: for random waves (mixed reads / blind writes / RMWs, random
    contention and distribution), LocalSubstrate and MeshSubstrate commit
    the same set with identical intervals under every drawn scheduler."""
    pytest.importorskip("hypothesis")
    print(_run(r"""
import numpy as np
from hypothesis import given, settings, strategies as st
from repro.core import SCHEDULERS, make_store, run_workload
from repro.core.dist_engine import (make_node_mesh, run_workload_dist,
                                    shard_store)
from repro.core.workloads import micro_waves

n_nodes, kpn, T = 4, 16, 12
mesh = make_node_mesh(n_nodes)

@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**16), sched=st.sampled_from(SCHEDULERS),
       read_ratio=st.sampled_from([0.2, 0.6]),
       blind_frac=st.sampled_from([0.0, 0.8]))
def check(seed, sched, read_ratio, blind_frac):
    waves = micro_waves(np.random.RandomState(seed), 1, T, n_nodes, kpn,
                        n_ops=3, read_ratio=read_ratio, dist_frac=0.5,
                        hot_frac=0.6, hot_per_node=2, blind_frac=blind_frac)
    hs = (np.array([0, 1, 0, 2], np.int32) if sched == "clocksi" else None)
    _, h1, s1 = run_workload(make_store(n_nodes*kpn, 4), waves, sched=sched,
                             n_nodes=n_nodes, host_skew=hs)
    _, h2, s2 = run_workload_dist(
        shard_store(make_store(n_nodes*kpn, 4), mesh), waves, mesh,
        sched=sched, n_nodes=n_nodes, host_skew=hs)
    assert s1 == s2, (sched, seed, s1, s2)
    for (t1, o1), (t2, o2) in zip(h1, h2):
        for name, f1, f2 in zip(o1._fields, o1, o2):
            np.testing.assert_array_equal(f1, f2,
                                          err_msg=f"{sched}/{seed}.{name}")

check()
print("DIST-HYPOTHESIS-OK")
"""))


def test_dist_engine_kernel_backends_bit_identical():
    """The kernel-backend plane on the mesh: all SEVEN schedulers (the six
    optimistic ones plus "planned") produce bit-identical WaveOut under
    ``jnp`` vs ``pallas_interpret``, three-dispatch vs fused megakernel, on
    the MeshSubstrate, per-wave AND scan-fused — and all match the
    LocalSubstrate (acceptance gate of the backend refactor; the
    version_scan / wave_commit kernels run on each node's local block
    inside shard_map).  The pallas_interpret configs must dispatch real
    (interpreted) Pallas on the mesh: the degrade counter stays ZERO."""
    print(_run(r"""
import numpy as np
from repro.core import SCHEDULERS, make_store, run_workload
from repro.core.dist_engine import (make_node_mesh, run_workload_dist,
                                    run_workload_fused_dist, shard_store)
from repro.core.substrate import mesh_degrade_count
from repro.core.workloads import smallbank_waves
from repro.planner import run_workload_planned

n_nodes, kpn, W, T = 4, 16, 2, 12
mesh = make_node_mesh(n_nodes)
CONFIGS = ("jnp", "pallas_interpret", "jnp+fused", "pallas_interpret+fused")

for sched in SCHEDULERS:
    waves = smallbank_waves(np.random.RandomState(13), W, T, n_nodes, kpn,
                            dist_frac=0.5, hot_frac=0.5, hot_per_node=4)
    hs = (np.array([0,1,1,2], np.int32) if sched == "clocksi" else None)
    ref = run_workload(make_store(n_nodes*kpn, 8), waves, sched=sched,
                       n_nodes=n_nodes, host_skew=hs, gc_track=True,
                       kernels="jnp")
    for bk in CONFIGS:
        for drv, runner in (("perwave", run_workload_dist),
                            ("fused", run_workload_fused_dist)):
            st, h, s = runner(shard_store(make_store(n_nodes*kpn, 8), mesh),
                              waves, mesh, sched=sched, n_nodes=n_nodes,
                              host_skew=hs, gc_track=True, kernels=bk)
            assert s == ref[2], (sched, bk, drv, s, ref[2])
            for (t1, o1), (t2, o2) in zip(ref[1], h):
                np.testing.assert_array_equal(t1, t2)
                for name, f1, f2 in zip(o1._fields, o1, o2):
                    np.testing.assert_array_equal(
                        f1, f2, err_msg=f"{sched}.{bk}.{drv}.{name}")
            for name, f1, f2 in zip(ref[0]._fields, ref[0], st):
                np.testing.assert_array_equal(
                    np.asarray(f1), np.asarray(f2),
                    err_msg=f"{sched}.{bk}.{drv}.store.{name}")
    print(f"DIST-BACKEND-{sched}-OK")

# the seventh scheduler: planned lane dispatch on the mesh, every config
waves = smallbank_waves(np.random.RandomState(29), 2, 12, n_nodes, kpn,
                        dist_frac=0.5, hot_frac=0.5, hot_per_node=3)
ref = None
for bk in CONFIGS:
    st, h, s = run_workload_planned(
        shard_store(make_store(n_nodes*kpn, 8), mesh), waves, sched="postsi",
        n_nodes=n_nodes, mesh=mesh, kernels=bk)
    assert s.aborted == 0, (bk, s)
    if ref is None:
        ref = (st, h, s)
        continue
    assert s._replace(plan_s=0) == ref[2]._replace(plan_s=0), (bk, s, ref[2])
    for (t1, o1), (t2, o2) in zip(ref[1], h):
        np.testing.assert_array_equal(t1, t2)
        for name, f1, f2 in zip(o1._fields, o1, o2):
            np.testing.assert_array_equal(f1, f2,
                                          err_msg=f"planned.{bk}.{name}")
    for name, f1, f2 in zip(ref[0]._fields, ref[0], st):
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2),
                                      err_msg=f"planned.{bk}.store.{name}")
print("DIST-BACKEND-planned-OK")

# degrade gate: no config above may have been served by a silent jnp
# fallback — pallas_interpret passes through shard_map as real
# (interpreted) Pallas, and only a true compiled-'pallas' request on a
# probe-failing platform is allowed to degrade (none was made here)
assert mesh_degrade_count() == 0, mesh_degrade_count()
print("DIST-DEGRADE-ZERO-OK")
"""))


def test_mesh_service_matches_single_device():
    """The sharded closed-loop service (TxnService(mesh=...), GC watermark
    merged by lax.pmin from per-node reader floors) serves the identical
    stream to the identical outcome as the single-device service, and the
    served history verifies."""
    print(_run(r"""
import numpy as np
from repro.core.dist_engine import make_node_mesh, mesh_watermark
from repro.core.workloads import poisson_arrivals
from repro.service import RetryPolicy, TxnService, smallbank_txn_gen

n_nodes, kpn, T = 8, 32, 16
mesh = make_node_mesh(n_nodes)
reports = []
for m in (None, mesh):
    svc = TxnService(n_keys=n_nodes*kpn, n_versions=8, T=T, sched="postsi",
                     n_nodes=n_nodes, retry=RetryPolicy(max_attempts=6),
                     seed=0, mesh=m)
    arr = poisson_arrivals(np.random.RandomState(100), 0.9*T, 8)
    gen = smallbank_txn_gen(np.random.RandomState(200), n_nodes, kpn,
                            dist_frac=0.3, hot_frac=0.6, hot_per_node=3)
    reports.append(svc.run_stream(arr, gen))
    assert svc.verify() == [], svc.verify()
    # decentralized watermark: pmin merge over per-node floors == host min
    h = svc.gc.pin(3, node=5)
    assert svc.gc.watermark() == mesh_watermark(
        mesh, svc.gc.node_floors(n_nodes))
    svc.gc.release(h)
a, b = reports
assert (a.committed, a.dropped, a.retries, a.waves, a.rejected) == \
       (b.committed, b.dropped, b.retries, b.waves, b.rejected), (a, b)
assert (a.latency_p50, a.latency_p95, a.latency_p99) == \
       (b.latency_p50, b.latency_p95, b.latency_p99)
print("MESH-SERVICE-OK committed:", a.committed)
"""))


def test_elastic_mesh_matches_static_theta099():
    """The elastic placement differential at the paper's hardest skew
    (zipf θ=0.99): the sharded service with a PlacementMap + live balancer
    moves commits the EXACT same request set with the EXACT same history as
    the static service on the identical stream — for all SEVEN schedulers
    (the six optimistic ones + planned lanes), and on both kernel backends
    for the representative pair.  Engine outcomes are placement-invariant
    by construction (slot translation is injective), so live repartitioning
    is invisible to concurrency control."""
    print(_run(r"""
import numpy as np
from repro.core.dist_engine import make_node_mesh
from repro.placement import PlacementMap
from repro.service import TxnService, ycsb_txn_gen

n_nodes, kpn, T = 8, 16, 16
n_keys = n_nodes * kpn
mesh = make_node_mesh(n_nodes)
SCHEDS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi", "planned")

def serve(sched, placement, balancer, kernels):
    hs = (np.array([0,1,1,2,0,1,2,0], np.int32) if sched == "clocksi"
          else None)
    svc = TxnService(n_keys=n_keys, n_versions=8, T=T,
                     sched="postsi" if sched == "planned" else sched,
                     n_nodes=n_nodes, host_skew=hs, seed=0, mesh=mesh,
                     kernels=kernels,
                     planner="planned" if sched == "planned" else None,
                     placement=placement, balancer=balancer)
    gen = ycsb_txn_gen(np.random.RandomState(42), n_nodes, kpn, theta=0.99)
    svc.run_stream([12] * 4, gen)
    return svc

for sched in SCHEDS:
    backends = (("jnp", "pallas_interpret") if sched in ("postsi", "planned")
                else ("jnp",))
    for kernels in backends:
        a = serve(sched, None, None, kernels)
        b = serve(sched, PlacementMap(n_keys, n_nodes, headroom=2), True,
                  kernels)
        cs = lambda s: sorted(r.req_id for r in s.requests
                              if r.status == "committed")
        assert cs(a) == cs(b), (sched, kernels, len(cs(a)), len(cs(b)))
        assert len(a.history) == len(b.history), (sched, kernels)
        for (t1, o1), (t2, o2) in zip(a.history, b.history):
            np.testing.assert_array_equal(t1, t2)
            for name, f1, f2 in zip(o1._fields, o1, o2):
                np.testing.assert_array_equal(
                    f1, f2, err_msg=f"{sched}.{kernels}.{name}")
        if sched != "clocksi":   # skewed hosts read stale snapshots by
            # design (paper §II anomaly) — measured, not verified; the
            # bit-equality above already proves placement invariance
            assert b.verify() == [], (sched, b.verify())
        print(f"ELASTIC-{sched}-{kernels}-OK commits: {b.committed}",
              f"moves: {b.report().placement_moves}")
print("ELASTIC-DIFFERENTIAL-OK")
"""))


def test_elastic_mesh_replicas_check_and_recovery():
    """Three elastic-plane properties that need the real 8-device mesh:
    (1) hot-key replica reads on the sharded service never run ahead of the
    lax.pmin watermark and the served history verifies; (2) the
    REPRO_PLACEMENT_CHECK=1 debug gate detects a mis-routed placement
    BEFORE dispatch instead of silently corrupting reads; (3) a crashed
    durable elastic mesh service recovers bit-identically, replaying
    interleaved REC_MOVE + REC_BLOCK records."""
    print(_run(r"""
import numpy as np, os, tempfile
from repro.core import Wave, make_store
from repro.core.dist_engine import make_node_mesh, run_wave_dist, shard_store
from repro.core.workloads import zipf_hot_keys
from repro.placement import PlacementError, PlacementMap
from repro.service import TxnService, ycsb_txn_gen

n_nodes, kpn = 8, 16
n_keys = n_nodes * kpn
mesh = make_node_mesh(n_nodes)

# 1. replica staleness on the mesh: floor <= pmin watermark clock, always
hot = zipf_hot_keys(n_nodes, kpn, theta=0.99)
svc = TxnService(n_keys=n_keys, n_versions=8, T=16, sched="postsi",
                 n_nodes=n_nodes, seed=0, mesh=mesh,
                 placement=PlacementMap(n_keys, n_nodes, headroom=2),
                 replicas=hot, balancer=True)
gen = ycsb_txn_gen(np.random.RandomState(7), n_nodes, kpn, theta=0.99)
svc.run_stream([12] * 4, gen)
assert svc.verify() == [], svc.verify()
rep = svc.replicas
assert svc.replica_commits > 0
assert rep.max_cid() <= rep.floor <= svc.gc.clock
for r in svc.requests:
    if r.replica:
        assert r.s == r.c <= svc.gc.clock
print("MESH-REPLICA-OK replica_commits:", svc.replica_commits,
      "floor:", rep.floor, "clock:", svc.gc.clock)

# 2. REPRO_PLACEMENT_CHECK=1 catches a cross-node slot corruption
os.environ["REPRO_PLACEMENT_CHECK"] = "1"
pm_bad = PlacementMap(n_keys, n_nodes, headroom=1)
slot = pm_bad.slot.copy()
slot[0], slot[-1] = slot[-1], slot[0]        # key 0's ring on node 7's block
pm_bad.slot = slot
T = 8
wave = Wave(op_kind=np.ones((T, 2), np.int32),
            op_key=np.zeros((T, 2), np.int32),
            op_val=np.zeros((T, 2), np.int32), host=np.zeros(T, np.int32),
            tid=np.arange(1, T + 1, dtype=np.int32))
st = shard_store(make_store(n_keys, 4), mesh)
try:
    run_wave_dist(st, wave, 1, 1, mesh, sched="postsi", n_nodes=n_nodes,
                  placement=pm_bad.device_arrays())
    raise AssertionError("mis-routed placement not detected")
except PlacementError as e:
    print("PLACEMENT-CHECK-OK", str(e)[:60])
os.environ["REPRO_PLACEMENT_CHECK"] = "0"

# 3. durable elastic mesh service: crash -> recover bit-identically
from repro.durability.recovery import DurabilityManager, recover
d = tempfile.mkdtemp()
mgr = DurabilityManager(d, fsync_every=1, snapshot_every=2)
svc2 = TxnService(n_keys=n_keys, n_versions=8, T=16, sched="postsi",
                  n_nodes=n_nodes, seed=1, mesh=mesh,
                  placement=PlacementMap(n_keys, n_nodes, headroom=2),
                  balancer=True, durability=mgr)
svc2.run_stream([12] * 4,
                ycsb_txn_gen(np.random.RandomState(9), n_nodes, kpn,
                             theta=0.99))
moves = svc2.report().placement_moves
assert moves >= 1, moves
mgr.crash()
state = recover(d, mesh=mesh)
for name in svc2.store._fields:
    np.testing.assert_array_equal(np.asarray(getattr(svc2.store, name)),
                                  np.asarray(getattr(state.store, name)),
                                  err_msg=name)
np.testing.assert_array_equal(state.placement_map.slot, svc2.placement.slot)
np.testing.assert_array_equal(state.placement_map.owner, svc2.placement.owner)
print("MESH-MOVE-RECOVERY-OK moves:", moves, "replayed:", state.n_replayed,
      "of", state.n_records, "records")
"""))
