"""Distribution-layer tests.

These need more than one XLA device, and the device count is locked at jax
init — so each test runs a child python with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  Smoke tests and
benches keep seeing 1 device (per the assignment).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mini_dryrun_lower_compile_8dev():
    """Reduced config lowers + compiles on a (2,2,2) pod/data/model mesh;
    memory & cost analysis available; collectives present."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.launch.train import make_train_step, abstract_train_state
from repro.launch.inputs import _train_batch
from repro.launch.sharding import input_shardings
from repro.models.module import use_mesh_and_rules, param_shardings
from repro.optim import adamw_init
from repro.optim.adamw import AdamWState

cfg = get_reduced("qwen3-14b")
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2,2,2), ("pod","data","model"))
with use_mesh_and_rules(mesh):
    model, params, opt = abstract_train_state(cfg)
    _, step = make_train_step(cfg)
    p_sh = param_shardings(model.param_specs(), mesh)
    o_sh = AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
    batch = _train_batch(cfg, 8, 64, True)
    b_sh = input_shardings(batch, mesh)
    low = jax.jit(step, in_shardings=(p_sh,o_sh,b_sh),
                  out_shardings=(p_sh,o_sh,None),
                  donate_argnums=(0,1)).lower(params, opt, batch)
    comp = low.compile()
txt = comp.as_text()
assert "all-reduce" in txt or "all-gather" in txt
from repro.launch.hlo_analysis import analyze
r = analyze(txt, 8)
assert r["flops"] > 0 and r["collective_bytes"] > 0
print("MINI-DRYRUN-OK", int(r["flops"]), int(r["collective_bytes"]))
"""))


def test_real_execution_on_mesh_matches_single_device():
    """The same train step executed (a) on 1 device and (b) SPMD on a (2,2)
    mesh gives the same loss — numerics of the distribution layer."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.launch.train import make_train_step
from repro.launch.inputs import make_batch
from repro.launch.sharding import input_shardings
from repro.models.module import use_mesh_and_rules, param_shardings
from repro.optim import adamw_init
from repro.optim.adamw import AdamWState

cfg = get_reduced("yi-9b")
model, step = make_train_step(cfg, lr=1e-3)
params = model.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = make_batch(cfg, 4, 32, "train")
_,_, m1 = jax.jit(step)(params, opt, batch)

mesh = Mesh(np.array(jax.devices()[:4]).reshape(2,2), ("data","model"))
with use_mesh_and_rules(mesh):
    p_sh = param_shardings(model.param_specs(), mesh)
    o_sh = AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
    b_sh = input_shardings(batch, mesh)
    pd = jax.device_put(params, p_sh)
    od = jax.device_put(opt, o_sh)
    bd = jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), batch, b_sh)
    _,_, m2 = jax.jit(step, in_shardings=(p_sh,o_sh,b_sh),
                      out_shardings=(p_sh,o_sh,None))(pd, od, bd)
d = abs(float(m1['loss']) - float(m2['loss']))
assert d < 1e-2, (float(m1['loss']), float(m2['loss']))
print("SPMD-EXEC-OK", float(m1['loss']), float(m2['loss']))
"""))


def test_compressed_psum_and_elastic_reshard():
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp, functools
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_psum

mesh = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("pod",))
x = jnp.asarray(np.random.RandomState(0).randn(4, 64), jnp.float32)

@functools.partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                   check_rep=False)
def f(xs):
    total, err = compressed_psum(xs, "pod")
    return total

out = f(x)
exact = x.sum(axis=0, keepdims=True)
rel = float(jnp.abs(out[0] - exact[0]).max() / jnp.abs(exact).max())
assert rel < 0.02, rel
print("COMPRESSED-PSUM-OK rel", rel)

# elastic reshard: state saved on a (2,2) mesh restores onto a (4,) mesh
from repro.checkpoint import PostSICheckpointer, reshard_tree
import tempfile
m1 = Mesh(np.array(jax.devices()[:4]).reshape(2,2), ("data","model"))
m2 = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("data",))
tree = {"w": jax.device_put(jnp.arange(16.0).reshape(4,4),
                            NamedSharding(m1, P("data","model")))}
with tempfile.TemporaryDirectory() as d:
    ck = PostSICheckpointer(d, tree)
    assert ck.save(1, tree)
    sh2 = {"w": NamedSharding(m2, P("data", None))}
    step, out = ck.restore(tree, sh2)
assert step == 1
np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(16.0).reshape(4,4))
assert out["w"].sharding.spec == P("data", None)
print("ELASTIC-RESHARD-OK")
"""))


def test_dist_engine_matches_single_device():
    """The shard_map PostSI engine (peer collectives, no coordinator) commits
    the exact same transactions with the exact same induced intervals as the
    single-device engine."""
    print(_run(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import make_store, run_wave
from repro.core.dist_engine import (make_node_mesh, run_wave_postsi_dist,
                                    shard_store)
from repro.core.workloads import micro_waves

n_nodes, kpn = 8, 64
rng = np.random.RandomState(3)
waves = micro_waves(rng, 1, 32, n_nodes, kpn, n_ops=4, read_ratio=0.4,
                    hot_frac=0.5, hot_per_node=4, blind_frac=0.5)
wave = waves[0]

# single-device reference
store1 = make_store(n_nodes * kpn, 8)
store1, out, clock = run_wave(store1, wave, jnp.int32(1), jnp.int32(1),
                              jnp.int32(n_nodes), sched="postsi")

# distributed
mesh = make_node_mesh(n_nodes)
store2 = shard_store(make_store(n_nodes * kpn, 8), mesh)
store2, status, s, c = run_wave_postsi_dist(store2, wave, jnp.int32(1),
                                            mesh, kpn)
np.testing.assert_array_equal(np.asarray(out.status), np.asarray(status))
np.testing.assert_array_equal(np.asarray(out.s), np.asarray(s))
np.testing.assert_array_equal(np.asarray(out.c), np.asarray(c))
np.testing.assert_array_equal(np.asarray(store1.val), np.asarray(store2.val))
np.testing.assert_array_equal(np.asarray(store1.cid), np.asarray(store2.cid))
print("DIST-ENGINE-OK commits:", int((status == 1).sum()),
      "aborts:", int((status == 2).sum()))
"""))
