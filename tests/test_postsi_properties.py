"""Property-based tests (hypothesis) for the paper's invariants.

The strongest correctness statement in the repo: for *arbitrary* interleaved
schedules and for randomized wave workloads, every committed history under
the PostSI scheduler admits a valid SI timestamping (Theorem 1), and the CV
scheduler never exhibits partial visibility or lost updates (Definition 5).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import make_store, run_workload, verify_cv, verify_si
from repro.core.seq import SeqScheduler
from repro.core.workloads import micro_waves, smallbank_waves, tpcc_waves

# ---------------------------------------------------------------------------
# sequential scheduler: arbitrary interleavings
# ---------------------------------------------------------------------------

# an action is (kind, txn_slot, key): kind 0=read 1=write 2=commit
ACTIONS = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 3), st.integers(0, 4)),
    min_size=4, max_size=40)


def _drive(mode, actions, n_keys=5, n_slots=4):
    s = SeqScheduler(n_keys, mode)
    tids = {}
    val = 0
    for kind, slot, key in actions:
        tid = tids.get(slot)
        if tid is None or s.txns[tid].status != "running":
            tid = s.begin()
            tids[slot] = tid
        if kind == 0:
            s.read(tid, key)
        elif kind == 1:
            val += 1
            s.write(tid, key, val)
        else:
            s.commit(tid)
            tids[slot] = None
    for slot, tid in tids.items():
        if tid is not None and s.txns[tid].status == "running":
            s.commit(tid)
    return s


@settings(max_examples=150, deadline=None)
@given(ACTIONS)
def test_seq_postsi_always_si(actions):
    s = _drive("postsi", actions)
    errs = verify_si(s.history())
    assert not errs, errs[:3]


@settings(max_examples=150, deadline=None)
@given(ACTIONS)
def test_seq_cv_always_cv(actions):
    s = _drive("cv", actions)
    errs = verify_cv(s.history())
    assert not errs, errs[:3]


@settings(max_examples=60, deadline=None)
@given(ACTIONS)
def test_seq_postsi_intervals_consistent(actions):
    """Committed intervals satisfy s < c, and ww-ordered writers are
    interval-disjoint (Definition 4 condition iii via Theorem 1)."""
    s = _drive("postsi", actions)
    for t in s.txns.values():
        if t.status == "committed":
            assert t.s is not None and t.c is not None and t.s < t.c


# ---------------------------------------------------------------------------
# wave engine: randomized workloads x schedulers
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["postsi", "si", "dsi"]),
       st.floats(0.0, 0.9), st.floats(0.0, 0.8))
def test_wave_engine_si_validity(seed, sched, hot, dist):
    rng = np.random.RandomState(seed)
    n_nodes, kpn = 4, 60
    waves = micro_waves(rng, 3, 24, n_nodes, kpn, n_ops=4, read_ratio=0.4,
                        hot_frac=hot, hot_per_node=4, dist_frac=dist,
                        blind_frac=0.5)
    _, hist, _ = run_workload(make_store(n_nodes * kpn, 8), waves,
                              sched=sched, n_nodes=n_nodes)
    errs = verify_si(hist)
    assert not errs, (sched, errs[:3])


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_wave_engine_cv_validity(seed):
    rng = np.random.RandomState(seed)
    n_nodes, kpn = 4, 60
    waves = micro_waves(rng, 3, 24, n_nodes, kpn, n_ops=4, read_ratio=0.3,
                        hot_frac=0.6, hot_per_node=4, blind_frac=0.5)
    _, hist, _ = run_workload(make_store(n_nodes * kpn, 8), waves,
                              sched="cv", n_nodes=n_nodes)
    errs = verify_cv(hist)
    assert not errs, errs[:3]


def test_wave_engine_standard_benchmarks_verify():
    rng = np.random.RandomState(0)
    n_nodes, kpn = 8, 120
    for mk in (smallbank_waves, tpcc_waves):
        waves = mk(rng, 3, 32, n_nodes, kpn, dist_frac=0.5)
        for sched in ("postsi", "si", "dsi", "cv"):
            _, hist, _ = run_workload(make_store(n_nodes * kpn, 8), waves,
                                      sched=sched, n_nodes=n_nodes)
            check = verify_cv if sched == "cv" else verify_si
            errs = check(hist)
            assert not errs, (mk.__name__, sched, errs[:3])


def test_postsi_commits_blind_writes_si_aborts():
    """The paper's Figure 1 advantage, end-to-end through the wave engine:
    under blind-write contention PostSI commits strictly more than
    first-committer-wins SI."""
    rng = np.random.RandomState(7)
    n_nodes, kpn = 4, 100
    waves = micro_waves(rng, 5, 48, n_nodes, kpn, n_ops=4, read_ratio=0.4,
                        hot_frac=0.6, hot_per_node=4, blind_frac=0.7)
    _, _, st_post = run_workload(make_store(n_nodes * kpn, 8), waves,
                                 sched="postsi", n_nodes=n_nodes)
    _, _, st_si = run_workload(make_store(n_nodes * kpn, 8), waves,
                               sched="si", n_nodes=n_nodes)
    assert st_post.committed > st_si.committed
    assert st_si.msgs_coord > 0 and st_post.msgs_coord == 0


def test_paper_worked_examples():
    """Figure 1 and Figure 3 Schedule III/IV discriminations (see core/seq)."""
    A, B = 0, 1
    # Fig 1: t3 blind-writes over t2's committed version while physically
    # overlapping -> PostSI commits (induced c2 < s3)
    s = SeqScheduler(2, "postsi")
    t1, t2, t3 = s.begin(), s.begin(), s.begin()
    s.read(t1, A)
    s.read(t2, A)
    s.write(t2, B, 20)
    assert s.commit(t2)
    s.write(t3, B, 30)
    assert s.commit(t3)
    assert not verify_si(s.history())

    # Schedule IV-like cycle: PostSI must abort the cycle-closing txn
    s = SeqScheduler(2, "postsi")
    t1, t2 = s.begin(), s.begin()
    s.read(t1, B)
    s.read(t1, A)
    s.write(t2, A, 1)
    assert s.commit(t2)
    t3 = s.begin()
    s.read(t3, A)
    s.write(t3, B, 2)
    assert s.commit(t3)
    s.write(t1, A, 3)
    assert not s.commit(t1)              # cycle closes -> abort
    assert not verify_si(s.history())


def test_cid_visibility_read_avoids_hot_item_abort():
    """Paper §IV-B: the CID-visibility read rule ("a version is visible only
    if its CID is below the start-time upper bound") lets a constrained
    reader take an *older* version of a hot item instead of aborting — the
    stronger, read-time form of the paper's retry-with-pinned-s_hi trick.
    A plain §III-D rule-3 read (always newest) would force s_lo=3 > s_hi=0
    and abort."""
    from repro.core.seq import SeqScheduler
    A, B = 0, 1
    s = SeqScheduler(2, "postsi")
    # B becomes hot: three committed versions with rising CIDs (1, 2, 3)
    for v in range(3):
        t = s.begin()
        s.write(t, B, 10 + v)
        assert s.commit(t)
    newest_cid = s.versions[B][-1].cid
    # t1 reads A (old); a peer overwrites A and commits while t1.s_lo is
    # still 0 -> rule 4(b) collapses t1's upper bound: s_hi = c(peer)-1 = 0
    t1 = s.begin()
    s.read(t1, A)
    tw = s.begin()
    s.write(tw, A, 99)
    assert s.commit(tw)
    pin = s.txns[t1].s_hi
    assert pin < newest_cid
    # t1 now reads hot B: the CID rule skips versions newer than s_hi and
    # returns an older visible one — no abort, and the history is still SI
    got = s.read(t1, B)
    assert s.txns[t1].status == "running"
    assert got is not None
    assert s.versions[B][s.txns[t1].reads[B]].cid <= pin
    assert s.commit(t1)
    assert not verify_si(s.history())
    # the explicit retry pin (begin(s_hi_pin=...)) gives the same visibility
    # ceiling up-front, for the distributed delegated-read race (§IV-B)
    t2 = s.begin(s_hi_pin=pin)
    got2 = s.read(t2, B)
    assert got2 == got and s.commit(t2)
