"""GC watermark safety (DESIGN.md §8), differential against core/seq.py.

The reclamation rule — a version is garbage once its superseder's CID is at
or below the watermark (the decentralized min over live readers' ``s_lo``
plus external pins) — must never destroy a version any live transaction
can still read.  We check that *empirically* against the sequential oracle:
drive random interleavings through ``SeqScheduler``, reclaim (irreversibly)
whatever the rule allows after every commit, and assert no later successful
read or commit-time SID bump ever touches a reclaimed version.

Also covered: the engine-side counter (``RunStats.evicted_visible``) fires
exactly when V is too small for the write rate, ``gc_block`` converts those
corruptions into aborts, and the pin API protects §IV-B s_hi-pinned retries
(whose snapshot floor the min-over-``s_lo`` alone cannot see).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import evicting_visible, make_store, run_workload, \
    run_workload_fused
from repro.core.seq import SeqScheduler
from repro.core.store import bump_sid, install_version
from repro.core.workloads import micro_waves
from repro.service import VisibilityGC, seq_watermark


# ---------------------------------------------------------------------------
# differential: watermark rule vs the sequential oracle's actual reads
# ---------------------------------------------------------------------------

def _reclaim(s: SeqScheduler, reclaimed: dict, era: int, pins=()) -> None:
    """Irreversibly mark every version the watermark rule allows to die,
    stamped with the era (event count) of first reclamation."""
    wm = seq_watermark(s, pins)
    for key, chain in s.versions.items():
        for idx in range(len(chain) - 1):
            if chain[idx + 1].cid <= wm:
                reclaimed.setdefault((key, idx), era)


def _drive_with_gc(seed: int, n_keys=5, n_slots=4, n_actions=60):
    """Random begin/read/write/commit interleaving; after every event,
    reclaim per the watermark.  Safety: no transaction ever reads (or
    SID-bumps at commit) a version reclaimed *while it was live*.

    A version reclaimed BEFORE a transaction began is different: PostSI's
    rule 4(b) can collapse a newborn's ``s_hi`` below past watermarks, and
    §IV-B's CID-visibility read then reaches for the old version — in a
    real engine that is an availability abort ("version not retained"),
    not a corruption, and the pin API exists to prevent it (see
    ``test_pin_protects_s_hi_pinned_retry``).  We count those separately.

    Returns (reclaimed_count, pre_birth_misses).
    """
    rng = np.random.RandomState(seed)
    s = SeqScheduler(n_keys, "postsi")
    reclaimed: dict = {}               # (key, idx) -> era first reclaimed
    birth_era: dict = {}               # tid -> era at begin
    tids = {}
    val = 0
    pre_birth_misses = 0
    for era in range(n_actions):
        kind = rng.randint(0, 3)
        slot = rng.randint(0, n_slots)
        key = rng.randint(0, n_keys)
        tid = tids.get(slot)
        if tid is None or s.txns[tid].status != "running":
            tid = s.begin()
            birth_era[tid] = era
            tids[slot] = tid
        if kind == 0:
            before = s.txns[tid].reads.get(key)
            got = s.read(tid, key)
            if (got is not None and s.txns[tid].status == "running"
                    and key in s.txns[tid].reads):   # not read-your-own-write
                idx = s.txns[tid].reads[key]
                if idx != before and (key, idx) in reclaimed:
                    assert reclaimed[(key, idx)] < birth_era[tid], (
                        f"seed={seed}: txn {tid} (born era "
                        f"{birth_era[tid]}) read version key={key} "
                        f"idx={idx} reclaimed at era "
                        f"{reclaimed[(key, idx)]} while it was live")
                    pre_birth_misses += 1
        elif kind == 1:
            val += 1
            s.write(tid, key, val)
        else:
            t = s.txns[tid]
            if t.status == "running":
                held = list(t.reads.items())
                ok = s.commit(tid)
                if ok:
                    # rule 4(c) bumped SIDs of every held read version —
                    # none may have been reclaimed while the txn was live
                    for k, idx in held:
                        if (k, idx) in reclaimed:
                            assert reclaimed[(k, idx)] < birth_era[tid], (
                                f"seed={seed}: SID bump on version "
                                f"key={k} idx={idx} reclaimed while "
                                f"txn {tid} was live")
        _reclaim(s, reclaimed, era)
    return len(reclaimed), pre_birth_misses


def test_watermark_never_reclaims_readable_versions():
    total = 0
    for seed in range(40):
        n, _ = _drive_with_gc(seed)
        total += n
    assert total > 0       # the rule actually reclaimed something


def test_watermark_rises_when_idle_and_tracks_min_s_lo():
    s = SeqScheduler(2, "postsi")
    for v in range(3):                       # B = key 1 gets cids 1, 2, 3
        t = s.begin()
        s.write(t, 1, 10 + v)
        assert s.commit(t)
    assert seq_watermark(s) == 3             # idle: newest commit time
    t1 = s.begin()
    assert s.read(t1, 0) is not None         # s_lo stays 0 (bootstrap read)
    assert seq_watermark(s) == 0             # live reader floors the min
    assert seq_watermark(s, pins=(2,)) == 0
    assert s.commit(t1)
    assert seq_watermark(s, pins=(2,)) == 2  # pin holds it below the clock


def test_pin_protects_s_hi_pinned_retry():
    """Paper §IV-B retries read *old* versions (s_hi pinned below the hot
    key's newest CID).  The min-over-live-s_lo watermark cannot see a pin
    that belongs to a not-yet-begun retry — without registering it, the
    rule legally reclaims the version the retry needs; with the pin held
    in VisibilityGC, the version survives and the retry commits."""
    def build():
        s = SeqScheduler(2, "postsi")
        for v in range(3):                   # hot B: cids 1, 2, 3
            t = s.begin()
            s.write(t, 1, 10 + v)
            assert s.commit(t)
        return s

    pin = 1                                  # retry may snapshot as low as 1

    # without the pin: idle watermark = 3 reclaims B@cid1 and B@cid2 ...
    s = build()
    reclaimed: dict = {}
    _reclaim(s, reclaimed, era=0)
    t = s.begin(s_hi_pin=pin)
    assert s.read(t, 1) is not None
    idx = s.txns[t].reads[1]
    assert (1, idx) in reclaimed             # ... exactly what the retry read

    # with the pin registered before reclamation: the version survives
    s = build()
    gcv = VisibilityGC()
    h = gcv.pin(pin)
    reclaimed = {}
    _reclaim(s, reclaimed, era=0, pins=gcv._pins.values())
    t = s.begin(s_hi_pin=pin)
    assert s.read(t, 1) is not None
    assert (1, s.txns[t].reads[1]) not in reclaimed
    assert s.commit(t)
    gcv.release(h)


# ---------------------------------------------------------------------------
# store: install_version accounting + evicting_visible semantics
# ---------------------------------------------------------------------------

def test_install_version_counts_visible_evictions():
    """The host-level install reports the silent ring overflow: wrapping a
    V=2 ring evicts nothing at first (empty slot), then a dead version
    (superseder at/below the watermark), then a still-visible one."""
    st = make_store(n_keys=3, n_versions=2)
    key = jnp.int32(1)
    # ring: [bootstrap cid0] [empty] -> install cid 5 evicts the empty slot
    st, ev = install_version(st, key, jnp.int32(11), jnp.int32(1),
                             jnp.int32(5), jnp.int32(1), watermark=jnp.int32(0))
    assert int(ev) == 0
    assert not bool(evicting_visible(st, key, jnp.int32(5)))
    # next install evicts the bootstrap, whose superseder (cid 5) is at the
    # watermark -> dead, reclaim is safe
    st, ev = install_version(st, key, jnp.int32(12), jnp.int32(2),
                             jnp.int32(9), jnp.int32(2), watermark=jnp.int32(5))
    assert int(ev) == 0
    # now the ring holds cids (5, 9); with the watermark still at 5 the
    # cid-5 version is the visible one for snapshots in [5, 9) -> evicting
    # it must be counted
    assert bool(evicting_visible(st, key, jnp.int32(5)))
    st, ev = install_version(st, key, jnp.int32(13), jnp.int32(3),
                             jnp.int32(14), jnp.int32(3),
                             watermark=jnp.int32(5))
    assert int(ev) == 1
    # other keys' rings are untouched throughout
    assert int(st.head[0]) == 0 and int(st.head[2]) == 0


def test_bump_sid_is_monotone():
    st = make_store(n_keys=2, n_versions=2)
    st = bump_sid(st, jnp.int32(0), jnp.int32(0), jnp.int32(7))
    assert int(st.sid[0, 0]) == 7
    st = bump_sid(st, jnp.int32(0), jnp.int32(0), jnp.int32(3))
    assert int(st.sid[0, 0]) == 7          # rule 4(c): max, never lowered


# ---------------------------------------------------------------------------
# engine: the evicted_visible counter and gc_block
# ---------------------------------------------------------------------------

def _blind_waves():
    rng = np.random.RandomState(1)
    return micro_waves(rng, 6, 32, 4, 60, n_ops=4, read_ratio=0.2,
                       hot_frac=0.8, hot_per_node=2, blind_frac=0.9)


def test_engine_counter_reports_small_rings():
    waves = _blind_waves()
    evicted = {}
    for V in (2, 16):
        _, _, st = run_workload(make_store(4 * 60, V), waves,
                                sched="postsi", n_nodes=4, gc_track=True)
        evicted[V] = st.evicted_visible
    assert evicted[2] > 0          # V too small: still-visible versions died
    assert evicted[16] == 0        # watermark respected: nothing visible died


def test_engine_gc_block_trades_corruption_for_aborts():
    waves = _blind_waves()
    _, _, free = run_workload(make_store(4 * 60, 2), waves,
                              sched="postsi", n_nodes=4, gc_track=True)
    _, _, blocked = run_workload(make_store(4 * 60, 2), waves,
                                 sched="postsi", n_nodes=4, gc_block=True)
    assert free.evicted_visible > 0
    assert blocked.evicted_visible == 0
    assert blocked.aborted > free.aborted
    assert blocked.committed + blocked.aborted == free.committed + free.aborted


def test_engine_fused_matches_perwave_counter():
    waves = _blind_waves()
    _, _, a = run_workload(make_store(4 * 60, 2), waves, sched="postsi",
                           n_nodes=4, gc_track=True)
    _, _, b = run_workload_fused(make_store(4 * 60, 2), waves,
                                 sched="postsi", n_nodes=4, gc_track=True)
    assert a == b and a.evicted_visible > 0
