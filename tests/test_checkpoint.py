"""Unit tests for the PostSI-committed checkpointer
(checkpoint/postsi_store.py) — shipped in the seed with zero coverage,
now the foundation of the durability plane's snapshots (DESIGN.md §9).
"""
import os
import pickle

import numpy as np
import pytest

from repro.checkpoint import PostSICheckpointer


def _tree(seed: int = 0):
    rng = np.random.RandomState(seed)
    return {"layer": {"w": rng.randint(0, 100, (4, 3)).astype(np.int32),
                      "b": rng.randint(0, 100, (3,)).astype(np.int32)},
            "step_scale": np.float32(seed + 0.5)}


def _assert_tree_equal(a, b):
    assert set(a) == set(b)
    np.testing.assert_array_equal(a["layer"]["w"], b["layer"]["w"])
    np.testing.assert_array_equal(a["layer"]["b"], b["layer"]["b"])
    np.testing.assert_allclose(a["step_scale"], b["step_scale"])


def test_save_restore_round_trip(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    assert ck.save(7, _tree(1))
    step, got = ck.restore(_tree())
    assert step == 7
    _assert_tree_equal(got, _tree(1))


def test_restore_empty_dir_is_none(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    assert ck.restore(_tree()) == (None, None)


def test_latest_snapshot_wins_and_reopen_sees_it(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    for step in (1, 2, 3):
        assert ck.save(step, _tree(step))
    step, got = ck.restore(_tree())
    assert step == 3
    _assert_tree_equal(got, _tree(3))
    # a fresh checkpointer over the same directory (restart) agrees
    ck2 = PostSICheckpointer(str(tmp_path), _tree())
    step2, got2 = ck2.restore(_tree())
    assert step2 == 3
    _assert_tree_equal(got2, _tree(3))


def test_gc_keep_latest_prunes_unreachable_files(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    n_leaves = len(ck.paths)
    for step in range(1, 6):
        assert ck.save(step, _tree(step))
    n_files = lambda: sum(f.endswith(".npy") for f in os.listdir(tmp_path))
    assert n_files() == 5 * n_leaves
    removed = ck.gc(keep_latest=2)
    assert removed == 3 * n_leaves
    assert n_files() == 2 * n_leaves
    # both retained checkpoints still restore
    step, got = ck.restore(_tree())
    assert step == 5
    _assert_tree_equal(got, _tree(5))
    assert ck.gc(keep_latest=2) == 0          # idempotent


def test_corrupted_meta_degrades_to_empty(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    assert ck.save(1, _tree(1))
    meta = tmp_path / PostSICheckpointer.META
    meta.write_bytes(b"\x80garbage not a pickle")
    ck2 = PostSICheckpointer(str(tmp_path), _tree())
    assert ck2.meta_corrupt
    assert ck2.restore(_tree()) == (None, None)   # degraded, not dead
    # the next save rewrites a clean meta and the store works again
    assert ck2.save(2, _tree(2))
    assert not PostSICheckpointer(str(tmp_path), _tree()).meta_corrupt


def test_meta_missing_required_keys_degrades(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    assert ck.save(1, _tree(1))
    with open(os.path.join(str(tmp_path), PostSICheckpointer.META), "wb") as f:
        pickle.dump({"sched": None}, f)       # valid pickle, wrong schema
    ck2 = PostSICheckpointer(str(tmp_path), _tree())
    assert ck2.meta_corrupt
    assert ck2.restore(_tree()) == (None, None)


def test_restore_rejects_mismatched_tree_with_clear_error(tmp_path):
    """Regression (ISSUE 6 satellite): a leaf-path mismatch must be
    rejected with a readable error naming the offending paths, not fail
    deep inside tree_unflatten."""
    ck = PostSICheckpointer(str(tmp_path), _tree())
    assert ck.save(1, _tree(1))
    wrong = {"layer": {"w": np.zeros((4, 3), np.int32),
                       "extra": np.zeros(2, np.int32)},
             "step_scale": np.float32(0)}
    with pytest.raises(ValueError, match="leaf paths do not match"):
        ck.restore(wrong)
    # the error names what is missing and what is unexpected
    with pytest.raises(ValueError, match=r"\['b'\]"):
        ck.restore(wrong)
    with pytest.raises(ValueError, match=r"\['extra'\]"):
        ck.restore(wrong)


def test_init_rejects_mismatched_tree_against_saved_meta(tmp_path):
    ck = PostSICheckpointer(str(tmp_path), _tree())
    assert ck.save(1, _tree(1))
    wrong = {"other": np.zeros(3, np.int32)}
    with pytest.raises(ValueError, match="does not match tree_example"):
        PostSICheckpointer(str(tmp_path), wrong)
