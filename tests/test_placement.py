"""Elastic placement plane tests (DESIGN.md §11) — single-device.

The load-bearing claim: the engine consumes a placement ONLY as an
injective logical-key -> physical-slot translation, so ANY placement —
identity, headroom'd blocks, or a layout mutated by live range moves
mid-workload — yields bit-identical outcomes (statuses, intervals,
history, logical store) to the static run, for every scheduler.  The
mesh twin of these tests lives in tests/test_distribution.py (needs 8
virtual devices); everything here runs in-process on one device.
"""
import numpy as np
import pytest

from repro.core import SCHEDULERS, make_store, run_workload
from repro.core.store import read_visible
from repro.core.workloads import micro_waves, zipf_hot_keys
from repro.placement import (HotKeyReplicas, LoadBalancer, PlacementError,
                             PlacementMap, apply_move, logical_store,
                             physical_store, validate_routing)

N_KEYS, N_NODES, V = 64, 4, 8


def _stores_equal(a, b, msg=""):
    for name, fa, fb in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb),
                                      err_msg=f"{msg}.{name}")


def _histories_equal(h1, h2, msg=""):
    assert len(h1) == len(h2), msg
    for (t1, o1), (t2, o2) in zip(h1, h2):
        np.testing.assert_array_equal(t1, t2, err_msg=msg)
        for name, f1, f2 in zip(o1._fields, o1, o2):
            np.testing.assert_array_equal(f1, f2, err_msg=f"{msg}.{name}")


# --------------------------------------------------------------- map basics

def test_placement_map_invariants_and_ranges():
    pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
    pm.validate()
    assert pm.n_slots == N_KEYS * 2
    # initial layout: contiguous blocks, one range per node
    assert pm.ranges() == [(0, 16, 0), (16, 32, 1), (32, 48, 2), (48, 64, 3)]
    assert pm.owner_of(0) == 0 and pm.owner_of(63) == 3
    # headroom=1 with a dividing key space is the identity layout
    pm1 = PlacementMap(N_KEYS, N_NODES, headroom=1)
    np.testing.assert_array_equal(pm1.slot, np.arange(N_KEYS))
    # a move splits the range and re-derives contiguous runs
    rec = pm.move(0, 8, 3)
    assert rec.keys.size == 8
    pm.apply_record(rec)
    pm.validate()
    assert pm.ranges()[0] == (0, 8, 3)
    assert (pm.owner[:8] == 3).all() and (pm.slot[:8] // pm.capacity == 3).all()
    # round-trip through the durable config (initial layout only)
    pm2 = PlacementMap.from_config(pm.to_config())
    assert pm2.capacity == pm.capacity and pm2.n_keys == pm.n_keys


def test_placement_map_capacity_exhaustion_is_loud():
    pm = PlacementMap(8, 2, headroom=1)      # 4 slots per node, all occupied
    with pytest.raises(PlacementError):
        pm.move(0, 2, 1)                     # node 1 has zero free slots


def test_validate_routing_detects_corruption():
    pm = PlacementMap(N_KEYS, N_NODES, headroom=1)
    p = pm.device_arrays()
    validate_routing(pm.n_slots, N_NODES, p)           # clean map passes
    # a slot on the wrong node's block (owner says 0, slot says node 3)
    bad_slot = np.asarray(p.slot).copy()
    bad_slot[0] = pm.n_slots - 1
    bad_slot[N_KEYS - 1] = 0
    broken = type(p)(p.owner, np.asarray(bad_slot))
    with pytest.raises(PlacementError):
        validate_routing(pm.n_slots, N_NODES, broken)
    # a duplicated slot (non-injective map) is also loud
    dup = np.asarray(p.slot).copy()
    dup[1] = dup[0]
    with pytest.raises(PlacementError):
        validate_routing(pm.n_slots, N_NODES, type(p)(p.owner, dup))


def test_physical_logical_store_roundtrip():
    pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
    pm.apply_record(pm.move(4, 12, 2))
    store = make_store(N_KEYS, V)
    phys = physical_store(store, pm)
    assert phys.head.shape[0] == pm.n_slots
    # unmapped rows are EMPTY (tid == NO_TID), mapped rows hold the rings
    occupied = np.zeros(pm.n_slots, bool)
    occupied[pm.slot] = True
    assert (np.asarray(phys.tid)[~occupied] == -1).all()
    _stores_equal(logical_store(phys, pm), store, "roundtrip")


# ------------------------------------------------- engine placement-invariance

@pytest.mark.parametrize("sched", SCHEDULERS)
def test_any_placement_bit_identical_per_sched(sched):
    """Identity, headroom'd blocks, and a post-move layout all reproduce the
    static run exactly: history AND logical final store."""
    rng = np.random.RandomState(5)
    waves = micro_waves(rng, 3, 12, N_NODES, N_KEYS // N_NODES, n_ops=3,
                        read_ratio=0.5, dist_frac=0.5, hot_frac=0.6,
                        hot_per_node=2)
    hs = (np.array([0, 1, 0, 2], np.int32) if sched == "clocksi" else None)
    ref_store, ref_h, ref_s = run_workload(
        make_store(N_KEYS, V), waves, sched=sched, n_nodes=N_NODES,
        host_skew=hs, gc_track=True)
    pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
    pm.apply_record(pm.move(0, 6, 3))        # a pre-moved, non-trivial layout
    st, h, s = run_workload(
        physical_store(make_store(N_KEYS, V), pm), waves, sched=sched,
        n_nodes=N_NODES, host_skew=hs, gc_track=True,
        placement=pm.device_arrays())
    assert s == ref_s, (sched, s, ref_s)
    _histories_equal(ref_h, h, sched)
    _stores_equal(ref_store, logical_store(st, pm), sched)


def test_live_move_mid_workload_bit_identical():
    """Moving a key range BETWEEN waves leaves every subsequent outcome and
    the final logical store bit-identical to the uninterrupted static run —
    the correctness core of live repartitioning."""
    from repro.core import step_wave
    rng = np.random.RandomState(11)
    waves = micro_waves(rng, 6, 12, N_NODES, N_KEYS // N_NODES, n_ops=3,
                        read_ratio=0.4, dist_frac=0.5, hot_frac=0.7,
                        hot_per_node=2)
    ref_store, ref_h, _ = run_workload(make_store(N_KEYS, V), waves,
                                       sched="postsi", n_nodes=N_NODES)
    pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
    store = physical_store(make_store(N_KEYS, V), pm)
    import jax.numpy as jnp
    clock = jnp.int32(1)
    h = []
    for w, wave in enumerate(waves):
        if w == 2:                            # live move at a wave boundary
            rec = pm.move(0, 10, 2)
            store = apply_move(store, rec)
            pm.apply_record(rec)
        if w == 4:                            # and a second one later
            rec = pm.move(32, 40, 0)
            store = apply_move(store, rec)
            pm.apply_record(rec)
        store, out, clock = step_wave(store, wave, w + 1, clock,
                                      sched="postsi", n_nodes=N_NODES,
                                      placement=pm.device_arrays())
        h.append((np.asarray(wave.tid), out))
    _histories_equal(ref_h, h, "live-move")
    _stores_equal(ref_store, logical_store(store, pm), "live-move")
    pm.validate()


# ----------------------------------------------------------------- balancer

def test_balancer_converges_on_skewed_load():
    """Synthetic zipfian per-key traffic: repeated plan/apply rounds drive
    the max/mean imbalance below the trigger, moves are contiguous range
    splits, and the hot node always keeps at least one key."""
    pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
    lb = LoadBalancer(N_KEYS, N_NODES, every=1, trigger=1.25, max_moves=2,
                      decay=1.0)
    # zipf-ish: key k draws ~1/(k+1) of the traffic -> node 0 is scorching
    lb.key_ops = 1000.0 / (np.arange(N_KEYS) + 1.0)
    start = lb.imbalance(pm)
    assert start > 2.0, start
    for _ in range(12):
        moves = lb.plan(pm)
        if not moves:
            break
        for lo, hi, dst in moves:
            assert 0 <= lo < hi <= N_KEYS
            pm.apply_record(pm.move(lo, hi, dst))
            pm.validate()
        assert all((pm.owner == n).sum() >= 1 for n in range(N_NODES))
    assert lb.imbalance(pm) < start
    assert lb.imbalance(pm) < 1.25 + 0.35, lb.imbalance(pm)


# ------------------------------------------------------------------ replicas

def test_replica_staleness_property():
    """Property over seeds: a replica NEVER serves state newer than its
    visibility floor, the floor never exceeds the engine clock, and the
    served values equal ``read_visible`` at the floor — stale but
    consistent, by construction."""
    from repro.service import TxnService
    from repro.core.commit_phase import NOP, READ, RMW
    for seed in (0, 3, 9):
        rng = np.random.RandomState(seed)
        hot = zipf_hot_keys(N_NODES, N_KEYS // N_NODES, theta=0.99)
        pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
        svc = TxnService(n_keys=N_KEYS, n_versions=V, T=16, O=4,
                         sched="postsi", n_nodes=N_NODES, placement=pm,
                         replicas=hot, seed=seed)
        for _ in range(150):
            kind = np.full(4, NOP, np.int32)
            key = np.zeros(4, np.int32)
            val = np.zeros(4, np.int32)
            ks = rng.choice(hot, size=2, replace=False)
            if rng.rand() < 0.6:
                kind[:2] = READ
            else:
                kind[:2] = RMW
                val[:2] = rng.randint(1, 100, 2)
            key[:2] = ks
            svc.submit(kind, key, val, int(rng.randint(0, N_NODES)))
            if rng.rand() < 0.3:
                svc.step()
        svc.drain()
        assert svc.verify() == [], svc.verify()
        rep = svc.replicas
        assert svc.replica_commits > 0
        assert rep.max_cid() <= rep.floor <= svc.gc.clock
        for r in svc.requests:
            if r.replica:
                assert r.s == r.c <= svc.gc.clock
        # consistency AT refresh time: immediately after a refresh, the
        # snapshot equals read_visible at its floor.  (An OLD floor can't be
        # re-read later — ring slots below the advancing watermark are
        # reclaimable; the replica's host copy is exactly what makes the
        # stale snapshot servable without pinning GC.)
        svc._refresh_replicas()
        import jax.numpy as jnp
        rows = jnp.asarray(pm.slot[rep.keys], jnp.int32)
        wm = jnp.broadcast_to(jnp.int32(rep.floor), rows.shape)
        vals, _, cids, _, _ = read_visible(svc.store, rows, wm)
        for i, k in enumerate(rep.keys.tolist()):
            assert rep._val[k] == int(np.asarray(vals)[i]), (seed, k)
            assert rep._cid[k] == int(np.asarray(cids)[i]), (seed, k)


def test_replica_never_serves_writers_or_cold_keys():
    from repro.core.commit_phase import NOP, READ, WRITE
    rep = HotKeyReplicas([1, 2, 3])
    assert not rep.can_serve(np.array([READ]), np.array([1]))  # no snapshot
    rep.floor = 0
    assert rep.can_serve(np.array([READ, NOP]), np.array([1, 0]))
    assert not rep.can_serve(np.array([READ, WRITE]), np.array([1, 2]))
    assert not rep.can_serve(np.array([READ]), np.array([7]))  # cold key
    assert not rep.can_serve(np.array([NOP]), np.array([0]))   # empty txn


# ----------------------------------------------- service + durability planes

def _mixed_txns(seed, n, hot_n=16):
    from repro.core.commit_phase import NOP, READ, RMW
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        kind = np.full(4, NOP, np.int32)
        key = np.zeros(4, np.int32)
        val = np.zeros(4, np.int32)
        ks = rng.choice(hot_n, size=2, replace=False)
        if rng.rand() < 0.5:
            kind[:2] = READ
        else:
            kind[:2] = RMW
            val[:2] = rng.randint(1, 100, 2)
        key[:2] = ks
        out.append((kind, key, val, int(rng.randint(0, N_NODES))))
    return out


def test_elastic_service_commit_set_equals_static():
    """TxnService with an elastic placement + live balancer moves commits
    the EXACT same request set as the static service on the same stream
    (replicas off — they intentionally change which txns reach the engine),
    and the served history verifies."""
    from repro.service import TxnService
    txns = _mixed_txns(2, 150)

    def run(**kw):
        svc = TxnService(n_keys=N_KEYS, n_versions=V, T=16, O=4,
                         sched="postsi", n_nodes=N_NODES, **kw)
        for t in txns:
            svc.submit(*t)
        svc.drain()
        return svc

    s_static = run()
    s_elastic = run(placement=PlacementMap(N_KEYS, N_NODES, headroom=2),
                    balancer=True)
    assert s_elastic.report().placement_moves > 0
    cs = lambda s: sorted(r.req_id for r in s.requests
                          if r.status == "committed")
    assert cs(s_static) == cs(s_elastic)
    _histories_equal(s_static.history, s_elastic.history, "service")
    assert s_elastic.verify() == []
    rep = s_elastic.report()
    assert rep.occupancy and sum(rep.occupancy) == rep.committed
    assert rep.imbalance >= 1.0


@pytest.mark.parametrize("snapshot_every", [None, 2])
def test_move_recovery_replay(tmp_path, snapshot_every):
    """Crash-restart of a durable elastic service with logged moves:
    recovery interleaves REC_MOVE and REC_BLOCK records in seq order and
    rebuilds the store, the PlacementMap and the history bit-identically —
    with and without snapshots (a snapshot taken AFTER a move must not
    re-apply it to the store, only to the map)."""
    from repro.durability.recovery import DurabilityManager, recover
    from repro.service import TxnService
    d = str(tmp_path / f"dur_{snapshot_every}")
    txns = _mixed_txns(4, 120)
    mgr = DurabilityManager(d, fsync_every=1, snapshot_every=snapshot_every)
    svc = TxnService(n_keys=N_KEYS, n_versions=V, T=16, O=4, sched="postsi",
                     n_nodes=N_NODES,
                     placement=PlacementMap(N_KEYS, N_NODES, headroom=2),
                     balancer=True, durability=mgr)
    for t in txns:
        svc.submit(*t)
    svc.drain()
    assert svc.report().placement_moves > 0
    mgr.crash()

    state = recover(d)
    _stores_equal(svc.store, state.store, "recovered")
    np.testing.assert_array_equal(state.placement_map.slot,
                                  svc.placement.slot)
    np.testing.assert_array_equal(state.placement_map.owner,
                                  svc.placement.owner)
    assert state.clock == int(svc.clock)
    # reattach: a fresh service adopts the replayed placement and verifies
    svc2 = TxnService(n_keys=N_KEYS, n_versions=V, T=16, O=4, sched="postsi",
                      n_nodes=N_NODES,
                      placement=PlacementMap(N_KEYS, N_NODES, headroom=2),
                      balancer=True,
                      durability=DurabilityManager(d, fsync_every=1))
    np.testing.assert_array_equal(svc2.placement.slot, svc.placement.slot)
    assert svc2.verify() == [], svc2.verify()


# --------------------------------------------- submit-path correctness sweep
# (PR 10 satellite regressions: each of these fails on the pre-fix code)

def test_replica_negative_key_never_serves():
    """Regression: ``can_serve`` must clamp keys from BELOW too.  Pre-fix
    it only clamped from above, so a negative key wrapped via Python
    negative indexing into the dense ``_member`` table — for the set
    {1,2,3} the table's last row (key 3) is True, so key -1 reported
    replicated and would have served a garbage snapshot at submit."""
    from repro.core.commit_phase import NOP, READ
    rep = HotKeyReplicas([1, 2, 3])
    rep.floor = 0
    assert not rep.can_serve(np.array([READ]), np.array([-1]))
    assert not rep.can_serve(np.array([READ, READ]), np.array([1, -1]))
    assert not rep.can_serve(np.array([READ]),
                             np.array([-rep._member.size]))
    # a negative key in a NOP (padding) slot is inactive and stays servable
    assert rep.can_serve(np.array([READ, NOP]), np.array([2, -1]))


@pytest.mark.parametrize("kernels", ["jnp", "jnp+fused", "pallas_interpret",
                                     "pallas_interpret+fused"])
def test_replica_negative_key_regression_all_kernels(kernels):
    """The negative-key submit rides the full service path under every
    kernel config: it must route to the engine (never the replica
    fast-path) and the session must stay verifiable."""
    from repro.core.commit_phase import NOP, READ
    from repro.service import TxnService
    hot = zipf_hot_keys(N_NODES, N_KEYS // N_NODES, theta=0.99)
    # the wrap target (the dense table's last row) IS a replicated key,
    # so the pre-fix membership lookup reports True for key -1
    svc = TxnService(n_keys=N_KEYS, n_versions=V, T=8, O=4, sched="postsi",
                     n_nodes=N_NODES, replicas=hot, kernels=kernels)
    kind = np.array([READ, READ, NOP, NOP], np.int32)
    key = np.array([int(hot[0]), -1, 0, 0], np.int32)
    req = svc.submit(kind, key, np.zeros(4, np.int32), 0)
    assert not req.replica, "negative key served from the replica table"
    assert req.status == "queued"
    # a well-formed replicated read on the same service still fast-paths
    ok = svc.submit(np.array([READ, NOP, NOP, NOP], np.int32),
                    np.array([int(hot[0]), 0, 0, 0], np.int32),
                    np.zeros(4, np.int32), 0)
    assert ok.replica


def test_balancer_plan_falls_through_full_coldest():
    """Regression: when the globally coldest node has zero free slots the
    planner must fall through to the coldest node WITH capacity instead of
    ending the round — hot ranges stayed pinned exactly when the cluster
    was fullest."""
    pm = PlacementMap(N_KEYS, N_NODES, headroom=2)
    # fill node 1 to capacity (its own 16 keys + node 2's block = 32 slots)
    pm.apply_record(pm.move(32, 48, 1))
    assert pm.free_slots(1) == 0
    assert pm.free_slots(2) == pm.capacity
    lb = LoadBalancer(N_KEYS, N_NODES, every=1, trigger=1.25, max_moves=2)
    lb.key_ops = np.zeros(N_KEYS)
    lb.key_ops[:16] = 100.0        # node 0 scorching
    lb.key_ops[48:] = 10.0         # node 3 mild; nodes 1, 2 load 0
    # coldest by load is node 1 (argmin tie, lowest index) but it is FULL;
    # node 2 is equally cold with a whole empty block
    moves = lb.plan(pm)
    assert moves, "planner gave up with a capacity-bearing cold node idle"
    # the first split lands on node 2 (the cold node WITH headroom); later
    # moves in the round may rebalance further, but never onto a full node
    assert moves[0][2] == 2, moves
    for lo, hi, dst in moves:
        assert dst != 1, moves              # node 1 has zero free slots
        assert pm.free_slots(dst) >= hi - lo
        pm.apply_record(pm.move(lo, hi, dst))
        pm.validate()
    assert lb.imbalance(pm) < N_NODES * 100.0 / 110.0  # load actually moved


def test_balancer_counts_committed_txns_not_ops():
    """Regression: ``node_commits`` is committed-TXN occupancy (DESIGN §11
    and the bench occupancy rows) — each transaction counts ONCE at the
    owner of its first active key.  Pre-fix it counted once per committed
    op, skewing the balancer toward wide-footprint ranges."""
    pm = PlacementMap(N_KEYS, N_NODES, headroom=1)
    lb = LoadBalancer(N_KEYS, N_NODES)
    op_key = np.array([[0, 1, 2, 3],        # 4-op txn on node 0, commits
                       [16, 17, 0, 0],      # 2-op txn on node 1, commits
                       [5, 6, 0, 0]])       # 2-op txn on node 0, aborts
    active = np.array([[1, 1, 1, 1], [1, 1, 0, 0], [1, 1, 0, 0]], bool)
    committed = np.array([True, True, False])
    lb.observe(op_key, active, committed, pm.owner)
    assert lb.node_commits.tolist() == [1, 1, 0, 0], lb.node_commits
    assert lb.node_aborts.tolist() == [1, 0, 0, 0], lb.node_aborts
    # per-op traffic is untouched: all six committed ops land in key_ops
    assert lb.key_ops.sum() == 6.0
    # and the counter now matches the service's own occupancy statistic
    occ = np.zeros(N_NODES, np.int64)
    first = np.argmax(active, axis=1)
    sel = committed & active.any(axis=1)
    np.add.at(occ, pm.owner[op_key[np.arange(3), first][sel]], 1)
    assert lb.node_commits.tolist() == occ.tolist()
