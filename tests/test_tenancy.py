"""Multi-tenant fairness + same-key RMW folding tests (DESIGN.md §12).

The claims under test:

* **DRR quotas**: backlogged tenants split the wave by weight (deficits
  bank across waves, bounded), no backlogged tenant starves, spare
  capacity is work-conserving, and a single default tenant degenerates to
  the original retries-first FIFO former.
* **Admission isolation**: one tenant flooding its bounded queue cannot
  reject another tenant's arrivals; retries outrank fresh arrivals only
  *within* a tenant.
* **Folding is commit-set-equal**: with ``fold_rmw`` on, same-key
  single-op RMWs fold into one delta-summed row, and the served commit
  set + final store values equal the unfolded run — differentially across
  all seven schedulers, both kernel backends, and both substrates (the
  mesh twin runs in a child process like tests/test_distribution.py).
* **Exactly-once fan-out**: every admitted request reaches exactly one
  terminal status, committed deltas are conserved per key, and the WAL
  replays folded blocks bit-identically with honest fold accounting.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import SCHEDULERS
from repro.core.commit_phase import NOP, READ, RMW
from repro.core.workloads import tenant_poisson_arrivals
from repro.service import (RetryPolicy, TxnRequest, TxnService, WaveFormer,
                           rmw_txn_gen, tenant_txn_gen, ycsb_txn_gen)

O = 4
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _req(rid, key=0, kind=RMW, val=1, tenant=0, host=0):
    op_kind = np.full(O, NOP, np.int32)
    op_key = np.zeros(O, np.int32)
    op_val = np.zeros(O, np.int32)
    op_kind[0] = kind
    op_key[0] = key
    op_val[0] = val
    return TxnRequest(rid, op_kind, op_key, op_val, host, tenant=tenant)


def _final_vals(svc, n_keys):
    head = np.asarray(svc.store.head)
    val = np.asarray(svc.store.val)
    return [int(val[k, head[k]]) for k in range(n_keys)]


def _committed_ids(svc):
    return sorted(r.req_id for r in svc.requests if r.status == "committed")


# ------------------------------------------------------------------ DRR former

def test_drr_weighted_quota_split():
    """Two saturated tenants at 3:1 split a T=16 wave 12:4."""
    f = WaveFormer(16, O, max_queue=1000, tenants={0: 3.0, 1: 1.0})
    rid = 0
    for t in (0, 1):
        for _ in range(64):
            rid += 1
            assert f.offer(_req(rid, key=rid, tenant=t), 0)
    _, slots = f.form(1)
    counts = {0: 0, 1: 0}
    for s in slots:
        counts[s.tenant] += 1
    assert counts == {0: 12, 1: 4}, counts


def test_drr_light_tenant_never_starves():
    """A 10:1-weighted heavy tenant cannot shut the light one out: over a
    16-wave window the light tenant collects at least its banked quota."""
    f = WaveFormer(8, O, max_queue=10_000, tenants={0: 10.0, 1: 1.0})
    rid = 0
    for t in (0, 1):
        for _ in range(16 * 8 + 8):
            rid += 1
            f.offer(_req(rid, key=rid, tenant=t), 0)
    light = 0
    for w in range(16):
        _, slots = f.form(w + 1)
        assert len(slots) == 8        # work conserving under backlog
        light += sum(1 for s in slots if s.tenant == 1)
    # quantum_1 = 8/11 per wave -> >= floor(16 * 8/11) - 2 = 9
    assert light >= 9, light


def test_drr_work_conserving_when_quota_idle():
    """Spare capacity flows to whoever has backlog, uncharged: a tenant
    with weight 1 against 99 still fills the whole wave when alone."""
    f = WaveFormer(8, O, max_queue=1000, tenants={0: 99.0, 1: 1.0})
    for rid in range(1, 21):
        f.offer(_req(rid, key=rid, tenant=1), 0)
    _, slots = f.form(1)
    assert len(slots) == 8 and all(s.tenant == 1 for s in slots)


def test_retry_outranks_fresh_within_tenant_only():
    """Tenant A's due retry beats A's fresh arrival, but never eats B's
    quota slot."""
    f = WaveFormer(2, O, max_queue=100, tenants={0: 1.0, 1: 1.0})
    retry_req = _req(1, key=1, tenant=0)
    retry_req.attempts = 1
    f.requeue(retry_req, 1)
    f.offer(_req(2, key=2, tenant=0), 1)    # A fresh
    f.offer(_req(3, key=3, tenant=1), 1)    # B fresh
    _, slots = f.form(1)
    ids = {s.req_id for s in slots}
    assert ids == {1, 3}, ids               # A's retry + B's fresh
    _, slots = f.form(2)
    assert [s.req_id for s in slots] == [2]


def test_admission_isolated_per_tenant():
    """A flooding tenant sheds at its OWN bounded queue; the other tenant's
    arrivals still admit.  Unknown tenants auto-register at weight 1."""
    f = WaveFormer(4, O, max_queue=4)
    rid = 0
    for _ in range(10):
        rid += 1
        f.offer(_req(rid, key=rid, tenant=0), 0)
    for _ in range(3):
        rid += 1
        assert f.offer(_req(rid, key=rid, tenant=7), 0)
    stats = f.tenant_stats()
    assert stats[0] == {"weight": 1.0, "admitted": 4, "rejected": 6,
                        "pending": 4}
    assert stats[7]["admitted"] == 3 and stats[7]["rejected"] == 0
    assert f.admitted == 7 and f.rejected == 6   # aggregate views


def test_single_tenant_is_plain_fifo():
    """Untagged traffic through the default tenant keeps the original
    former semantics: FIFO order, due retries first, full waves."""
    f = WaveFormer(4, O)
    for rid in range(1, 7):
        f.offer(_req(rid, key=rid), 0)
    r = _req(99, key=99)
    r.attempts = 1
    f.requeue(r, 1)
    wave, slots = f.form(1)
    assert [s.req_id for s in slots] == [99, 1, 2, 3]
    np.testing.assert_array_equal(np.asarray(wave.tid),
                                  wave.tid[0] + np.arange(4))
    _, slots = f.form(2)
    assert [s.req_id for s in slots] == [4, 5, 6]


def test_explicit_tenant_map_rejects_unknown_tenants():
    """Regression: with an explicit tenant map an unregistered tag is shed
    at admission WITHOUT growing a queue or a DRR share — an open tag
    space must not scale admission capacity or dilute registered tenants'
    quotas."""
    f = WaveFormer(4, O, max_queue=8, tenants={0: 3.0, 1: 1.0})
    assert f.offer(_req(1, key=1, tenant=0), 0)
    r = _req(2, key=2, tenant=99)
    assert not f.offer(r, 0)
    assert r.status == "rejected"
    assert 99 not in f._tenants          # no queue, no rotation slot
    assert f.rejected == 1 and f.admitted == 1
    stats = f.tenant_stats()
    assert stats[99] == {"weight": 0.0, "admitted": 0, "rejected": 1,
                         "pending": 0}
    # the registered tenants' DRR split is undiluted by the stray tag
    assert stats[0]["weight"] == 3.0 and stats[1]["weight"] == 1.0


def test_auto_registration_capped_without_map():
    """Without an explicit map, tags auto-register at weight 1 only up to
    ``auto_tenant_cap``; overflow tags are shed, keeping total admission
    capacity bounded."""
    f = WaveFormer(4, O, max_queue=4, auto_tenant_cap=3)
    for t in range(5):
        assert f.offer(_req(t + 1, key=t, tenant=t), 0) == (t < 3)
    assert len(f._tenants) == 3
    assert f.admitted == 3 and f.rejected == 2


# ------------------------------------------------------------------- folding

def test_fold_unit_same_key_rmws_share_one_row():
    """Five same-(tenant, host, key) RMWs fold to one row carrying the
    delta sum; different keys, multi-op txns and READs stay unfolded."""
    f = WaveFormer(8, O, max_queue=100, fold_rmw=True)
    for rid, val in zip(range(1, 6), (1, 2, 3, 4, 5)):
        f.offer(_req(rid, key=7, val=val), 0)
    f.offer(_req(6, key=9, val=10), 0)          # other key: own row
    multi = _req(7, key=1, val=1)
    multi.op_kind[1] = RMW
    multi.op_key[1] = 2
    multi.op_val[1] = 1
    f.offer(multi, 0)                           # two ops: not foldable
    f.offer(_req(8, key=7, kind=READ, val=0), 0)  # READ: not foldable
    wave, slots = f.form(1)
    assert len(slots) == 4
    leader = slots[0]
    assert leader.req_id == 1
    assert [m.req_id for m in leader.folded] == [2, 3, 4, 5]
    assert int(np.asarray(wave.op_val)[0, 0]) == 1 + 2 + 3 + 4 + 5
    assert int(np.asarray(wave.op_val)[1, 0]) == 10
    # the whole group runs under the leader's tid, counted once each
    assert all(m.tid == leader.tid and m.status == "inflight"
               for m in leader.folded)
    assert f.fold_groups == 1 and f.folded_requests == 4


def test_fold_respects_tenant_host_and_cap():
    """Folding never crosses tenants or hosts, and ``fold_max`` bounds the
    group size."""
    f = WaveFormer(8, O, max_queue=100, tenants={0: 1.0, 1: 1.0},
                   fold_rmw=True, fold_max=2)
    f.offer(_req(1, key=5, tenant=0, host=0), 0)
    f.offer(_req(2, key=5, tenant=1, host=0), 0)   # other tenant
    f.offer(_req(3, key=5, tenant=0, host=1), 0)   # other host
    for rid in (4, 5, 6):                          # cap=2 -> two groups
        f.offer(_req(rid, key=8, tenant=1, host=0), 0)
    _, slots = f.form(1)
    groups = {s.req_id: [m.req_id for m in s.folded] for s in slots}
    assert groups == {1: [], 2: [], 3: [], 4: [5], 6: []}, groups


def test_fold_member_delta_read_at_its_own_slot():
    """Regression (lost update): a member whose single RMW sits at a
    DIFFERENT op index than the leader's must still contribute its real
    delta — folding groups by (tenant, host, key), never by op slot, and
    the pre-fix code read every member's value at the leader's slot."""
    f = WaveFormer(8, O, max_queue=100, fold_rmw=True)
    f.offer(_req(1, key=7, val=10), 0)            # leader: RMW at slot 0
    member = _req(2, key=0, val=0)
    member.op_kind[0] = NOP                       # member: RMW at slot 2
    member.op_kind[2] = RMW
    member.op_key[2] = 7
    member.op_val[2] = 7
    f.offer(member, 0)
    wave, slots = f.form(1)
    assert [m.req_id for m in slots[0].folded] == [2]
    assert int(np.asarray(wave.op_val)[0, 0]) == 17


def test_fold_mixed_slot_served_delta_conservation():
    """The slot-mix regression end-to-end: a served stream whose single
    RMWs land at random op indices still conserves per-key committed
    deltas against the final store (pre-fix, off-slot members committed
    their padding zeros — silently losing their updates)."""
    n_keys, n_ops = 8, O
    rng = np.random.RandomState(3)

    def gen():
        host = int(rng.randint(0, 2))
        op_kind = np.full(n_ops, NOP, np.int32)
        op_key = np.zeros(n_ops, np.int32)
        op_val = np.zeros(n_ops, np.int32)
        o = int(rng.randint(0, n_ops))
        op_kind[o] = RMW
        op_key[o] = host * (n_keys // 2)          # the host's hot key
        op_val[o] = 1 + int(rng.randint(0, 8))
        return op_kind, op_key, op_val, host

    svc = TxnService(n_keys, T=8, n_nodes=2, fold_rmw=True, max_queue=10_000,
                     retry=RetryPolicy(max_attempts=30, jitter=False), seed=1)
    svc.run_stream([6] * 8, gen)
    rep = svc.report()
    assert rep.folded_requests > 0
    assert svc.verify() == [], svc.verify()
    sums = np.zeros(n_keys, np.int64)
    for r in svc.requests:
        if r.status == "committed":
            np.add.at(sums, r.op_key[r.op_kind != NOP],
                      r.op_val[r.op_kind != NOP])
    assert sums.tolist() == _final_vals(svc, n_keys)


def test_fold_overflow_guard_starts_new_leader():
    """Regression: a member whose delta would push the running fold sum
    outside int32 starts a fresh leader row instead of silently wrapping
    (the engine's RMW adds int32s — a wrapped sum commits a value no
    serial unfolded execution could produce)."""
    f = WaveFormer(8, O, max_queue=100, fold_rmw=True)
    f.offer(_req(1, key=7, val=2 ** 31 - 1), 0)
    f.offer(_req(2, key=7, val=5), 0)       # would wrap: becomes new leader
    f.offer(_req(3, key=7, val=1), 0)       # folds onto req 2
    wave, slots = f.form(1)
    assert [s.req_id for s in slots] == [1, 2]
    assert [m.req_id for m in slots[1].folded] == [3]
    vals = np.asarray(wave.op_val)
    assert int(vals[0, 0]) == 2 ** 31 - 1 and int(vals[1, 0]) == 6


def test_fold_exactly_once_fanout_and_delta_conservation():
    """Served write-hot stream with folding: every admitted request lands
    exactly one terminal status, every commit is latency-counted once, and
    per-key committed deltas equal the final store values (a double
    fan-out would overcount, a lost member would undercount)."""
    n_keys = 40
    gen = rmw_txn_gen(np.random.RandomState(11), 2, n_keys // 2, theta=0.99)
    svc = TxnService(n_keys, T=8, n_nodes=2, fold_rmw=True, max_queue=10_000,
                     retry=RetryPolicy(max_attempts=30, jitter=False), seed=5)
    svc.run_stream([6] * 10, gen)
    rep = svc.report()
    assert rep.folded_requests > 0
    assert svc.verify() == [], svc.verify()
    terminal = [r for r in svc.requests if r.status in ("committed", "dropped")]
    assert len(terminal) == rep.admitted == rep.offered
    assert len(svc.latencies) == rep.committed
    sums = np.zeros(n_keys, np.int64)
    for r in svc.requests:
        if r.status == "committed":
            np.add.at(sums, r.op_key[r.op_kind != NOP],
                      r.op_val[r.op_kind != NOP])
    assert sums.tolist() == _final_vals(svc, n_keys)


def _fold_differential(sched, kernels, planner=None, seed=7):
    n_keys = 40

    def run(fold):
        gen = rmw_txn_gen(np.random.RandomState(seed), 2, n_keys // 2,
                          theta=0.99)
        svc = TxnService(n_keys, T=8, n_nodes=2, sched=sched,
                         kernels=kernels, planner=planner, fold_rmw=fold,
                         max_queue=10_000, seed=3,
                         retry=RetryPolicy(max_attempts=30, jitter=False))
        svc.run_stream([5] * 8, gen)
        assert svc.verify() == [], (sched, kernels, svc.verify())
        if fold:
            assert svc.report().folded_requests > 0, (sched, kernels)
        return _committed_ids(svc), _final_vals(svc, n_keys)

    ids0, vals0 = run(False)
    ids1, vals1 = run(True)
    assert ids0 == ids1, (sched, kernels, "commit sets diverge")
    assert vals0 == vals1, (sched, kernels, "final values diverge")


@pytest.mark.parametrize("kernels", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("sched", SCHEDULERS)
def test_fold_commit_set_equal_all_schedulers(sched, kernels):
    """Tentpole acceptance: folding is commit-set-equal to unfolded
    execution for every optimistic scheduler x kernel backend."""
    _fold_differential(sched, kernels)


@pytest.mark.parametrize("kernels", ["jnp", "pallas_interpret"])
def test_fold_commit_set_equal_planned(kernels):
    """...and for the seventh ('planned') scheduler."""
    _fold_differential("postsi", kernels, planner="planned")


def test_fold_commit_set_equal_mesh():
    """Substrate twin: the fold differential holds on the 8-virtual-device
    mesh (child process, like tests/test_distribution.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    code = r"""
import numpy as np
from repro.core.dist_engine import make_node_mesh
from repro.service import RetryPolicy, TxnService, rmw_txn_gen

mesh = make_node_mesh(4)
n_keys = 40

def run(fold):
    gen = rmw_txn_gen(np.random.RandomState(7), 4, n_keys // 4, theta=0.99)
    svc = TxnService(n_keys, T=8, n_nodes=4, mesh=mesh, fold_rmw=fold,
                     max_queue=10_000, seed=3,
                     retry=RetryPolicy(max_attempts=30, jitter=False))
    svc.run_stream([5] * 8, gen)
    assert svc.verify() == [], svc.verify()
    head = np.asarray(svc.store.head)
    val = np.asarray(svc.store.val)
    ids = sorted(r.req_id for r in svc.requests if r.status == "committed")
    return ids, [int(val[k, head[k]]) for k in range(n_keys)]

a = run(False)
b = run(True)
assert a == b, (a, b)
print("MESH-FOLD-OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH-FOLD-OK" in out.stdout


def test_wal_fold_replay_bit_identical(tmp_path):
    """A folded session's WAL replays bit-identically (the delta-summed row
    IS the executed input) and recovery surfaces the fold accounting."""
    from repro.durability import DurabilityManager, recover
    mgr = DurabilityManager(str(tmp_path))
    gen = rmw_txn_gen(np.random.RandomState(13), 2, 20, theta=0.99)
    svc = TxnService(40, T=8, n_nodes=2, fold_rmw=True, max_queue=10_000,
                     durability=mgr, seed=4,
                     retry=RetryPolicy(max_attempts=30, jitter=False))
    svc.run_stream([5] * 8, gen)
    rep = svc.report()
    assert rep.folded_requests > 0
    mgr.close()
    st = recover(str(tmp_path))
    assert len(st.history) == len(svc.history)
    for (t1, o1), (t2, o2) in zip(st.history, svc.history):
        np.testing.assert_array_equal(t1, t2)
        for name, f1, f2 in zip(o1._fields, o1, o2):
            np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2),
                                          err_msg=name)
    for f in ("val", "tid", "cid", "head"):
        np.testing.assert_array_equal(np.asarray(getattr(st.store, f)),
                                      np.asarray(getattr(svc.store, f)),
                                      err_msg=f)
    assert st.folded_requests == rep.folded_requests


def test_wal_fold_accounting_planned(tmp_path):
    """Regression: the planned scheduler logs fold multiplicities too (at
    each request's executed lane position), so recovery's fold accounting
    matches the service instead of undercounting to 0."""
    from repro.durability import DurabilityManager, recover
    mgr = DurabilityManager(str(tmp_path))
    gen = rmw_txn_gen(np.random.RandomState(13), 2, 20, theta=0.99)
    svc = TxnService(40, T=8, n_nodes=2, fold_rmw=True, planner="planned",
                     max_queue=10_000, durability=mgr, seed=4,
                     retry=RetryPolicy(max_attempts=30, jitter=False))
    svc.run_stream([5] * 8, gen)
    rep = svc.report()
    assert rep.folded_requests > 0
    mgr.close()
    st = recover(str(tmp_path))
    assert st.folded_requests == rep.folded_requests


# ------------------------------------------------------- served multi-tenant

def test_service_tenant_report_and_quota_isolation():
    """A hot RMW tenant flooding the service cannot starve a light READ
    tenant: with quotas on, the light tenant's commits track its offered
    load and the per-tenant report rows reconcile with the aggregates."""
    rng = np.random.RandomState(0)
    arr = tenant_poisson_arrivals(rng, [3.0, 24.0], 16)
    gens = [ycsb_txn_gen(np.random.RandomState(1), 4, 50, theta=0.0,
                         read_frac=0.5),
            rmw_txn_gen(np.random.RandomState(2), 4, 50, theta=0.99)]
    svc = TxnService(200, T=16, n_nodes=4, tenants={0: 1.0, 1: 1.0},
                     fold_rmw=True, seed=9)
    rep = svc.run_stream(arr, tenant_txn_gen(gens))
    assert svc.verify() == [], svc.verify()
    rows = rep.tenants
    assert set(rows) == {"0", "1"}
    assert rep.committed == sum(r["committed"] for r in rows.values())
    assert rep.offered == sum(r["offered"] for r in rows.values())
    assert rep.rejected == sum(r["rejected"] for r in rows.values())
    # the light tenant is fully served despite the hot flood
    light = rows["0"]
    assert light["committed"] == light["offered"] - light["rejected"] \
        - light["dropped"]
    assert light["committed"] > 0 and light["latency_p99"] > 0


def test_tenant_report_counts_replica_commits_separately():
    """Reads served from hot-key replicas commit at submit without passing
    admission; the tenant row must surface them as ``replica_commits`` so
    ``committed > admitted`` is explicable (committed - replica_commits
    <= admitted always holds)."""
    from repro.core.workloads import zipf_hot_keys
    hot = zipf_hot_keys(2, 10, theta=0.99)
    svc = TxnService(20, T=8, n_nodes=2, replicas=hot, seed=2)
    kind = np.full(O, NOP, np.int32)
    kind[0] = READ
    key = np.zeros(O, np.int32)
    key[0] = int(hot[0])
    for _ in range(5):
        r = svc.submit(kind, key, np.zeros(O, np.int32), 0)
        assert r.replica and r.status == "committed"
    w = _req(99, key=3)                  # one engine-path write alongside
    svc.submit(w.op_kind, w.op_key, w.op_val, w.host)
    svc.drain()
    rep = svc.report()
    row = rep.tenants["0"]
    assert row["replica_commits"] == 5
    assert row["committed"] - row["replica_commits"] <= row["admitted"]
    assert rep.replica_commits == 5
