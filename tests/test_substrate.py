"""Substrate tests: data pipeline determinism, PostSI checkpoint atomicity,
fault-tolerant runner restart, straggler policy, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import PostSICheckpointer
from repro.configs import get_reduced
from repro.data import TokenStream
from repro.launch.train import make_train_step
from repro.optim import adamw_init, adamw_update
from repro.runtime import FailureInjector, StragglerPolicy, TrainRunner


def test_tokenstream_deterministic_resume():
    cfg = get_reduced("qwen2-0.5b")
    s1 = TokenStream(cfg, 4, 16, seed=7)
    b0, b1, b2 = s1.next(), s1.next(), s1.next()
    s2 = TokenStream(cfg, 4, 16, seed=7)
    s2.restore({"step": 2, "seed": 7, "host_id": 0, "host_count": 1})
    b2b = s2.next()
    np.testing.assert_array_equal(np.asarray(b2["tokens"]), np.asarray(b2b["tokens"]))


def test_tokenstream_host_sharding_disjoint():
    cfg = get_reduced("qwen2-0.5b")
    a = TokenStream(cfg, 8, 16, seed=3, host_count=2, host_id=0).next()
    b = TokenStream(cfg, 8, 16, seed=3, host_count=2, host_id=1).next()
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ck = PostSICheckpointer(str(tmp_path), tree)
    assert ck.save(5, tree)
    step, out = ck.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_checkpoint_atomic_snapshot_no_torn_read(tmp_path):
    """The paper's guarantee as a framework feature: a reader transaction
    interleaved with a writer sees the OLD checkpoint atomically, never a
    mix.  This is exactly the partial-visibility anomaly CV forbids."""
    tree = {"w0": jnp.zeros((2,)), "w1": jnp.zeros((2,))}
    ck = PostSICheckpointer(str(tmp_path), tree)
    assert ck.save(1, {"w0": jnp.ones((2,)) * 1, "w1": jnp.ones((2,)) * 1})

    # writer txn of checkpoint 2 starts and writes w0... (not yet committed)
    sched = ck.sched
    wtid = sched.begin()
    key_w0 = ck.key_of[[k for k in ck.paths if "w0" in k][0]]
    sched.write(wtid, key_w0, 999)

    # reader comes now: must see checkpoint-1 handles for BOTH leaves
    step, out = ck.restore(tree)
    assert step == 1
    assert float(out["w0"][0]) == 1.0 and float(out["w1"][0]) == 1.0
    sched.abort(wtid)

    # after a full save(2), reader sees both new leaves
    assert ck.save(2, {"w0": jnp.ones((2,)) * 2, "w1": jnp.ones((2,)) * 2})
    step, out = ck.restore(tree)
    assert step == 2 and float(out["w0"][0]) == 2.0 and float(out["w1"][0]) == 2.0


def test_checkpoint_gc(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ck = PostSICheckpointer(str(tmp_path), tree)
    for i in range(5):
        ck.save(i + 1, {"a": jnp.ones((2,)) * i})
    removed = ck.gc(keep_latest=2)
    assert removed >= 1
    step, out = ck.restore(tree)
    assert step == 5


def test_runner_restart_after_failure(tmp_path):
    cfg = get_reduced("qwen2-0.5b")
    model, step_fn = make_train_step(cfg, lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    stream = TokenStream(cfg, 2, 16, seed=1)
    tree_ex = {"params": params, "opt": opt, "data": {"step": jnp.asarray(0)}}
    ck = PostSICheckpointer(str(tmp_path), tree_ex)
    runner = TrainRunner(jax.jit(step_fn), stream, ck, ckpt_every=4)
    inj = FailureInjector(fail_at=(6,))
    out = runner.run(params, opt, 10, injector=inj)
    assert out["restarts"] == 1
    assert out["final_step"] == 10
    assert all(np.isfinite(out["losses"]))
    # after restore at step 4, steps 4..10 were re-run: 10 + (6-4) losses
    assert len(out["losses"]) == 12


def test_straggler_policy_flags_outlier():
    pol = StragglerPolicy(threshold=3.0)
    for step in range(20):
        flagged = pol.record(step, 0.1 + 0.001 * (step % 3), worker=0)
        assert not flagged
    assert pol.record(20, 1.5, worker=0)
    assert pol.grad_scale(16, 1) == pytest.approx(16 / 15)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw ||w||^2
        params, opt, _ = adamw_update(params, grads, opt, lr=5e-2,
                                      weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_server_hot_swap_atomicity():
    """launch.serve.Server: batches always see one atomic weight version,
    publishes land between batches, generation shapes are right."""
    from repro.launch.serve import Server

    cfg = get_reduced("qwen2-0.5b").replace(vocab_size=512)
    from repro.models.model import build
    model = build(cfg)
    p0 = model.init(jax.random.PRNGKey(0))
    p1 = model.init(jax.random.PRNGKey(1))
    srv = Server(cfg, p0, batch_size=2)
    rng = np.random.RandomState(0)
    toks = rng.randint(0, cfg.vocab_size, (2, 16)).astype(np.int32)

    r0 = srv.serve_batch(toks, max_new_tokens=3)
    assert r0["generated"].shape == (2, 3)
    assert r0["weight_version"] == 0
    assert srv.publish(p1)
    r1 = srv.serve_batch(toks, max_new_tokens=3)
    assert r1["weight_version"] == 1
    assert srv.stats.batches == 2 and srv.stats.publishes == 1
    # different weights -> (almost surely) different generations
    assert not np.array_equal(r0["generated"], r1["generated"])
