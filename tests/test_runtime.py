"""Unit tests for the runtime fault plane: the straggler detector
(runtime/straggler.py — seed code with zero coverage) and the fault
injector that generalizes it (runtime/faults.py, DESIGN.md §9).
"""
import numpy as np
import pytest

from repro.runtime import Fault, FaultSchedule, InjectedCrash, StragglerPolicy


# --------------------------------------------------------------- straggler
class TestStragglerPolicy:
    def test_no_flag_before_window_warms_up(self):
        p = StragglerPolicy()
        # fewer than 8 observations: even a 100x outlier is not flagged
        for step in range(7):
            assert not p.record(step, 1.0)
        assert not p.record(7, 100.0)

    def test_flags_outlier_after_warmup(self):
        p = StragglerPolicy(threshold=4.0)
        for step in range(8):
            p.record(step, 1.0 + 0.01 * (step % 3))
        assert p.record(8, 50.0, worker=0)
        assert p.flags and p.flags[-1][0] == 8 and p.flags[-1][1] == 0

    def test_threshold_scales_sensitivity(self):
        def flagged_at(threshold, dt):
            p = StragglerPolicy(threshold=threshold)
            for step in range(8):
                p.record(step, 1.0 + 0.05 * (step % 4))
            return p.record(8, dt)
        # a mild outlier trips a tight threshold but not a loose one
        assert flagged_at(2.0, 1.6)
        assert not flagged_at(20.0, 1.6)

    def test_per_worker_isolation(self):
        p = StragglerPolicy()
        for step in range(10):
            p.record(step, 1.0, worker=0)
            p.record(step, 10.0, worker=1)    # slow but *consistent*
        assert not p.record(10, 10.0, worker=1)   # its own model: normal
        assert p.record(10, 3.0, worker=0)        # 3x its model: straggler

    def test_window_forgets_old_regime(self):
        p = StragglerPolicy(window=8)
        for step in range(8):
            p.record(step, 1.0)
        for step in range(8, 24):     # regime change: 5x slower, stabilizes
            p.record(step, 5.0 + 0.1 * (step % 4))
        assert not p.record(24, 5.2)  # old fast regime fell out of window

    def test_grad_scale_unbiased(self):
        p = StragglerPolicy(action="skip")
        assert p.grad_scale(8, 0) == 1.0
        assert p.grad_scale(8, 2) == pytest.approx(8 / 6)
        assert p.grad_scale(1, 1) == 1.0      # never divides by zero

    def test_rebalance_share_inverse_mean(self):
        p = StragglerPolicy(action="rebalance")
        for step in range(4):
            p.record(step, 1.0, worker=0)
            p.record(step, 3.0, worker=1)
        s0, s1 = p.share(0, 2), p.share(1, 2)
        assert s0 == pytest.approx(0.75) and s1 == pytest.approx(0.25)
        assert p.share(7, 2) == 0.5           # unknown worker: uniform


# ------------------------------------------------------------ fault plane
class TestFaultSchedule:
    def test_kill_fires_on_nth_visit_only(self):
        s = FaultSchedule([Fault("kill", "retire", 2)])
        s.at_retire()
        s.at_retire()
        with pytest.raises(InjectedCrash, match="kill at retire#2"):
            s.at_retire()
        assert s.crashed is not None and s.crashed.at == 2

    def test_seams_counted_independently(self):
        s = FaultSchedule([Fault("kill", "post_log", 1)])
        for _ in range(5):
            s.at_dispatch()
            s.at_retire()
        s.post_log()
        with pytest.raises(InjectedCrash):
            s.post_log()

    def test_delay_budget_is_finite(self):
        s = FaultSchedule([Fault("delay_retire", "retire", 0, arg=3)])
        s.at_retire()                         # arms the budget
        assert [s.delay_retire() for _ in range(5)] == \
            [True, True, True, False, False]
        assert s.delays_taken == 3

    def test_fault_fires_once(self):
        s = FaultSchedule([Fault("delay_retire", "retire", 0, arg=1)])
        s.at_retire()
        assert s.delay_retire()
        s.at_retire()                         # visit 1: fault already fired
        assert not s.delay_retire()

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault("segfault", "retire", 0)

    def test_pure_kill_classification(self):
        assert FaultSchedule([Fault("kill", "retire", 1),
                              Fault("torn_tail", "wal", 0, arg=9)]).pure_kill
        assert not FaultSchedule(
            [Fault("delay_retire", "retire", 0, arg=1),
             Fault("kill", "retire", 1)]).pure_kill

    def test_random_is_seed_deterministic(self):
        a, b = FaultSchedule.random(123), FaultSchedule.random(123)
        assert [(f.kind, f.point, f.at, f.arg) for f in a.faults] == \
            [(f.kind, f.point, f.at, f.arg) for f in b.faults]
        c = FaultSchedule.random(124)
        assert a.faults != c.faults or a.seed != c.seed
        # every random schedule carries exactly one terminal kill
        for seed in range(30):
            s = FaultSchedule.random(seed)
            assert sum(f.kind == "kill" for f in s.faults) == 1

    def test_mutilate_wal_tears_scheduled_bytes(self, tmp_path):
        p = tmp_path / "wal.log"
        p.write_bytes(b"x" * 100)
        s = FaultSchedule([Fault("kill", "retire", 0),
                           Fault("torn_tail", "wal", 0, arg=30)])
        assert s.mutilate_wal(str(p)) == 30
        assert p.stat().st_size == 70
        assert FaultSchedule([Fault("kill", "retire", 0)]) \
            .mutilate_wal(str(p)) == 0        # no tear scheduled
