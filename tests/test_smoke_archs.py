"""Per-architecture smoke tests: reduced config of the same family, one
forward + train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced, SHAPES, applicable
from repro.launch.inputs import make_batch
from repro.models.model import build
from repro.models.module import count_params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assigned = {
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "mamba2-130m": (24, 768, None, None, 0, 50280),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }[arch]
    L, d, H, KH, ff, V = assigned
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab_size == V
    if H is not None:
        assert cfg.n_heads == H and cfg.n_kv_heads == KH
    assert cfg.d_ff == ff or (cfg.moe and cfg.d_ff_expert == ff)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_reduced(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S, "train")

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p2 = jax.tree_util.tree_map(lambda w, g: w - 1e-3 * g.astype(w.dtype), p, grads)
        return loss, p2

    loss, params2 = step(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    finite = jax.tree_util.tree_reduce(
        lambda a, x: a and bool(jnp.isfinite(x).all()), params2, True)
    assert finite, f"{arch}: non-finite params after update"
    # loss should move under a step
    loss2, _ = step(params2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_reduced(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = make_batch(cfg, B, S, "prefill")
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()

    dbatch, dcache = make_batch(cfg, B, S, "decode")
    logits2, cache2 = jax.jit(model.decode)(params, dcache, dbatch)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits2).all()
    assert int(cache2["len"]) == S + 1


def test_param_count_estimates():
    # full-size configs should land in the right parameter class
    expect = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "qwen3-14b": (12e9, 17e9),
        "deepseek-coder-33b": (28e9, 38e9),
        "yi-9b": (7.5e9, 10.5e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "qwen2-vl-2b": (1.2e9, 2.2e9),
        "seamless-m4t-large-v2": (1.0e9, 2.4e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        model = build(cfg)
        n = count_params(model.param_specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]"


def test_shape_applicability():
    # 40 cells total; long_500k runs only for ssm/hybrid
    live = sum(applicable(get_config(a).family, s) for a in ARCH_IDS for s in SHAPES)
    assert live == 32
    assert applicable("ssm", "long_500k") and not applicable("dense", "long_500k")
