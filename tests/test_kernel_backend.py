"""Unified kernel-backend plane tests (DESIGN.md §7).

* ``KernelConfig`` resolution semantics and the deprecated
  ``set_potential_backend`` shim;
* a backend sentinel: the engine read path (``run_wave_on`` over a
  ``LocalSubstrate``) really dispatches ``ops.version_scan`` — the kernel
  is live end-to-end, not just in microbenchmarks;
* the six-scheduler differential: bit-identical ``WaveOut`` histories and
  final stores under ``jnp`` vs ``pallas_interpret`` on the LocalSubstrate,
  per-wave AND fused (the MeshSubstrate twin lives in
  ``tests/test_distribution.py`` — it needs a multi-device child process);
* a hypothesis property over random waves;
* the masked/NOP-key regression: a wave padded with NEGATIVE keys (the
  nastiest padding convention — negative indexing silently wraps) runs
  bit-identically to one padded with key 0, and ``store.evicting_visible``
  never reports the last key's eviction state for a padded key.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (SCHEDULERS, KernelConfig, make_store, resolve,
                        run_workload, run_workload_fused)
from repro.core.commit_phase import NOP
from repro.core.engine import Wave, run_wave, run_wave_on
from repro.core.store import evicting_visible, install_version
from repro.core.substrate import LocalSubstrate
from repro.core.workloads import micro_waves, smallbank_waves
from repro.kernels import ops

BACKENDS = ("jnp", "pallas_interpret")
# the four CPU-runnable configs: each backend, three-dispatch and fused
CONFIGS = ("jnp", "pallas_interpret", "jnp+fused", "pallas_interpret+fused")


# ------------------------------------------------------------------ config
def test_kernel_config_resolution():
    assert KernelConfig("jnp").backend == "jnp"
    assert not KernelConfig("jnp").use_pallas
    cfg = KernelConfig("pallas_interpret")
    assert cfg.use_pallas and cfg.interpret
    auto = KernelConfig("auto")
    assert auto.backend in ("pallas", "pallas_interpret")   # never "auto"
    assert resolve(cfg) is cfg
    assert resolve("jnp") == KernelConfig("jnp")
    assert resolve(None).backend in ("pallas", "pallas_interpret", "jnp")
    with pytest.raises(AssertionError):
        KernelConfig("cuda")


def test_kernel_config_fused_spec():
    """The ``+fused`` suffix and the ``fused`` field are the same knob, it
    survives resolution, and the spec string round-trips."""
    cfg = KernelConfig("pallas_interpret+fused")
    assert cfg.backend == "pallas_interpret" and cfg.fused
    assert cfg == KernelConfig("pallas_interpret", fused=True)
    assert cfg.name == "pallas_interpret+fused"
    assert resolve(cfg.name) == cfg
    assert KernelConfig("auto+fused").fused
    assert not KernelConfig("jnp").fused
    assert KernelConfig("jnp").name == "jnp"
    with pytest.raises(AssertionError):
        KernelConfig("cuda+fused")


def test_set_potential_backend_shim_forwards_and_warns():
    from repro.core import potential_backend, set_potential_backend
    from repro.kernels import default_backend
    before = default_backend()
    try:
        with pytest.warns(DeprecationWarning):
            set_potential_backend("jnp")
        assert default_backend() == "jnp"
        assert potential_backend() == "jnp"
    finally:
        from repro.kernels import set_default_backend
        set_default_backend(before)


# ---------------------------------------------------------------- sentinel
def test_version_scan_dispatched_on_engine_read_path(monkeypatch):
    """The engine's read phase must route slot selection through
    ``ops.version_scan`` (the dormant-kernel wiring this refactor exists
    for), with the configured backend flags."""
    calls = []
    real = ops.version_scan

    def spy(cids, tids, max_cid, **kw):
        calls.append(kw)
        return real(cids, tids, max_cid, **kw)

    monkeypatch.setattr(ops, "version_scan", spy)
    rng = np.random.RandomState(0)
    waves = micro_waves(rng, 1, 8, 2, 16, n_ops=3)
    store = make_store(32, 4)
    sub = LocalSubstrate("pallas_interpret")
    # run_wave_on un-jitted: the single copy of the rules, traced fresh
    run_wave_on(sub, store, waves[0], jnp.int32(1), jnp.int32(1),
                jnp.int32(2), sched="postsi")
    assert calls, "engine read path never dispatched ops.version_scan"
    assert all(kw["use_pallas"] and kw["interpret"] for kw in calls)
    calls.clear()
    run_wave_on(LocalSubstrate("jnp"), store, waves[0], jnp.int32(1),
                jnp.int32(1), jnp.int32(2), sched="postsi")
    assert calls and all(not kw["use_pallas"] for kw in calls)


# ------------------------------------------------- six-sched differential
def _assert_same(h1, s1, st1, h2, s2, st2, tag):
    assert s1 == s2, (tag, s1, s2)
    for (t1, o1), (t2, o2) in zip(h1, h2):
        np.testing.assert_array_equal(t1, t2)
        for name, f1, f2 in zip(o1._fields, o1, o2):
            np.testing.assert_array_equal(f1, f2, err_msg=f"{tag}.{name}")
    for name, f1, f2 in zip(st1._fields, st1, st2):
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2),
                                      err_msg=f"{tag}.store.{name}")


@pytest.mark.parametrize("sched", SCHEDULERS)
def test_backends_bit_identical_local(sched):
    """jnp vs pallas_interpret, three-dispatch vs fused megakernel: same
    WaveOut history and final store for every scheduler, on both the
    per-wave and the scan driver."""
    rng = np.random.RandomState(1)
    n_nodes, kpn, W, T = 4, 60, 4, 16
    waves = smallbank_waves(rng, W, T, n_nodes, kpn, dist_frac=0.5,
                            hot_frac=0.4, hot_per_node=4)
    hs = np.array([0, 1, 1, 2], np.int32) if sched == "clocksi" else None
    runs = {}
    for bk in CONFIGS:
        runs[bk] = {
            "perwave": run_workload(
                make_store(n_nodes * kpn, 8), waves, sched=sched,
                n_nodes=n_nodes, host_skew=hs, gc_track=True, kernels=bk),
            "fused": run_workload_fused(
                make_store(n_nodes * kpn, 8), waves, sched=sched,
                n_nodes=n_nodes, host_skew=hs, gc_track=True, kernels=bk),
        }
    for driver in ("perwave", "fused"):
        st1, h1, s1 = runs[CONFIGS[0]][driver]
        for bk in CONFIGS[1:]:
            st2, h2, s2 = runs[bk][driver]
            _assert_same(h1, s1, st1, h2, s2, st2, f"{sched}.{driver}.{bk}")
    # and fused == perwave within each config (the §7 contract holds per
    # config, not just for the default)
    for bk in CONFIGS:
        st1, h1, s1 = runs[bk]["perwave"]
        st2, h2, s2 = runs[bk]["fused"]
        _assert_same(h1, s1, st1, h2, s2, st2, f"{sched}.{bk}.fusedvswave")


def test_planned_scheduler_fused_kernel_bit_identical():
    """The seventh scheduler ("planned", PR 7) dispatches through
    ``step_block``; the fused megakernel must leave its lane execution
    bit-identical too — outcomes, stores, and the zero-abort invariant."""
    from repro.planner import run_workload_planned
    rng = np.random.RandomState(5)
    n_nodes, kpn, W, T = 4, 16, 3, 16
    waves = smallbank_waves(rng, W, T, n_nodes, kpn, dist_frac=0.5,
                            hot_frac=0.5, hot_per_node=3)
    runs = [run_workload_planned(make_store(n_nodes * kpn, 8), waves,
                                 sched="postsi", n_nodes=n_nodes, kernels=bk)
            for bk in CONFIGS]
    st1, h1, s1 = runs[0]
    assert s1.aborted == 0
    for (st2, h2, s2), bk in zip(runs[1:], CONFIGS[1:]):
        # plan_s is host wall-clock — everything else must match exactly
        assert s1._replace(plan_s=0) == s2._replace(plan_s=0), (bk, s1, s2)
        for (t1, o1), (t2, o2) in zip(h1, h2):
            np.testing.assert_array_equal(t1, t2)
            for name, f1, f2 in zip(o1._fields, o1, o2):
                np.testing.assert_array_equal(f1, f2,
                                              err_msg=f"planned.{bk}.{name}")
        for name, f1, f2 in zip(st1._fields, st1, st2):
            np.testing.assert_array_equal(
                np.asarray(f1), np.asarray(f2),
                err_msg=f"planned.{bk}.store.{name}")


def test_backends_hypothesis_random_waves():
    """Property: for random waves (mixed reads / blind writes / RMWs, random
    contention), the two CPU backends commit the same set with identical
    intervals under every drawn scheduler."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n_nodes, kpn, T = 4, 16, 12

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2 ** 16), sched=st.sampled_from(SCHEDULERS),
           read_ratio=st.sampled_from([0.2, 0.6]),
           blind_frac=st.sampled_from([0.0, 0.8]))
    def check(seed, sched, read_ratio, blind_frac):
        waves = micro_waves(np.random.RandomState(seed), 1, T, n_nodes, kpn,
                            n_ops=3, read_ratio=read_ratio, dist_frac=0.5,
                            hot_frac=0.6, hot_per_node=2,
                            blind_frac=blind_frac)
        hs = (np.array([0, 1, 0, 2], np.int32) if sched == "clocksi"
              else None)
        st1, h1, s1 = run_workload(make_store(n_nodes * kpn, 4), waves,
                                   sched=sched, n_nodes=n_nodes,
                                   host_skew=hs, kernels="jnp")
        st2, h2, s2 = run_workload(make_store(n_nodes * kpn, 4), waves,
                                   sched=sched, n_nodes=n_nodes,
                                   host_skew=hs, kernels="pallas_interpret")
        _assert_same(h1, s1, st1, h2, s2, st2, f"{sched}/{seed}")

    check()


# ------------------------------------------------ masked/NOP key guarding
def _nop_padded_wave(pad_key: int, T: int = 8, O: int = 3) -> Wave:
    """Half-real wave: rows T//2.. are NOP padding carrying ``pad_key``."""
    rng = np.random.RandomState(9)
    (wave,) = micro_waves(rng, 1, T, 2, 8, n_ops=O, read_ratio=0.4,
                          dist_frac=0.5, hot_frac=0.5, hot_per_node=2)
    kind = np.asarray(wave.op_kind).copy()
    key = np.asarray(wave.op_key).copy()
    val = np.asarray(wave.op_val).copy()
    kind[T // 2:] = NOP
    key[T // 2:] = pad_key
    val[T // 2:] = 0
    return wave._replace(op_kind=jnp.asarray(kind), op_key=jnp.asarray(key),
                         op_val=jnp.asarray(val))


@pytest.mark.parametrize("kernels", CONFIGS)
def test_negative_key_nop_padding_regression(kernels):
    """A wave NOP-padded with key -1 (negative padding would wrap to the
    LAST key under minimum-clamping) or with a HOT real key (the clamp
    sentinel collision the fused-kernel audit guards) must produce the
    exact same WaveOut, final store and GC accounting as one padded with
    key 0 — on every backend x fusion config."""
    n_keys = 16
    outs = []
    for pad_key in (0, -1, 3):
        wave = _nop_padded_wave(pad_key)
        store = make_store(n_keys, 2)      # V=2: wraps fast, GC check live
        # wrap every ring so evicting_visible has real evictions to see
        for v in range(3):
            store, _ = install_version(
                store, jnp.arange(n_keys), jnp.full((n_keys,), v),
                jnp.int32(1), jnp.int32(v + 1), jnp.int32(0))
        st, out, _ = run_wave(store, wave, jnp.int32(1), jnp.int32(10),
                              jnp.int32(2), sched="postsi", gc_track=True,
                              watermark=jnp.int32(0), kernels=kernels)
        outs.append((st, out))
    (st0, o0) = outs[0]
    for st1, o1 in outs[1:]:
        for name, f1, f2 in zip(o0._fields, o0, o1):
            np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2),
                                          err_msg=f"padkey.{name}")
        for name, f1, f2 in zip(st0._fields, st0, st1):
            np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2),
                                          err_msg=f"padkey.store.{name}")


def test_evicting_visible_clamps_negative_keys():
    """Direct unit check of the clip guard: key -1 must NOT report the last
    key's eviction state (negative-index wraparound)."""
    store = make_store(8, 2)
    # wrap ONLY the last key's ring so it (and nothing else) would evict
    for v in range(3):
        store, _ = install_version(store, jnp.int32(7), jnp.int32(v),
                                   jnp.int32(1), jnp.int32(v + 1),
                                   jnp.int32(0))
    wm = jnp.int32(0)
    assert bool(evicting_visible(store, jnp.int32(7), wm))
    assert not bool(evicting_visible(store, jnp.int32(0), wm))
    # the padding sentinel clamps to key 0, never wraps to key 7
    assert not bool(evicting_visible(store, jnp.int32(-1), wm))
    np.testing.assert_array_equal(
        np.asarray(evicting_visible(store, jnp.asarray([-1, -8, 0, 7]), wm)),
        [False, False, False, True])


@pytest.mark.parametrize("kernels", BACKENDS)
def test_substrate_read_clamps_negative_keys(kernels):
    """Substrate read path: negative padding keys resolve like key 0 instead
    of wrapping to the last key."""
    store = make_store(8, 4)
    store = store._replace(val=store.val.at[:, 0].set(
        jnp.arange(8, dtype=jnp.int32) * 10))
    sub = LocalSubstrate(kernels)
    val, tid, cid, sid, slot = sub.read_newest(
        store, jnp.asarray([-1, 0, 7], jnp.int32))
    np.testing.assert_array_equal(np.asarray(val), [0, 0, 70])
