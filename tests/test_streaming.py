"""Differential conformance suite for the pipelined streaming service plane
(DESIGN.md §8).

The streaming driver (``TxnService.run_streaming``: K-blocks-in-flight fused
dispatch over ``engine.run_block``) is locked to the per-wave step loop:

* ``B=1, K=1`` is **bit-identical** to ``run_stream`` — every wave's full
  ``WaveOut`` history (commits, induced intervals, CIDs), every request's
  fate/TID/latency, for all six schedulers, on the single device here and
  on the mesh in ``test_streaming_mesh_*`` (child process, 8 virtual
  devices, like every mesh test).
* ``B ∈ {2, 4}`` is **commit-set-equal modulo retry timing**: with a retry
  budget generous enough that nothing drops, the exact set of committed
  requests matches the step loop and the history still verifies.
* **Oracle coverage**: the post-hoc verifiers (``core/verify.py``) run over
  *streaming* histories for every scheduler — si/dsi/clocksi/postsi pass
  ``verify_si``, cv passes ``verify_cv`` (optimal is excluded by design:
  the paper's upper bound is not guaranteed correct).

Plus units for the bounded-AIMD ``AdaptiveWaveSizer`` and a hypothesis
property (marked ``slow``, run by the CI slow leg) over random arrival
processes × zipf skew: every enqueued transaction commits exactly once or
is reported dropped, and the GC watermark handed to every dispatch never
passes a pinned reader's snapshot floor.
"""
import numpy as np
import pytest

from repro.core import ABORTED, COMMITTED, SCHEDULERS
from repro.core.verify import verify_cv, verify_si
from repro.core.workloads import bursty_arrivals, poisson_arrivals
from repro.service import (AdaptiveWaveSizer, RetryPolicy, StreamingDriver,
                           TxnService, ycsb_txn_gen)

T = 16
N_NODES, KPN = 4, 40


def _host_skew(sched):
    return (np.round(np.linspace(0, 2, N_NODES)).astype(np.int32)
            if sched == "clocksi" else None)


def _session(mode, sched, B=1, K=1, sizer=None, theta=0.9, read_frac=0.5,
             max_attempts=6, n_ticks=10, rate=12.0, seed=3, skew=True,
             bursty=False):
    """One served session; ``mode`` picks the step loop or the streaming
    plane over the identical request stream (same seeds everywhere)."""
    svc = TxnService(n_keys=N_NODES * KPN, T=T, sched=sched, n_nodes=N_NODES,
                     retry=RetryPolicy(max_attempts=max_attempts),
                     host_skew=_host_skew(sched) if skew else None, seed=seed)
    gen = ycsb_txn_gen(np.random.RandomState(seed + 100), N_NODES, KPN,
                       theta=theta, read_frac=read_frac, dist_frac=0.3)
    arr_rng = np.random.RandomState(seed + 200)
    arr = (bursty_arrivals(arr_rng, rate, n_ticks) if bursty
           else poisson_arrivals(arr_rng, rate, n_ticks))
    if mode == "step":
        rep = svc.run_stream(arr, gen)
    else:
        rep = svc.run_streaming(arr, gen, B=B, K=K, sizer=sizer)
    return svc, rep


def _assert_history_bit_identical(a, b):
    assert len(a.history) == len(b.history)
    for (ta, oa), (tb, ob) in zip(a.history, b.history):
        np.testing.assert_array_equal(ta, tb)
        for fa, fb, name in zip(oa, ob, oa._fields):
            np.testing.assert_array_equal(fa, fb, err_msg=name)


# ------------------------------------------------------- B=1 K=1 identity
@pytest.mark.parametrize("sched", SCHEDULERS)
def test_streaming_b1k1_bit_identical_to_step(sched):
    """The degenerate pipeline IS the step loop: full WaveOut history and
    every request's fate/TID/interval/latency, per scheduler."""
    a, ra = _session("step", sched)
    b, rb = _session("stream", sched, B=1, K=1)
    _assert_history_bit_identical(a, b)
    for qa, qb in zip(a.requests, b.requests):
        assert (qa.status, qa.tid, qa.tids, qa.attempts, qa.commit_tick,
                qa.s, qa.c) == (qb.status, qb.tid, qb.tids, qb.attempts,
                                qb.commit_tick, qb.s, qb.c)
    assert (ra.committed, ra.dropped, ra.retries, ra.waves, ra.rejected,
            ra.idle_ticks) == (rb.committed, rb.dropped, rb.retries,
                               rb.waves, rb.rejected, rb.idle_ticks)
    assert (ra.latency_p50, ra.latency_p95, ra.latency_p99) == \
           (rb.latency_p50, rb.latency_p95, rb.latency_p99)


# --------------------------------------------------- B>1 commit-set equal
@pytest.mark.parametrize("B,K", [(2, 2), (4, 2)])
def test_streaming_blocks_commit_set_equal(B, K):
    """Block pipelining only re-times retries: with a retry budget generous
    enough that nothing drops, the committed request set matches the step
    loop exactly and the streamed history verifies."""
    a, ra = _session("step", "postsi", max_attempts=12)
    b, rb = _session("stream", "postsi", B=B, K=K, max_attempts=12)
    assert ra.dropped == 0 and rb.dropped == 0
    assert ra.admitted == rb.admitted
    commits = lambda svc: {r.req_id for r in svc.requests
                           if r.status == "committed"}
    assert commits(a) == commits(b)
    assert rb.blocks > 0
    assert b.verify() == []


# ------------------------------------------------------------ oracle pass
@pytest.mark.parametrize("sched", ["postsi", "si", "dsi", "clocksi", "cv"])
def test_streaming_history_passes_oracle(sched):
    """core/verify.py over *streaming* histories: SI validity (snapshot
    reads + disjoint writer intervals) for the SI family, CV validity for
    cv — plus final-store-matches-serial-replay via ``svc.verify``.
    clocksi runs with zero skew here: skewed hosts read stale snapshots by
    design (the paper's §II anomaly), which is measured, not verified."""
    svc, rep = _session("stream", sched, B=2, K=2, skew=False,
                        max_attempts=8)
    assert rep.committed > 0
    check = verify_cv if sched == "cv" else verify_si
    assert check(svc.history) == []
    assert svc.verify() == []


def test_streaming_bursty_zipf_serves_and_verifies():
    """Bursty MMPP arrivals × heavy zipf skew through the full pipeline:
    load is shed at admission, retries happen, invariants hold."""
    svc, rep = _session("stream", "postsi", B=4, K=2, theta=1.2,
                        read_frac=0.2, bursty=True, n_ticks=12)
    assert rep.offered == rep.admitted + rep.rejected
    assert rep.committed + rep.dropped == rep.admitted
    assert rep.committed > 0 and rep.retries > 0
    assert svc.verify() == []


# -------------------------------------------------------- adaptive sizing
def test_adaptive_sizer_aimd_ladder():
    s = AdaptiveWaveSizer(T0=64, t_min=8, window=10)
    assert s.T == 64
    s.observe(10, 8)                     # 80% aborts: halve
    assert s.T == 32 and s.decreases == 1
    s.observe(10, 9)
    assert s.T == 16
    s.observe(10, 10)
    s.observe(10, 10)
    assert s.T == 8                      # floor: never below t_min
    s.observe(10, 10)
    assert s.T == 8
    for _ in range(40):                  # calm: climb one quantum per window
        s.observe(10, 0)
    assert s.T == 64 and s.increases >= 7   # ceiling: never above t_max
    s.observe(10, 2)                     # 20% is inside the deadband
    assert s.T == 64
    assert s.abort_rate() > 0            # deadband keeps a trailing window
    # the deadband must not accumulate an unbounded average: after a long
    # calm-ish plateau, a contention spike still reacts within ~one window
    for _ in range(50):
        s.observe(10, 2)                 # 500 deadband executions
    s.observe(10, 10)                    # spike
    assert s.T == 32                     # reacted immediately, not 100s later


def test_driver_honors_caller_block_size_with_non_adapting_sizer():
    """A sizer that only adapts T (adapt_B=False, the default) must not
    silently replace run_streaming's B with its own B0: blocks still
    batch multiple waves."""
    sizer = AdaptiveWaveSizer(T0=T)      # B0 defaults to 1, adapt_B=False
    svc, rep = _session("stream", "postsi", B=4, K=2, sizer=sizer,
                        max_attempts=8)
    assert rep.blocks < rep.waves        # real multi-wave blocks shipped
    assert svc.verify() == []


def test_adaptive_sizer_adapts_block_size():
    s = AdaptiveWaveSizer(T0=32, B0=4, t_min=8, window=4, adapt_B=True)
    s.observe(4, 4)
    assert s.B == 2                      # shorter pipeline under contention
    s.observe(4, 4)
    s.observe(4, 4)
    assert s.B == 1                      # floor at b_min
    for _ in range(3):
        s.observe(4, 0)
    assert s.B == 4                      # restored to B0 when calm


def test_adaptive_sizer_and_driver_validate_args():
    with pytest.raises(ValueError):
        AdaptiveWaveSizer(T0=32, high=0.1, low=0.5)
    with pytest.raises(ValueError):
        AdaptiveWaveSizer(T0=4, t_min=8)     # empty ladder: t_max < t_min


def test_adaptive_sizer_off_quantum_ceiling_reachable():
    """t_max is always a rung: a T0 that is not a multiple of the quantum
    must be honored at construction and restorable by additive increase."""
    s = AdaptiveWaveSizer(T0=12, t_min=8, window=10)
    assert s.T == 12                         # not floored to 8
    s.observe(10, 8)
    assert s.T == 8                          # MD onto the quantum rung
    s.observe(10, 0)
    assert s.T == 12                         # AI reaches the ceiling again
    svc = TxnService(n_keys=N_NODES * KPN, T=T, n_nodes=N_NODES)
    with pytest.raises(ValueError):
        StreamingDriver(svc, B=0, K=1)
    with pytest.raises(ValueError):
        StreamingDriver(svc, B=2, K=0)


def test_adaptive_streaming_regulates_contention():
    """§V-D in open-stream form: a write-heavy, heavily-skewed stream drives
    the trailing abort rate over the threshold and the sizer shrinks T;
    every invariant still holds and the history verifies."""
    sizer = AdaptiveWaveSizer(T0=T, B0=2, t_min=4, window=24, adapt_B=True)
    svc, rep = _session("stream", "postsi", B=2, K=2, sizer=sizer,
                        theta=1.2, read_frac=0.1, max_attempts=8,
                        n_ticks=12, rate=14.0)
    assert sizer.decreases >= 1          # contention actually regulated
    assert sizer.T < T
    assert rep.committed + rep.dropped == rep.admitted
    assert svc.verify() == []


# ----------------------------------------------------------- block step API
def test_step_block_is_run_block_plus_sync():
    """``engine.step_block`` is exactly ``run_block`` + numpy
    materialization — the synchronous block-step entry point external
    callers get (the streaming driver syncs lazily via run_block)."""
    import jax.numpy as jnp
    from repro.core import make_store, run_block, step_block, stack_waves
    from repro.core.workloads import ycsb_waves
    store = make_store(32, 4)
    stacked = stack_waves(ycsb_waves(np.random.RandomState(0), 3, 4, 4, 8,
                                     theta=0.9, read_frac=0.3))
    s1, o1, c1 = run_block(store, stacked, 1, jnp.int32(1), sched="postsi",
                           n_nodes=4)
    s2, o2, c2 = step_block(store, stacked, 1, jnp.int32(1), sched="postsi",
                            n_nodes=4)
    assert all(isinstance(leaf, np.ndarray) for leaf in o2)
    for a, b, name in zip(o1, o2, o2._fields):
        np.testing.assert_array_equal(np.asarray(a), b, err_msg=name)
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(c1) == int(c2)


# ------------------------------------------------------------- mesh twin
def test_streaming_mesh_b1k1_and_blocks():
    """Mesh conformance (child process, 8 virtual devices): per scheduler,
    mesh streaming B=1,K=1 is bit-identical to the mesh step loop; and for
    postsi, mesh streaming B=2,K=2 is bit-identical to *local* streaming
    B=2,K=2 (the substrates agree wave for wave)."""
    import test_distribution as td
    print(td._run(r"""
import numpy as np
from repro.core import SCHEDULERS
from repro.core.dist_engine import make_node_mesh
from repro.core.workloads import poisson_arrivals
from repro.service import RetryPolicy, TxnService, ycsb_txn_gen

n_nodes, kpn, T = 8, 32, 8
mesh = make_node_mesh(n_nodes)

def session(mesh_, mode, sched, B=1, K=1):
    hs = (np.round(np.linspace(0, 2, n_nodes)).astype(np.int32)
          if sched == "clocksi" else None)
    svc = TxnService(n_keys=n_nodes*kpn, T=T, sched=sched, n_nodes=n_nodes,
                     retry=RetryPolicy(max_attempts=6), host_skew=hs,
                     seed=0, mesh=mesh_)
    arr = poisson_arrivals(np.random.RandomState(100), 0.8*T, 5)
    gen = ycsb_txn_gen(np.random.RandomState(200), n_nodes, kpn, theta=0.9,
                       read_frac=0.5, dist_frac=0.3)
    rep = (svc.run_stream(arr, gen) if mode == "step"
           else svc.run_streaming(arr, gen, B=B, K=K))
    return svc, rep

def same(a, b):
    assert len(a.history) == len(b.history)
    for (ta, oa), (tb, ob) in zip(a.history, b.history):
        np.testing.assert_array_equal(ta, tb)
        for fa, fb, name in zip(oa, ob, oa._fields):
            np.testing.assert_array_equal(fa, fb, err_msg=name)

for sched in SCHEDULERS:
    a, ra = session(mesh, "step", sched)
    b, rb = session(mesh, "stream", sched, B=1, K=1)
    same(a, b)
    assert (ra.committed, ra.dropped, ra.retries) == \
           (rb.committed, rb.dropped, rb.retries), sched
    print("MESH-B1K1-OK", sched, ra.committed)

c, _ = session(mesh, "stream", "postsi", B=2, K=2)
d, _ = session(None, "stream", "postsi", B=2, K=2)
same(c, d)
assert c.verify() == []
print("MESH-BLOCK-OK")

# step_block_dist == run_block_dist + numpy materialization
from repro.core import make_store, stack_waves
from repro.core.dist_engine import run_block_dist, shard_store, step_block_dist
from repro.core.workloads import ycsb_waves
st = shard_store(make_store(n_nodes*kpn, 4), mesh)
stk = stack_waves(ycsb_waves(np.random.RandomState(3), 2, T, n_nodes, kpn))
_, o1, c1 = run_block_dist(st, stk, 1, 1, mesh)
_, o2, c2 = step_block_dist(st, stk, 1, 1, mesh)
for a, b, name in zip(o1, o2, o2._fields):
    np.testing.assert_array_equal(np.asarray(a), b, err_msg=name)
assert int(c1) == int(c2)
print("STEP-BLOCK-DIST-OK")
"""))


# ------------------------------------------------- hypothesis (slow leg)
@pytest.mark.slow
def test_streaming_property_commit_once_and_watermark_pins():
    """Random arrival processes (Poisson + bursty) × random zipf θ × random
    pipeline shape: every enqueued transaction commits exactly once or is
    reported dropped (counted over its full TID history against the served
    WaveOut record), and the GC watermark handed to every block dispatch
    never passes a pinned reader's snapshot floor."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 10_000), st.booleans(),
           st.floats(0.0, 1.3), st.sampled_from([(1, 1), (2, 2), (4, 3)]),
           st.integers(2, 8))
    def run(seed, bursty, theta, shape, max_attempts):
        B, K = shape
        svc = TxnService(n_keys=N_NODES * KPN, T=8, sched="postsi",
                         n_nodes=N_NODES, max_queue=16,
                         retry=RetryPolicy(max_attempts=max_attempts),
                         seed=seed)
        floor = 1
        svc.gc.pin(floor)                      # long-lived external reader
        seen_wm = []
        orig = svc._watermark
        svc._watermark = lambda: seen_wm.append(orig()) or seen_wm[-1]
        rng = np.random.RandomState(seed)
        arr = (bursty_arrivals(rng, 6.0, 8) if bursty
               else poisson_arrivals(rng, 6.0, 8))
        gen = ycsb_txn_gen(np.random.RandomState(seed + 1), N_NODES, KPN,
                           theta=theta, read_frac=0.3, dist_frac=0.3)
        rep = svc.run_streaming(arr, gen, B=B, K=K)

        assert svc.former.pending() == 0
        assert rep.committed + rep.dropped == rep.admitted
        assert rep.offered == rep.admitted + rep.rejected
        fate = {}                              # tid -> status, from history
        for tids, out in svc.history:
            for i, t in enumerate(tids):
                fate[int(t)] = int(out.status[i])
        for r in svc.requests:
            assert r.status in ("committed", "dropped", "rejected")
            if r.status == "rejected":
                assert not r.tids
                continue
            assert r.attempts == len(r.tids)
            n_committed = sum(fate[t] == COMMITTED for t in r.tids)
            if r.status == "committed":
                assert n_committed == 1        # exactly once, ever
                assert all(fate[t] == ABORTED for t in r.tids[:-1])
            else:
                assert n_committed == 0
                assert r.attempts == max_attempts
        # the dispatch-time watermark respects the pinned floor, always
        assert seen_wm and all(w is not None and w <= floor
                               for w in seen_wm)
        assert svc.verify() == []

    run()
