"""Model/layer unit + property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_reduced
from repro.launch.inputs import make_batch
from repro.models.config import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, attention,
                                 cross_entropy, rmsnorm, _chunked_attention,
                                 _dense_attention)
from repro.models.model import build

BASE = dict(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=128)


def _mk(arch):
    return get_reduced(arch)


# ------------------------------------------------------------------ layers
def test_chunked_attention_equals_dense():
    rng = np.random.RandomState(0)
    B, S, H, KH, D = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.4, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KH, D) * 0.4, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KH, D) * 0.4, jnp.float32)
    for causal in (True, False):
        dense = _dense_attention(q, k, v, causal=causal)
        chunk = _chunked_attention(q, k, v, causal=causal, chunk=32)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunk),
                                   atol=2e-5, rtol=2e-5)


def test_rope_relative_property():
    """RoPE: q.k after rotation depends only on relative positions."""
    rng = np.random.RandomState(1)
    B, H, D = 1, 1, 32
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32)

    def score(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]), 1e4)
        kr = apply_rope(k, jnp.asarray([[pk]]), 1e4)
        return float(jnp.einsum("bshd,bshd->", qr, kr))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(6, 3), rel=1e-4)


def test_mrope_matches_rope_for_equal_sections():
    """Text tokens (t=h=w position) under M-RoPE == plain RoPE."""
    rng = np.random.RandomState(2)
    B, S, H, D = 2, 8, 2, 32
    x = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    pos3 = jnp.broadcast_to(pos[..., None], (B, S, 3))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_cross_entropy_masks_ignored_labels():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    labels = jnp.asarray([[1, 2, -1, -1]], jnp.int32)
    ce = cross_entropy(logits, labels, 8)
    assert ce == pytest.approx(np.log(8), rel=1e-5)


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(np.random.RandomState(3).randn(2, 5, 16), jnp.float32)
    w = jnp.ones((16,))
    a = rmsnorm(x, w)
    b = rmsnorm(x * 100.0, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# -------------------------------------------------------- causality property
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 14))
def test_causal_future_invariance(seed, t):
    """Perturbing tokens after position t must not change logits at <= t."""
    cfg = ModelConfig(name="p", family="dense", **BASE)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(seed)
    S = 16
    toks = rng.randint(0, cfg.vocab_size, (1, S)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, t:] = rng.randint(0, cfg.vocab_size, S - t)
    h1, _ = model.hidden(params, jnp.asarray(toks), jnp.arange(S)[None])
    h2, _ = model.hidden(params, jnp.asarray(toks2), jnp.arange(S)[None])
    np.testing.assert_allclose(np.asarray(h1[0, :t]), np.asarray(h2[0, :t]),
                               atol=1e-4, rtol=1e-4)


def test_ssm_causality():
    cfg = get_reduced("mamba2-130m")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(5)
    S, t = 32, 17
    toks = rng.randint(0, cfg.vocab_size, (1, S)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, t:] = rng.randint(0, cfg.vocab_size, S - t)
    h1 = model.hidden(params, jnp.asarray(toks))
    h2 = model.hidden(params, jnp.asarray(toks2))
    np.testing.assert_allclose(np.asarray(h1[0, :t]), np.asarray(h2[0, :t]),
                               atol=2e-2, rtol=2e-2)


# --------------------------------------------- prefill/decode = full forward
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = _mk(arch)
    if cfg.moe:
        # capacity-MoE drops differ between batched prefill and one-token
        # decode by construction; compare in the dropless regime
        cfg = cfg.replace(capacity_factor=16.0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    full = {"tokens": toks, "labels": jnp.zeros_like(toks)}
    pre = {"tokens": toks[:, :S], "labels": jnp.zeros_like(toks[:, :S])}
    if cfg.mrope:
        p3 = jnp.broadcast_to(jnp.arange(S + 1)[None, :, None],
                              (B, S + 1, 3)).astype(jnp.int32)
        full["positions"] = p3
        pre["positions"] = p3[:, :S]
    if cfg.family == "encdec":
        emb = jnp.asarray(rng.randn(B, S, cfg.d_model) * 0.05, jnp.float32)
        full["enc_embeds"] = emb
        pre["enc_embeds"] = emb
    lg_full, _ = model.prefill(params, full)
    _, cache = model.prefill(params, pre)
    for kk in ("k", "v"):
        if kk in cache:
            pad = jnp.zeros(cache[kk].shape[:2] + (8,) + cache[kk].shape[3:],
                            cache[kk].dtype)
            cache[kk] = jnp.concatenate([cache[kk], pad], axis=2)
    lg_dec, _ = model.decode(params, cache, {"token": toks[:, S:S + 1]})
    scale = max(float(jnp.abs(lg_full).max()), 1.0)
    assert float(jnp.abs(lg_full - lg_dec).max()) < 0.06 * scale, arch


# ---------------------------------------------------------------- moe props
def test_moe_capacity_drops_are_bounded():
    cfg = get_reduced("deepseek-moe-16b").replace(capacity_factor=8.0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, "train")
    loss_hi, _ = model.loss(params, batch)
    cfg2 = cfg.replace(capacity_factor=0.25)   # heavy drops
    model2 = build(cfg2)
    loss_lo, _ = model2.loss(params, batch)
    assert jnp.isfinite(loss_hi) and jnp.isfinite(loss_lo)


def test_train_loss_decreases_reduced():
    cfg = get_reduced("qwen2-0.5b")
    from repro.launch.train import make_train_step
    from repro.optim import adamw_init
    model, step = make_train_step(cfg, lr=3e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = make_batch(cfg, 4, 32, "train")
    jstep = jax.jit(step)
    first = None
    for i in range(30):
        params, opt, m = jstep(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.7, (first, float(m["loss"]))