"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle, swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("S,D,dtype", [
    (128, 128, jnp.float32),
    (256, 128, jnp.float32),
    (512, 128, jnp.bfloat16),
    (256, 64, jnp.float32),      # D padded to 128 inside the wrapper
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(S, D, dtype, causal):
    rng = np.random.RandomState(0)
    B, H, KH = 2, 4, 2
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, dtype)
    k = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, dtype)
    v = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, dtype)
    out_p = ops.flash_attention(q, k, v, causal=causal, use_pallas=True,
                                interpret=True)
    out_r = ops.flash_attention(q, k, v, causal=causal, use_pallas=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_matches_model_layer():
    """The kernel, its oracle and the model's chunked-XLA path must agree."""
    from repro.models.layers import attention
    rng = np.random.RandomState(1)
    B, S, H, KH, D = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, jnp.float32)
    out_model = attention(q, k, v, causal=True, chunk=64)
    out_kernel = ops.flash_attention(q, k, v, causal=True, use_pallas=True,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               atol=3e-3, rtol=3e-3)


# ---------------------------------------------------------------------- ssd
@pytest.mark.parametrize("S,P,N,chunk", [
    (256, 64, 128, 128),
    (256, 32, 64, 64),
    (512, 64, 128, 128),
])
def test_ssd_kernel_vs_ref(S, P, N, chunk):
    rng = np.random.RandomState(2)
    B, H = 2, 3
    BH = B * H
    x = jnp.asarray(rng.randn(BH, S, P) * 0.5, jnp.float32)
    dA = -jnp.asarray(np.abs(rng.rand(BH, S)) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
    y_p, h_p = ops.ssd(x, dA, Bm, Cm, n_heads_per_group=H, chunk=chunk,
                       use_pallas=True, interpret=True)
    y_r, h_r = ops.ssd(x, dA, Bm, Cm, n_heads_per_group=H)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r), atol=1e-3, rtol=1e-3)


def test_ssd_kernel_vs_model_chunked():
    """Kernel agrees with the model's ssd_chunked (different layouts)."""
    rng = np.random.RandomState(3)
    B, S, H, P, N = 2, 256, 4, 32, 64
    x = jnp.asarray(rng.randn(B, S, H, P) * 0.5, jnp.float32)
    dA = -jnp.asarray(np.abs(rng.rand(B, S, H)) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, 1, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, 1, N) * 0.3, jnp.float32)
    y_m, h_m = ssd_chunked(x, dA, Bm, Cm, chunk=64)
    xk = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dk = dA.transpose(0, 2, 1).reshape(B * H, S)
    y_k, h_k = ops.ssd(xk, dk, Bm[:, :, 0], Cm[:, :, 0], n_heads_per_group=H,
                       chunk=64, use_pallas=True, interpret=True)
    y_k = y_k.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    h_k = h_k.reshape(B, H, N, P).transpose(0, 1, 3, 2)   # model: [B,H,P,N]
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_k), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h_m), np.asarray(h_k), atol=2e-3, rtol=2e-3)


# -------------------------------------------------------------- version scan
@pytest.mark.parametrize("M,V", [(256, 4), (512, 8), (1000, 6)])
def test_version_scan_vs_ref(M, V):
    rng = np.random.RandomState(4)
    cids = jnp.asarray(np.sort(rng.randint(0, 1000, (M, V)), axis=1), jnp.int32)
    tids = jnp.asarray(rng.randint(-1, 50, (M, V)), jnp.int32)
    max_cid = jnp.asarray(rng.randint(0, 1200, (M,)), jnp.int32)
    s_p, c_p = ops.version_scan(cids, tids, max_cid, use_pallas=True,
                                interpret=True)
    s_r, c_r = ops.version_scan(cids, tids, max_cid, use_pallas=False)
    # selected cid must match exactly; slots may differ only on duplicate cids
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))
    dup = np.asarray(jnp.take_along_axis(cids, s_r[:, None], 1)[:, 0]) == np.asarray(c_r)
    np.testing.assert_array_equal(np.asarray(s_p)[dup], np.asarray(s_r)[dup])


def test_version_scan_matches_store():
    """Kernel equals the engine's read_visible on a live store."""
    from repro.core import make_store, read_visible
    import jax.numpy as jnp
    store = make_store(512, 4)
    store = store._replace(
        cid=store.cid.at[:, 1].set(5), tid=store.tid.at[:, 1].set(3))
    keys = jnp.arange(512, dtype=jnp.int32)
    max_cid = jnp.full((512,), 4, jnp.int32)
    _, _, cid_ref2, _, slot_ref = read_visible(store, keys, max_cid)
    s_p, c_p = ops.version_scan(store.cid[keys], store.tid[keys], max_cid,
                                use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(cid_ref2))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(slot_ref))


# --------------------------------------------------------- potential matrix
@pytest.mark.parametrize("T,O", [(64, 4), (128, 8), (200, 12)])
def test_potential_matrix_vs_ref(T, O):
    rng = np.random.RandomState(5)
    rk = jnp.asarray(rng.randint(-1, 40, (T, O)), jnp.int32)
    wk = jnp.asarray(rng.randint(-1, 40, (T, O)), jnp.int32)
    p_p = ops.potential_matrix(rk, wk, use_pallas=True, interpret=True,
                               block_t=64)
    p_r = ops.potential_matrix(rk, wk, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(p_p), np.asarray(p_r))


def test_potential_matrix_matches_engine():
    """The engine's build route (``commit_phase.build_potential`` on the jnp
    leg — which is just ``ref.potential_matrix_ref``, the only jnp copy) must
    equal the kernel."""
    from repro.core.commit_phase import build_potential
    rng = np.random.RandomState(6)
    T, O = 64, 4
    keys = jnp.asarray(rng.randint(0, 30, (T, O)), jnp.int32)
    is_r = jnp.asarray(rng.rand(T, O) < 0.5)
    is_w = jnp.asarray(rng.rand(T, O) < 0.5)
    eng = build_potential(keys, is_r, is_w, backend="jnp")
    rk = jnp.where(is_r, keys, -1)
    wk = jnp.where(is_w, keys, -1)
    krn = ops.potential_matrix(rk, wk, use_pallas=True, interpret=True,
                               block_t=64)
    np.testing.assert_array_equal(np.asarray(eng), np.asarray(krn).astype(bool))


# ---------------------------------------------------- fused wave-commit kernel
def _ring_inputs(seed, T, O, V, n_keys=64):
    """Random gathered-ring inputs with the store invariants the kernel
    relies on: per-ring CIDs unique and >= 0, empty slots tid = -1."""
    rng = np.random.RandomState(seed)
    # unique cids per (t, o) ring via a shuffled base sequence
    cids = np.argsort(rng.rand(T, O, V), axis=2) * 3 + \
        rng.randint(0, 3, (T, O, 1))
    tids = np.where(rng.rand(T, O, V) < 0.3, -1, rng.randint(1, 99, (T, O, V)))
    sids = rng.randint(0, 40, (T, O, V))
    vals = rng.randint(-100, 100, (T, O, V))
    mc = rng.randint(-1, 3 * V, (T, O))     # includes all-invisible ceilings
    keys = rng.randint(0, n_keys, (T, O))
    is_r = rng.rand(T, O) < 0.5
    is_w = rng.rand(T, O) < 0.4
    to = lambda a: jnp.asarray(a, jnp.int32)
    return (to(cids), to(tids), to(sids), to(vals), to(mc),
            jnp.where(jnp.asarray(is_r), to(keys), -1),
            jnp.where(jnp.asarray(is_w), to(keys), -1), jnp.asarray(is_r))


@pytest.mark.parametrize("T,O,V", [(16, 3, 4), (64, 8, 8), (130, 5, 6)])
def test_wave_commit_vs_ref(T, O, V):
    """Fused megakernel (interpret) == the jnp oracle composition, every
    output, including non-aligned T/O shapes the wrapper pads."""
    args = _ring_inputs(7, T, O, V)
    out_p = ops.wave_commit(*args, use_pallas=True, interpret=True)
    out_r = ops.wave_commit(*args, use_pallas=False)
    names = ("slot", "r_val", "r_tid", "r_cid", "r_sid", "s_lo0", "potential")
    for name, a, b in zip(names, out_p, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_wave_commit_vs_unfused_composition():
    """Fused == the exact three-op route it replaces (same backend): the
    version_scan slots, the slot gathers, the rule-3 seed reduction and the
    potential tile, dispatched separately."""
    T, O, V = 48, 4, 6
    cids, tids, sids, vals, mc, rk, wk, rvalid = _ring_inputs(11, T, O, V)
    (slot, r_val, r_tid, r_cid, r_sid, s_lo0, pot) = ops.wave_commit(
        cids, tids, sids, vals, mc, rk, wk, rvalid,
        use_pallas=True, interpret=True)
    slot_u, _ = ops.version_scan(cids.reshape(-1, V), tids.reshape(-1, V),
                                 mc.reshape(-1), use_pallas=True,
                                 interpret=True)
    slot_u = slot_u.reshape(T, O)
    take = lambda a: jnp.take_along_axis(a, slot_u[..., None], -1)[..., 0]
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_u))
    np.testing.assert_array_equal(np.asarray(r_cid), np.asarray(take(cids)))
    np.testing.assert_array_equal(np.asarray(r_val), np.asarray(take(vals)))
    np.testing.assert_array_equal(np.asarray(r_tid), np.asarray(take(tids)))
    np.testing.assert_array_equal(np.asarray(r_sid), np.asarray(take(sids)))
    s_lo0_u = jnp.where(rvalid, take(cids), 0).max(axis=1)
    np.testing.assert_array_equal(np.asarray(s_lo0), np.asarray(s_lo0_u))
    pot_u = ops.potential_matrix(rk, wk, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(pot), np.asarray(pot_u))


def test_wave_commit_hypothesis_random_waves():
    """Property sweep: for random live waves on a live store, the fused and
    unfused read phases agree on every substrate output (the satellite-4
    random-wave differential at the kernel seam)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.core import make_store
    from repro.core.engine import run_wave
    from repro.core.substrate import LocalSubstrate
    from repro.core.workloads import micro_waves
    from repro.kernels import KernelConfig

    n_nodes, kpn, T = 4, 16, 12

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2 ** 16),
           read_ratio=st.sampled_from([0.2, 0.7]),
           ceiling=st.sampled_from([0, 2, 1 << 30]))
    def check(seed, read_ratio, ceiling):
        waves = micro_waves(np.random.RandomState(seed), 2, T, n_nodes, kpn,
                            n_ops=3, read_ratio=read_ratio, dist_frac=0.5,
                            hot_frac=0.6, hot_per_node=2)
        # a populated store: run the first wave through the engine
        store = make_store(n_nodes * kpn, 4)
        store, _, _ = run_wave(store, waves[0], jnp.int32(1), jnp.int32(1),
                               jnp.int32(n_nodes), kernels="jnp")
        wave = waves[1]
        is_r = (wave.op_kind == 1) | (wave.op_kind == 3)
        is_w = (wave.op_kind == 2) | (wave.op_kind == 3)
        mc = jnp.broadcast_to(jnp.int32(ceiling), wave.op_key.shape)
        outs = [LocalSubstrate(cfg).read_phase(store, wave.op_key, mc,
                                               is_r, is_w)
                for cfg in (KernelConfig("pallas_interpret"),
                            KernelConfig("pallas_interpret", fused=True),
                            KernelConfig("jnp", fused=True))]
        names = ("r_val", "r_tid", "r_cid", "r_sid", "r_slot", "s_lo0",
                 "potential")
        for got in outs[1:]:
            for name, a, b in zip(names, outs[0], got):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f"{seed}.{name}")

    check()


@pytest.mark.parametrize("pad_key", [0, -1, 5])
def test_wave_commit_nop_padding_no_false_edges(pad_key):
    """Satellite audit: two NOP-padded txns sharing the clamp sentinel key
    (0, -1, or a HOT real key) must not grow a false anti-dependency edge in
    any of the three fused bodies — adversarial placement interleaves the
    NOP rows with real txns instead of suffix-padding them."""
    T, O, V = 16, 3, 4
    cids, tids, sids, vals, mc, rk, wk, rvalid = _ring_inputs(13, T, O, V,
                                                              n_keys=8)
    # interleaved NOP rows: every third txn is padding, all ops masked off
    # but the raw key column set to the adversarial pad_key
    nop_rows = np.arange(0, T, 3)
    rk = rk.at[nop_rows].set(-1)          # NOP => not a read
    wk = wk.at[nop_rows].set(-1)          # NOP => not a write
    rvalid = rvalid.at[nop_rows].set(False)
    # real txn 1 reads AND writes pad_key's clamped target to maximize the
    # chance a sentinel mixup would connect it to the padding rows
    hot = max(pad_key, 0)
    rk = rk.at[1, 0].set(hot)
    wk = wk.at[1, 1].set(hot)
    rvalid = rvalid.at[1, 0].set(True)
    for use_pallas in (False, True):
        _, _, _, _, _, s_lo0, pot = ops.wave_commit(
            cids, tids, sids, vals, mc, rk, wk, rvalid,
            use_pallas=use_pallas, interpret=use_pallas)
        pot = np.asarray(pot).astype(bool)
        assert not pot[nop_rows].any(), "NOP row grew outgoing rw edges"
        assert not pot[:, nop_rows].any(), "NOP row grew incoming rw edges"
        # the three bodies separately: version scan and potential directly,
        # the seed via the rvalid mask — NOP rows contribute exactly 0
        assert (np.asarray(s_lo0)[nop_rows] == 0).all()
        pot_u = np.asarray(ops.potential_matrix(
            rk, wk, use_pallas=use_pallas,
            interpret=use_pallas)).astype(bool)
        np.testing.assert_array_equal(pot, pot_u)
