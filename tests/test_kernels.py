"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle, swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("S,D,dtype", [
    (128, 128, jnp.float32),
    (256, 128, jnp.float32),
    (512, 128, jnp.bfloat16),
    (256, 64, jnp.float32),      # D padded to 128 inside the wrapper
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(S, D, dtype, causal):
    rng = np.random.RandomState(0)
    B, H, KH = 2, 4, 2
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, dtype)
    k = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, dtype)
    v = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, dtype)
    out_p = ops.flash_attention(q, k, v, causal=causal, use_pallas=True,
                                interpret=True)
    out_r = ops.flash_attention(q, k, v, causal=causal, use_pallas=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_matches_model_layer():
    """The kernel, its oracle and the model's chunked-XLA path must agree."""
    from repro.models.layers import attention
    rng = np.random.RandomState(1)
    B, S, H, KH, D = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KH, D) * 0.3, jnp.float32)
    out_model = attention(q, k, v, causal=True, chunk=64)
    out_kernel = ops.flash_attention(q, k, v, causal=True, use_pallas=True,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               atol=3e-3, rtol=3e-3)


# ---------------------------------------------------------------------- ssd
@pytest.mark.parametrize("S,P,N,chunk", [
    (256, 64, 128, 128),
    (256, 32, 64, 64),
    (512, 64, 128, 128),
])
def test_ssd_kernel_vs_ref(S, P, N, chunk):
    rng = np.random.RandomState(2)
    B, H = 2, 3
    BH = B * H
    x = jnp.asarray(rng.randn(BH, S, P) * 0.5, jnp.float32)
    dA = -jnp.asarray(np.abs(rng.rand(BH, S)) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N) * 0.3, jnp.float32)
    y_p, h_p = ops.ssd(x, dA, Bm, Cm, n_heads_per_group=H, chunk=chunk,
                       use_pallas=True, interpret=True)
    y_r, h_r = ops.ssd(x, dA, Bm, Cm, n_heads_per_group=H)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r), atol=1e-3, rtol=1e-3)


def test_ssd_kernel_vs_model_chunked():
    """Kernel agrees with the model's ssd_chunked (different layouts)."""
    rng = np.random.RandomState(3)
    B, S, H, P, N = 2, 256, 4, 32, 64
    x = jnp.asarray(rng.randn(B, S, H, P) * 0.5, jnp.float32)
    dA = -jnp.asarray(np.abs(rng.rand(B, S, H)) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, 1, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, 1, N) * 0.3, jnp.float32)
    y_m, h_m = ssd_chunked(x, dA, Bm, Cm, chunk=64)
    xk = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dk = dA.transpose(0, 2, 1).reshape(B * H, S)
    y_k, h_k = ops.ssd(xk, dk, Bm[:, :, 0], Cm[:, :, 0], n_heads_per_group=H,
                       chunk=64, use_pallas=True, interpret=True)
    y_k = y_k.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    h_k = h_k.reshape(B, H, N, P).transpose(0, 1, 3, 2)   # model: [B,H,P,N]
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_k), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h_m), np.asarray(h_k), atol=2e-3, rtol=2e-3)


# -------------------------------------------------------------- version scan
@pytest.mark.parametrize("M,V", [(256, 4), (512, 8), (1000, 6)])
def test_version_scan_vs_ref(M, V):
    rng = np.random.RandomState(4)
    cids = jnp.asarray(np.sort(rng.randint(0, 1000, (M, V)), axis=1), jnp.int32)
    tids = jnp.asarray(rng.randint(-1, 50, (M, V)), jnp.int32)
    max_cid = jnp.asarray(rng.randint(0, 1200, (M,)), jnp.int32)
    s_p, c_p = ops.version_scan(cids, tids, max_cid, use_pallas=True,
                                interpret=True)
    s_r, c_r = ops.version_scan(cids, tids, max_cid, use_pallas=False)
    # selected cid must match exactly; slots may differ only on duplicate cids
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))
    dup = np.asarray(jnp.take_along_axis(cids, s_r[:, None], 1)[:, 0]) == np.asarray(c_r)
    np.testing.assert_array_equal(np.asarray(s_p)[dup], np.asarray(s_r)[dup])


def test_version_scan_matches_store():
    """Kernel equals the engine's read_visible on a live store."""
    from repro.core import make_store, read_visible
    import jax.numpy as jnp
    store = make_store(512, 4)
    store = store._replace(
        cid=store.cid.at[:, 1].set(5), tid=store.tid.at[:, 1].set(3))
    keys = jnp.arange(512, dtype=jnp.int32)
    max_cid = jnp.full((512,), 4, jnp.int32)
    _, _, cid_ref2, _, slot_ref = read_visible(store, keys, max_cid)
    s_p, c_p = ops.version_scan(store.cid[keys], store.tid[keys], max_cid,
                                use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_p), np.asarray(cid_ref2))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(slot_ref))


# --------------------------------------------------------- potential matrix
@pytest.mark.parametrize("T,O", [(64, 4), (128, 8), (200, 12)])
def test_potential_matrix_vs_ref(T, O):
    rng = np.random.RandomState(5)
    rk = jnp.asarray(rng.randint(-1, 40, (T, O)), jnp.int32)
    wk = jnp.asarray(rng.randint(-1, 40, (T, O)), jnp.int32)
    p_p = ops.potential_matrix(rk, wk, use_pallas=True, interpret=True,
                               block_t=64)
    p_r = ops.potential_matrix(rk, wk, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(p_p), np.asarray(p_r))


def test_potential_matrix_matches_engine():
    from repro.core.engine import _potential_antidep
    rng = np.random.RandomState(6)
    T, O = 64, 4
    keys = jnp.asarray(rng.randint(0, 30, (T, O)), jnp.int32)
    is_r = jnp.asarray(rng.rand(T, O) < 0.5)
    is_w = jnp.asarray(rng.rand(T, O) < 0.5)
    eng = _potential_antidep(keys, keys, is_r, is_w)
    rk = jnp.where(is_r, keys, -1)
    wk = jnp.where(is_w, keys, -1)
    krn = ops.potential_matrix(rk, wk, use_pallas=True, interpret=True,
                               block_t=64)
    np.testing.assert_array_equal(np.asarray(eng), np.asarray(krn).astype(bool))
