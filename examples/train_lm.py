"""End-to-end training driver: a small LM on the synthetic token language,
with PostSI-committed checkpoints, an injected node failure mid-run, and
automatic restore/resume.

The exact same step/runner/checkpointer code drives the full-size configs on
a real pod (see repro/launch/dryrun.py for the 512-chip lowering of the same
train_step).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch qwen2-0.5b]
"""
import argparse
import shutil
import tempfile

import jax

from repro.checkpoint import PostSICheckpointer
from repro.configs import get_reduced
from repro.data import TokenStream
from repro.launch.train import make_train_step
from repro.optim import adamw_init
from repro.runtime import FailureInjector, TrainRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fail-at", type=int, default=77,
                    help="inject a node failure at this step (-1: off)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(vocab_size=2048)
    model, step_fn = make_train_step(cfg, lr=args.lr)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"batch={args.batch}x{args.seq}")

    stream = TokenStream(cfg, args.batch, args.seq, seed=0)
    ckdir = tempfile.mkdtemp(prefix="postsi_ckpt_")
    tree_ex = {"params": params, "opt": opt,
               "data": {"step": jax.numpy.asarray(0)}}
    ck = PostSICheckpointer(ckdir, tree_ex)

    runner = TrainRunner(jax.jit(step_fn, donate_argnums=(0, 1)), stream, ck,
                         ckpt_every=25)
    injector = FailureInjector(fail_at=() if args.fail_at < 0 else (args.fail_at,))

    out = runner.run(params, opt, args.steps, injector=injector)
    ls = out["losses"]
    print(f"\nsteps={out['final_step']} restarts={out['restarts']} "
          f"(injected failure {'fired' if out['restarts'] else 'off'})")
    for i in range(0, len(ls), max(len(ls) // 10, 1)):
        print(f"  step {i:4d}  loss {ls[i]:.4f}")
    print(f"  final loss {ls[-1]:.4f}  (start {ls[0]:.4f})")
    assert ls[-1] < ls[0], "loss should decrease"
    shutil.rmtree(ckdir, ignore_errors=True)
    print("OK: trained through an injected failure with PostSI checkpoints.")


if __name__ == "__main__":
    main()
