"""Quickstart: the paper's decentralized MVCC in 60 seconds.

1. Walk through Figure 1 with the reference PostSI scheduler: a blind write
   over a committed-but-physically-overlapping peer COMMITS under PostSI
   (timestamps are induced, not measured) while first-committer-wins SI
   aborts it.
2. Run a SmallBank workload through the vectorized wave engine under PostSI
   and conventional SI, verify both histories satisfy snapshot isolation and
   compare coordination traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import make_store, run_workload, verify_si
from repro.core.seq import SeqScheduler
from repro.core.workloads import smallbank_waves

A, B = 0, 1

print("=== Paper Figure 1: posterior timestamps in action ===")
s = SeqScheduler(2, mode="postsi")
t1, t2, t3 = s.begin(), s.begin(), s.begin()
s.read(t1, A)               # t1 overlaps everyone
s.read(t2, A)
s.write(t2, B, 20)
assert s.commit(t2)
print(f"t2 committed with interval ({s.txns[t2].s}, {s.txns[t2].c})")
s.write(t3, B, 30)          # blind write over t2's version, while overlapping
ok = s.commit(t3)
print(f"t3 blind-writes B after t2's commit -> "
      f"{'COMMIT' if ok else 'ABORT'} with interval "
      f"({s.txns[t3].s}, {s.txns[t3].c})   (conventional SI would abort)")
assert not verify_si(s.history()), None
print("history verifies as snapshot-isolated:", verify_si(s.history()) == [])

print("\n=== Wave engine: SmallBank on 8 shared-nothing nodes ===")
rng = np.random.RandomState(0)
n_nodes, kpn = 8, 400
waves = smallbank_waves(rng, 4, 64, n_nodes, kpn, dist_frac=0.3)
for sched in ("postsi", "cv", "si", "optimal"):
    _, hist, stats = run_workload(make_store(n_nodes * kpn, 8), waves,
                                  sched=sched, n_nodes=n_nodes)
    errs = verify_si(hist) if sched != "cv" else []
    print(f"{sched:8s} committed={stats.committed:4d} aborted={stats.aborted:3d} "
          f"cross-msgs={stats.msgs_cross:4d} coordinator-msgs={stats.msgs_coord:4d} "
          f"SI-violations={len(errs)}")
print("\nPostSI: zero coordinator messages — the paper's point.")
