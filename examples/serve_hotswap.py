"""Serving with live weight hot-swap under PostSI (DESIGN.md §3.2).

A server answers batched decode requests while a publisher transaction
commits new weight versions concurrently.  Each request batch is a reader
transaction over the versioned weight store: the paper's Consistent
Visibility guarantees every batch sees exactly ONE weight version — reading
layer 0 of version k and layer 1 of version k+1 ("torn" weights) is the
partial-visibility anomaly CV forbids.

We verify: every served batch reports a single consistent version tag, even
though publishes interleave with serving.

Run:  PYTHONPATH=src python examples/serve_hotswap.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.seq import SeqScheduler
from repro.launch.train import make_decode_step, make_prefill_step
from repro.launch.inputs import make_batch


def main():
    cfg = get_reduced("qwen2-0.5b").replace(vocab_size=512)
    model, prefill = make_prefill_step(cfg)
    _, decode = make_decode_step(cfg)
    prefill = jax.jit(prefill)
    decode = jax.jit(decode)

    # weight versions: v0 and v1 (e.g., a fresh finetune published mid-serving)
    params_v = [model.init(jax.random.PRNGKey(i)) for i in range(3)]
    leaves0 = jax.tree_util.tree_leaves(params_v[0])
    n_leaves = len(leaves0)

    # the versioned store: one key per weight leaf; value = version id
    sched = SeqScheduler(n_leaves, mode="postsi")
    pub = sched.begin()
    for k in range(n_leaves):
        sched.write(pub, k, 0)
    assert sched.commit(pub)

    def publish(version: int, upto: int | None = None):
        """Writer txn; ``upto`` lets us leave a publish half-done (in-flight)."""
        t = sched.begin()
        for k in range(n_leaves if upto is None else upto):
            sched.write(t, k, version)
        return t

    def serve_batch(batch_id: int) -> int:
        """Reader txn: assemble weights leaf-by-leaf from the store."""
        t = sched.begin()
        versions = [sched.read(t, k) for k in range(n_leaves)]
        assert sched.commit(t)
        vs = set(versions)
        assert len(vs) == 1, f"TORN WEIGHTS in batch {batch_id}: {vs}"
        v = versions[0]
        params = params_v[v]
        B, S = 4, 16
        batch = make_batch(cfg, B, S, "prefill",
                           rng=np.random.RandomState(batch_id))
        logits, cache = prefill(params, batch)
        for kk in ("k", "v"):
            pad = jnp.zeros(cache[kk].shape[:2] + (8,) + cache[kk].shape[3:],
                            cache[kk].dtype)
            cache[kk] = jnp.concatenate([cache[kk], pad], axis=2)
        tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
        for _ in range(4):                       # a few decode steps
            tok, cache = decode(params, cache, {"token": tok})
        return v

    print("serving 8 batches with two interleaved weight publishes...")
    served = []
    served.append(serve_batch(0))
    served.append(serve_batch(1))
    inflight = publish(1, upto=n_leaves // 2)    # publisher writes half...
    served.append(serve_batch(2))                # ...reader must still see v0
    for k in range(n_leaves // 2, n_leaves):
        sched.write(inflight, k, 1)
    assert sched.commit(inflight)                # v1 becomes visible atomically
    served.append(serve_batch(3))
    served.append(serve_batch(4))
    t2 = publish(2)
    assert sched.commit(t2)
    served.append(serve_batch(5))
    served.append(serve_batch(6))
    served.append(serve_batch(7))

    print("weight version per batch:", served)
    assert served[:3] == [0, 0, 0] and served[3] in (1,) and served[-1] == 2
    print("OK: every batch saw one atomic weight version; the half-published "
          "update was invisible until its commit (no torn weights).")


if __name__ == "__main__":
    main()
