"""Closed-loop transaction service quickstart (DESIGN.md §8).

An open SmallBank request stream — bursty arrivals, a per-node hotspot —
served end-to-end by the decentralized PostSI wave engine: the wave former
admits and packs arrivals, aborted transactions retry with fresh TIDs under
exponential backoff, and the visibility watermark guards version GC.  The
served history is then verified post-hoc: it must be snapshot-isolated and
the final store must match a serial replay of the committed transactions.

The same stream is then replayed through the pipelined streaming plane
(blocks of B waves as one fused device program, K blocks in flight) —
same closed loop, same verifiers, a fraction of the dispatch overhead.

Run:  PYTHONPATH=src python examples/serve_txn_service.py
"""
import numpy as np

from repro.core.workloads import bursty_arrivals
from repro.service import RetryPolicy, TxnService, smallbank_txn_gen

N_NODES = 4
KEYS_PER_NODE = 50
T = 32          # wave capacity (txns per tick)
N_TICKS = 40
RATE = 20.0     # calm-state arrivals per tick (bursts spike to 6x)


def main():
    # warm both data planes first (a throwaway session each), so neither
    # timed run below is measuring jit compilation
    for streaming in (False, True):
        warm = TxnService(n_keys=N_NODES * KEYS_PER_NODE, n_versions=8, T=T,
                          sched="postsi", n_nodes=N_NODES, seed=0)
        gen = smallbank_txn_gen(np.random.RandomState(9), N_NODES,
                                KEYS_PER_NODE)
        if streaming:
            # a backlog burst, so full B-wave blocks form and every pow2
            # chunk shape ([1],[2],[4]) compiles here, not in the timed run
            warm.run_streaming([4 * T] * 6, gen, B=4, K=2)
        else:
            warm.run_stream([T] * 2, gen)

    svc = TxnService(n_keys=N_NODES * KEYS_PER_NODE, n_versions=8, T=T,
                     sched="postsi", n_nodes=N_NODES,
                     retry=RetryPolicy(max_attempts=6), seed=0)
    gen = smallbank_txn_gen(np.random.RandomState(1), N_NODES, KEYS_PER_NODE,
                            dist_frac=0.3, hot_frac=0.5, hot_per_node=4)
    arrivals = bursty_arrivals(np.random.RandomState(2), RATE, N_TICKS)
    print(f"offered: {int(arrivals.sum())} txns over {N_TICKS} ticks "
          f"(capacity {T}/tick, bursts up to {int(arrivals.max())})")

    report = svc.run_stream(arrivals, gen)

    print(f"\ncommitted {report.committed}/{report.admitted} admitted "
          f"({report.rejected} shed at admission, {report.dropped} dropped "
          f"after {svc.retry.max_attempts} attempts)")
    print(f"retries: {report.retries} (rate {report.retry_rate:.2f}); "
          f"goodput {report.goodput_tps:.0f} txn/s, "
          f"sustained {report.txns_per_sec:.0f} exec/s over "
          f"{report.waves} waves")
    print(f"latency p50/p95/p99: {report.latency_p50:.0f}/"
          f"{report.latency_p95:.0f}/{report.latency_p99:.0f} ticks")
    print(f"GC: watermark {report.gc['watermark']}, "
          f"still-visible evictions {report.evicted_visible}")

    errors = svc.verify()
    assert not errors, errors[:3]
    print("\nhistory verified: snapshot-isolated, store == serial replay "
          f"({len(svc.history)} waves, 0 violations)")

    # the same stream through the pipelined streaming plane (DESIGN.md §8)
    svc2 = TxnService(n_keys=N_NODES * KEYS_PER_NODE, n_versions=8, T=T,
                      sched="postsi", n_nodes=N_NODES,
                      retry=RetryPolicy(max_attempts=6), seed=0)
    gen2 = smallbank_txn_gen(np.random.RandomState(1), N_NODES,
                             KEYS_PER_NODE, dist_frac=0.3, hot_frac=0.5,
                             hot_per_node=4)
    arrivals2 = bursty_arrivals(np.random.RandomState(2), RATE, N_TICKS)
    rep2 = svc2.run_streaming(arrivals2, gen2, B=4, K=2)
    assert svc2.verify() == []
    print(f"\nstreaming (B=4, K=2): committed {rep2.committed}/"
          f"{rep2.admitted} over {rep2.waves} waves in {rep2.blocks} fused "
          f"blocks; goodput {rep2.goodput_tps:.0f} txn/s "
          f"(step loop: {report.goodput_tps:.0f})")


if __name__ == "__main__":
    main()
