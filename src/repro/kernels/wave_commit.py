"""Fused wave-commit Pallas kernel: the whole wave read phase in ONE launch.

The unfused engine pays three dispatches per wave before the commit loop —
``version_scan`` (latest-visible slot per gathered ring, paper §IV-B CID
rule), a jnp reduction for the PostSI rule-3 negotiation seed
``s_lo0 = max(cid of versions read)``, and ``potential_matrix`` (the
anti-dependency candidate build, CV rule 6 / PostSI rule 4 feed) — with the
selected ``r_cid`` round-tripping through HBM between them.  This kernel
fuses all three bodies over the same VMEM-resident blocks:

  inputs   gathered rings  cid/tid/sid/val  [T, O, Vp]   (Vp = 128 lanes)
           per-op ceiling  max_cid          [T, O]
           masked keys     rk / wk          [T, O]       (-1 = inactive)
           seed mask       rvalid           [T, O]       (read AND owned)
  outputs  slot, r_val, r_tid, r_cid, r_sid [T, O]
           s_lo0                            [T, 128]     (lane-broadcast)
           potential                        [T, T] int8

Tiling follows ``interval_negotiate``: a 2-D (reader-block i, writer-block
j) grid over [BT x BT] potential tiles with static O^2 broadcast-compare
accumulation.  The read-phase blocks (rings, scan outputs, s_lo0) use
index maps that ignore ``j``, so they are revisited across the inner grid
dimension and stay resident in VMEM for the whole reader row — the
``flash_attention``/``ssd_scan`` revisited-block idiom.

The ``rvalid`` mask (rather than ``rk >= 0``) feeds the s_lo0 seed so the
mesh substrate can pass ``is_read & mine`` and merge per-node partial
maxima with ``lax.pmax`` — bit-identical to the unfused merge-then-reduce
order because every contribution is a non-negative CID.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cid_ref, tid_ref, sid_ref, val_ref, mc_ref, rk_ref, wk_ref,
            rv_ref, slot_ref, rval_ref, rtid_ref, rcid_ref, rsid_ref,
            slo_ref, pot_ref, *, block_t: int, n_ops: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    # ---- anti-dependency tile (potential_matrix body) ---------------------
    # potential[i, j] = "txn i read a key txn j writes"; -1 sentinels carry
    # both the op masks and any NOP padding, guarded by r >= 0
    rk = rk_ref[...]                                    # [BT, O] reader keys
    wk = wk_ref[...]                                    # [BT, O] writer keys
    acc = jnp.zeros((block_t, block_t), jnp.bool_)
    for o1 in range(n_ops):
        r = rk[:, o1]
        for o2 in range(n_ops):
            w = wk[:, o2]
            acc = acc | ((r[:, None] == w[None, :]) & (r[:, None] >= 0))
    gi = i * block_t + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
    gj = j * block_t + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    pot_ref[...] = (acc & (gi != gj)).astype(jnp.int8)

    # ---- read phase (version_scan body over the gathered rings) -----------
    cids = cid_ref[...]                                 # [BT, O, Vp]
    tids = tid_ref[...]
    ceil = mc_ref[...]                                  # [BT, O]
    ok = (tids != -1) & (cids <= ceil[:, :, None])
    masked = jnp.where(ok, cids, -1)
    best = masked.max(axis=2)                           # [BT, O]
    Vp = cids.shape[2]
    lane = jax.lax.broadcasted_iota(jnp.int32, masked.shape, 2)
    # argmax via equality with the max (first match wins, matching jnp.argmax
    # tie-break because per-key CIDs are unique; all-invisible rows hit 0)
    hit = jnp.where(masked == best[:, :, None], lane, Vp)
    slot = hit.min(axis=2)                              # [BT, O]
    sel = lane == slot[:, :, None]
    pick = lambda a: jnp.where(sel, a, 0).sum(axis=2)   # exact: one lane set
    r_cid = pick(cids)                                  # RAW cid at slot
    slot_ref[...] = slot.astype(jnp.int32)
    rval_ref[...] = pick(val_ref[...]).astype(jnp.int32)
    rtid_ref[...] = pick(tids).astype(jnp.int32)
    rcid_ref[...] = r_cid.astype(jnp.int32)
    rsid_ref[...] = pick(sid_ref[...]).astype(jnp.int32)

    # ---- PostSI rule-3 seed: s_lo0 = max CID over valid reads -------------
    rv = rv_ref[...]                                    # [BT, O]
    slo = jnp.where(rv != 0, r_cid, 0).max(axis=1)      # [BT]
    slo_ref[...] = jnp.broadcast_to(slo[:, None],
                                    slo_ref.shape).astype(jnp.int32)


def wave_commit_pallas(cids, tids, sids, vals, max_cid, read_key, write_key,
                       rvalid, *, block_t: int = 128,
                       interpret: bool = False):
    """cids/tids/sids/vals: [T, O, Vp] int32 gathered rings (Vp lane-padded
    to 128; empty/padded slots tid=-1); max_cid/read_key/write_key/rvalid:
    [T, O] int32.  Returns (slot, r_val, r_tid, r_cid, r_sid [T, O],
    s_lo0 [T, 128] lane-broadcast, potential [T, T] int8)."""
    T, O, Vp = cids.shape
    assert T % block_t == 0, (T, block_t)
    kern = functools.partial(_kernel, block_t=block_t, n_ops=O)
    grid = (T // block_t, T // block_t)
    ring = pl.BlockSpec((block_t, O, Vp), lambda i, j: (i, 0, 0))
    row2d = pl.BlockSpec((block_t, O), lambda i, j: (i, 0))
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            ring, ring, ring, ring,                     # cid/tid/sid/val
            row2d,                                      # max_cid
            row2d,                                      # read_key (block i)
            pl.BlockSpec((block_t, O), lambda i, j: (j, 0)),  # write_key (j)
            row2d,                                      # rvalid
        ],
        out_specs=[
            row2d, row2d, row2d, row2d, row2d,          # slot + r_* gathers
            pl.BlockSpec((block_t, 128), lambda i, j: (i, 0)),  # s_lo0
            pl.BlockSpec((block_t, block_t), lambda i, j: (i, j)),  # potential
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, O), jnp.int32),
            jax.ShapeDtypeStruct((T, O), jnp.int32),
            jax.ShapeDtypeStruct((T, O), jnp.int32),
            jax.ShapeDtypeStruct((T, O), jnp.int32),
            jax.ShapeDtypeStruct((T, O), jnp.int32),
            jax.ShapeDtypeStruct((T, 128), jnp.int32),
            jax.ShapeDtypeStruct((T, T), jnp.int8),
        ],
        interpret=interpret,
    )(cids, tids, sids, vals, max_cid, read_key, write_key, rvalid)
    return outs
