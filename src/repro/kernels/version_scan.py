"""Pallas TPU kernel for the PostSI read hot spot: latest-visible-version
selection over version ring buffers (paper CV rule 4 / §IV-B CID rule).

For a block of read requests, each with a gathered ring of V version slots
(CIDs + creator TIDs) and a per-request visibility ceiling ``max_cid``,
select the newest visible slot:

    ok     = (tid != -1) & (cid <= max_cid)
    best   = argmax(where(ok, cid, -1))

Tiling: requests on the sublane axis (BM per block), the V ring slots padded
to the 128-lane axis in ops.version_scan.  Outputs are lane-broadcast
[BM, 128] tiles (slot index and selected cid); the wrapper takes lane 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cid_ref, tid_ref, maxcid_ref, slot_ref, best_ref):
    cids = cid_ref[...]                                  # [BM, Vp]
    tids = tid_ref[...]
    ceil = maxcid_ref[...][:, 0]                         # [BM]
    ok = (tids != -1) & (cids <= ceil[:, None])
    masked = jnp.where(ok, cids, -1)
    best = masked.max(axis=1)                            # [BM]
    # argmax via equality with the max (first match wins, matching jnp.argmax
    # tie-break because per-key CIDs are unique)
    Vp = cids.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, masked.shape, 1)
    hit = jnp.where(masked == best[:, None], lane, Vp)
    slot = hit.min(axis=1)
    slot_ref[...] = jnp.broadcast_to(slot[:, None], slot_ref.shape).astype(jnp.int32)
    best_ref[...] = jnp.broadcast_to(best[:, None], best_ref.shape).astype(jnp.int32)


def version_scan_pallas(cids: jax.Array, tids: jax.Array, max_cid: jax.Array,
                        *, block_m: int = 256, interpret: bool = False):
    """cids, tids: [M, Vp] int32 (Vp lane-padded; empty slots tid=-1);
    max_cid: [M, 128] int32 (lane-broadcast).  Returns (slot [M], cid [M])."""
    M, Vp = cids.shape
    assert M % block_m == 0, (M, block_m)
    slot, best = pl.pallas_call(
        _kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, Vp), lambda i: (i, 0)),
            pl.BlockSpec((block_m, Vp), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 128), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 128), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 128), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, 128), jnp.int32),
            jax.ShapeDtypeStruct((M, 128), jnp.int32),
        ],
        interpret=interpret,
    )(cids, tids, max_cid)
    return slot[:, 0], best[:, 0]
