"""Kernel plane: Pallas TPU kernels, their jnp oracles, and the unified
backend registry that routes every data-plane hot spot (version scan,
anti-dependency build) through one resolved :class:`KernelConfig`.
"""
from .backend import (BACKENDS, KernelConfig, default_backend,
                      register_cache_clear, resolve, set_default_backend)

__all__ = [
    "BACKENDS", "KernelConfig", "default_backend", "register_cache_clear",
    "resolve", "set_default_backend",
]
