"""Kernel plane: Pallas TPU kernels, their jnp oracles, and the unified
backend registry that routes every data-plane hot spot (version scan,
anti-dependency build, fused wave-commit read phase) through one resolved
:class:`KernelConfig`.
"""
from .backend import (BACKENDS, KernelConfig, can_compile_pallas,
                      default_backend, register_cache_clear, resolve,
                      set_default_backend)

__all__ = [
    "BACKENDS", "KernelConfig", "can_compile_pallas", "default_backend",
    "register_cache_clear", "resolve", "set_default_backend",
]
