"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are also the XLA fallback paths the models/engine actually run on CPU
and in the dry-run (Mosaic kernels cannot lower on the CPU backend).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q, k, v: [BH, S, D] — dense softmax attention."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array,
            n_heads_per_group: int):
    """Naive sequential SSD recurrence. x: [BH,S,P]; dA: [BH,S];
    Bm/Cm: [Bg,S,N].  Returns (y [BH,S,P], h [BH,N,P])."""
    BH, S, P = x.shape
    H = n_heads_per_group
    N = Bm.shape[-1]

    def one(bh):
        b = bh // H

        def step(h, t):
            a = jnp.exp(dA[bh, t])
            h = h * a + jnp.outer(Bm[b, t], x[bh, t])       # [N, P]
            y = Cm[b, t] @ h                                # [P]
            return h, y

        h0 = jnp.zeros((N, P), jnp.float32)
        h, ys = jax.lax.scan(step, h0, jnp.arange(S))
        return ys, h

    ys, hs = jax.vmap(one)(jnp.arange(BH))
    return ys.astype(x.dtype), hs


def version_scan_ref(cids: jax.Array, tids: jax.Array, max_cid: jax.Array):
    """cids/tids: [M, V]; max_cid: [M]. Returns (slot [M], cid [M])."""
    ok = (tids != -1) & (cids <= max_cid[:, None])
    masked = jnp.where(ok, cids, -1)
    slot = jnp.argmax(masked, axis=1)
    best = jnp.take_along_axis(masked, slot[:, None], axis=1)[:, 0]
    return slot.astype(jnp.int32), best.astype(jnp.int32)


def potential_matrix_ref(read_key: jax.Array, write_key: jax.Array) -> jax.Array:
    """[T,O] x [T,O] -> [T,T] int8 rw-candidate matrix (diagonal zero).

    The ONLY jnp home of the anti-dependency build: ``commit_phase
    .build_potential`` routes its jnp leg here and the Pallas kernel
    (`interval_negotiate`) is validated against it.  Distinct negative
    sentinels (-1 reads, -2 writes) keep masked/NOP ops — which may share a
    padding key — from ever matching each other.
    """
    rk = jnp.where(read_key >= 0, read_key, -1)
    wk = jnp.where(write_key >= 0, write_key, -2)
    eq = rk[:, None, :, None] == wk[None, :, None, :]
    pot = eq.any(axis=(2, 3))
    T = read_key.shape[0]
    return (pot & ~jnp.eye(T, dtype=bool)).astype(jnp.int8)


def wave_commit_ref(cids: jax.Array, tids: jax.Array, sids: jax.Array,
                    vals: jax.Array, max_cid: jax.Array, read_key: jax.Array,
                    write_key: jax.Array, rvalid: jax.Array):
    """Fused wave read-phase oracle: the exact composition of
    ``version_scan_ref`` + slot gathers + the rule-3 seed reduction +
    ``potential_matrix_ref`` that the unfused engine path runs.

    cids/tids/sids/vals: [T, O, V] gathered rings; max_cid/read_key/
    write_key: [T, O]; rvalid: [T, O] bool (read AND owned — the s_lo0
    seed mask).  Returns (slot, r_val, r_tid, r_cid, r_sid [T, O] int32,
    s_lo0 [T] int32, potential [T, T] int8).
    """
    T, O, V = cids.shape
    slot, _ = version_scan_ref(cids.reshape(-1, V), tids.reshape(-1, V),
                               max_cid.reshape(-1))
    slot = slot.reshape(T, O)
    take = lambda a: jnp.take_along_axis(a, slot[..., None], axis=-1)[..., 0]
    r_val, r_tid, r_cid, r_sid = take(vals), take(tids), take(cids), take(sids)
    s_lo0 = jnp.where(rvalid, r_cid, 0).max(axis=1).astype(jnp.int32)
    pot = potential_matrix_ref(read_key, write_key)
    return (slot.astype(jnp.int32), r_val, r_tid, r_cid, r_sid, s_lo0, pot)
