"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (B*H, num_chunks) — the chunk axis is innermost and sequential; the
inter-chunk recurrent state h [N, P] lives in VMEM scratch, so the whole
sequence is processed with a single HBM pass over x/B/C (the XLA fallback
materializes per-chunk states in HBM).

Per chunk (Q x P tile of x, Q x N tiles of B/C, Q-vector of log-decays dA):
  cs      = cumsum(dA)
  L       = tril(exp(cs_i - cs_j))                  intra-chunk decay
  y_diag  = (C B^T  .* L) x
  y_off   = exp(cs) * (C h)
  h'      = exp(cs_Q) h + B^T (exp(cs_Q - cs) .* x)

B/C are shared across the H heads of a group (G=1): the BlockSpec index map
divides the head-program id by H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, da_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)                    # [Q, P]
    da = da_ref[0].astype(jnp.float32)                  # [Q]
    Bm = b_ref[0].astype(jnp.float32)                   # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                   # [Q, N]
    Q = x.shape[0]

    cs = jnp.cumsum(da)                                 # [Q]
    seg = cs[:, None] - cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(li >= lj, jnp.exp(seg), 0.0)          # [Q, Q]

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    y = jax.lax.dot_general(scores * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    h = h_ref[...]                                      # [N, P]
    y_off = jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0] = (y + y_off).astype(y_ref.dtype)

    decay_in = jnp.exp(cs[Q - 1] - cs)[:, None] * x     # [Q, P]
    h_new = jnp.exp(cs[Q - 1]) * h + jax.lax.dot_general(
        Bm, decay_in, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_ref[...] = h_new

    @pl.when(ci == nc - 1)
    def _done():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array,
                    *, n_heads_per_group: int, chunk: int = 128,
                    interpret: bool = False):
    """x: [BH, S, P]; dA: [BH, S]; Bm, Cm: [Bg, S, N] with Bg = BH // H.

    Returns (y [BH, S, P], final_state [BH, N, P]).
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    H = n_heads_per_group
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kern = functools.partial(_kernel, nc=nc)
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b // H, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b // H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dA, Bm, Cm)
