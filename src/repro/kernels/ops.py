"""jit'd public wrappers around the Pallas kernels.

Each op takes ``use_pallas`` / ``interpret``:
  use_pallas=False          -> the pure-jnp oracle (ref.py) — what the models
                               and the CPU dry-run actually lower;
  use_pallas=True           -> pl.pallas_call, Mosaic on real TPU;
  use_pallas=True, interpret=True -> kernel body interpreted on CPU
                               (how the tests validate the kernels here).

Wrappers own all TPU alignment: head folding, GQA KV repetition, lane
padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .interval_negotiate import potential_matrix_pallas
from .ssd_scan import ssd_scan_pallas
from .version_scan import version_scan_pallas
from .wave_commit import wave_commit_pallas


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "use_pallas",
                                             "interpret", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, use_pallas=False, interpret=False,
                    block_q=128, block_k=128):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KH, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    # fold heads; repeat KV across the GQA group (kernel-validation path; the
    # on-TPU variant maps kv blocks to head groups via the BlockSpec index map)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    if use_pallas:
        Dp = ((D + 127) // 128) * 128
        qp = _pad_to(qf, 128, 2)
        kp = _pad_to(kf, 128, 2)
        vp = _pad_to(vf, 128, 2)
        import math
        o = flash_attention_pallas(qp, kp, vp, causal=causal,
                                   block_q=min(block_q, Sq),
                                   block_k=min(block_k, kf.shape[1]),
                                   sm_scale=1.0 / math.sqrt(D),
                                   interpret=interpret)[:, :, :D]
    else:
        o = ref.attention_ref(qf, kf, vf, causal=causal)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_heads_per_group", "chunk",
                                             "use_pallas", "interpret"))
def ssd(x, dA, Bm, Cm, *, n_heads_per_group, chunk=128, use_pallas=False,
        interpret=False):
    """x: [BH, S, P]; dA: [BH, S]; Bm/Cm: [Bg, S, N] ->
    (y [BH, S, P], final state [BH, N, P])."""
    if use_pallas:
        return ssd_scan_pallas(x, dA, Bm, Cm,
                               n_heads_per_group=n_heads_per_group,
                               chunk=chunk, interpret=interpret)
    return ref.ssd_ref(x, dA, Bm, Cm, n_heads_per_group)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_m"))
def version_scan(cids, tids, max_cid, *, use_pallas=False, interpret=False,
                 block_m=256):
    """cids/tids: [M, V] int32; max_cid: [M] -> (slot [M], cid [M])."""
    if not use_pallas:
        return ref.version_scan_ref(cids, tids, max_cid)
    M = cids.shape[0]
    bm = min(block_m, M)
    cp = _pad_to(_pad_to(cids, 128, 1, value=-1), bm, 0, value=-1)
    tp = _pad_to(_pad_to(tids, 128, 1, value=-1), bm, 0, value=-1)
    mc = jnp.broadcast_to(max_cid[:, None], (M, 128))
    mc = _pad_to(mc, bm, 0)
    slot, best = version_scan_pallas(cp, tp, mc, block_m=bm,
                                     interpret=interpret)
    return slot[:M], best[:M]


# ---------------------------------------------------------------------------
# batched commit-phase data movement (jnp scatter/gather: no Pallas variant —
# XLA already emits single fused scatters; they live here so the substrate's
# whole data plane is kernel-plane ops and the engine body stays pure rule
# arithmetic over op outputs)
# ---------------------------------------------------------------------------

def sid_regather(sid, keys, slots):
    """Rule-4(a) input: re-gather the SIDs of previously read (key, slot)
    pairs — peers may have bumped them since the read phase.
    sid: [n_keys, V]; keys/slots: [...] -> [...]."""
    return sid[keys, slots]


def masked_install(val, tid, cid, sid, head, wave, *, mask, keys, values,
                   new_tid, new_cid, wave_idx):
    """Masked version install over a key batch (rule 4(c) CID stamping).

    Pushes a new ring version for every key with ``mask`` set: the slot after
    ``head`` is overwritten, SID resets to 0, ``head``/``wave`` advance.
    Masked-off rows are routed to an OOB sentinel and dropped by the scatter;
    masked/NOP keys (which may be negative padding) are clamped before the
    ``head`` gather so they can never wrap to a real key.  Returns the six
    updated ring arrays.
    """
    n_keys, n_versions = val.shape
    k_install = jnp.where(mask, keys, n_keys)
    h_new = (head[jnp.clip(keys, 0, n_keys - 1)] + 1) % n_versions
    return (val.at[k_install, h_new].set(values, mode="drop"),
            tid.at[k_install, h_new].set(new_tid, mode="drop"),
            cid.at[k_install, h_new].set(new_cid, mode="drop"),
            sid.at[k_install, h_new].set(0, mode="drop"),
            head.at[k_install].set(h_new, mode="drop"),
            wave.at[k_install].set(wave_idx, mode="drop"))


def masked_sid_bump(sid, tid, *, mask, keys, slots, expect_tid, s_val):
    """Rule-4(c) SID bump over a key batch: raise the SID of read versions to
    the reader's start time, guarded against ring slots recycled since the
    read (creator TID must still match).  Returns the updated sid array."""
    n_keys = sid.shape[0]
    ok = mask & (tid[keys, slots] == expect_tid)
    k_sid = jnp.where(ok, keys, n_keys)
    return sid.at[k_sid, slots].max(s_val, mode="drop")


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_t"))
def wave_commit(cids, tids, sids, vals, max_cid, read_key, write_key, rvalid,
                *, use_pallas=False, interpret=False, block_t=128):
    """Fused wave read phase: version-scan slot selection + selected-version
    gathers + PostSI rule-3 seed + anti-dependency build in ONE kernel
    launch (DESIGN.md §7; bodies shared with ``version_scan`` /
    ``potential_matrix``, validated bit-identical against their composition).

    cids/tids/sids/vals: [T, O, V] int32 gathered rings; max_cid/read_key/
    write_key: [T, O] int32 (-1 key sentinel = inactive op); rvalid: [T, O]
    bool — the s_lo0 seed mask (read AND owned, so the mesh substrate can
    pmax-merge per-node partial maxima).  Returns (slot, r_val, r_tid,
    r_cid, r_sid [T, O] int32, s_lo0 [T] int32, potential [T, T] int8).
    """
    if not use_pallas:
        return ref.wave_commit_ref(cids, tids, sids, vals, max_cid,
                                   read_key, write_key, rvalid)
    T, O, V = cids.shape
    assert V <= 128, V                 # ring fits one lane register
    bt = min(block_t, T)
    # rings: V -> 128 lanes, O -> 8 sublanes, T -> block multiple; padded
    # slots carry tid=-1 (never visible), padded rows/ops are sliced off
    pad3 = lambda a, v: _pad_to(_pad_to(_pad_to(a, 128, 2, value=v),
                                        8, 1, value=v), bt, 0, value=v)
    pad2 = lambda a, v: _pad_to(_pad_to(a, 8, 1, value=v), bt, 0, value=v)
    slot, r_val, r_tid, r_cid, r_sid, slo, pot = wave_commit_pallas(
        pad3(cids, 0), pad3(tids, -1), pad3(sids, 0), pad3(vals, 0),
        pad2(max_cid, 0), pad2(read_key, -1), pad2(write_key, -1),
        pad2(rvalid.astype(jnp.int32), 0), block_t=bt, interpret=interpret)
    return (slot[:T, :O], r_val[:T, :O], r_tid[:T, :O], r_cid[:T, :O],
            r_sid[:T, :O], slo[:T, 0], pot[:T, :T])


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_t"))
def potential_matrix(read_key, write_key, *, use_pallas=False, interpret=False,
                     block_t=128):
    """[T, O] read/write key sets -> [T, T] int8 anti-dependency candidates."""
    if not use_pallas:
        return ref.potential_matrix_ref(read_key, write_key)
    T = read_key.shape[0]
    bt = min(block_t, T)
    rk = _pad_to(read_key, bt, 0, value=-1)
    wk = _pad_to(write_key, bt, 0, value=-1)
    out = potential_matrix_pallas(rk, wk, block_t=bt, interpret=interpret)
    return out[:T, :T]
