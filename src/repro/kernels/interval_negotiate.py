"""Pallas TPU kernel for the PostSI negotiation hot spot: the dense
anti-dependency matrix  potential[i, j] = "txn i read a key that txn j
writes" (paper CV rule 6 / PostSI rule 4 feed).

This is the O(T^2 O^2) core of the wave commit phase.  Tiling: [BT x BT]
output tiles over the (reader, writer) transaction grid; the O read keys and
O write keys per transaction are compared with static O^2 broadcast-compare
accumulation in VMEM (O is small: 4-12).

The bound updates themselves (rule 4a/4b min/max folds over the matrix) are
cheap [T,T]x[T] reductions left to XLA — the matrix build is the hot spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rk_ref, wk_ref, out_ref, *, block_t: int, n_ops: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rk = rk_ref[...]                                    # [BT, O] (reader keys)
    wk = wk_ref[...]                                    # [BT, O] (writer keys)
    acc = jnp.zeros((block_t, block_t), jnp.bool_)
    for o1 in range(n_ops):
        r = rk[:, o1]                                   # [BT]
        for o2 in range(n_ops):
            w = wk[:, o2]                               # [BT]
            acc = acc | ((r[:, None] == w[None, :]) & (r[:, None] >= 0))
    # mask the diagonal (i == j transactions)
    gi = i * block_t + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
    gj = j * block_t + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    out_ref[...] = (acc & (gi != gj)).astype(jnp.int8)


def potential_matrix_pallas(read_key: jax.Array, write_key: jax.Array, *,
                            block_t: int = 128, interpret: bool = False
                            ) -> jax.Array:
    """read_key/write_key: [T, O] int32 with -1 for inactive ops.
    Returns potential [T, T] int8 (1 = rw edge candidate)."""
    T, O = read_key.shape
    assert T % block_t == 0, (T, block_t)
    kern = functools.partial(_kernel, block_t=block_t, n_ops=O)
    return pl.pallas_call(
        kern,
        grid=(T // block_t, T // block_t),
        in_specs=[
            pl.BlockSpec((block_t, O), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, O), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, T), jnp.int8),
        interpret=interpret,
    )(read_key, write_key)
