"""Pallas TPU flash attention (causal, online softmax, block-skipping).

Grid: (batch*heads, num_q_blocks, num_kv_blocks) — the kv axis is innermost
and sequential, so the running (m, l, acc) statistics live in VMEM scratch
across kv iterations.  Causal block skipping: kv blocks strictly above the
diagonal are predicated off with ``pl.when`` — this is the ~2x FLOP saving
over the masked full-grid XLA fallback (models/layers._chunked_attention).

Layout per block:
  q tile  [BQ, D]   VMEM
  k tile  [BK, D]   VMEM
  v tile  [BK, D]   VMEM
  scratch acc [BQ, D] f32, m/l [BQ, 128] f32 (lane-padded)

TPU alignment: BQ/BK multiples of 128 (MXU), D a multiple of 128 (lanes) —
``ops.flash_attention`` pads when needed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip kv blocks entirely above the diagonal
    run = True if not causal else (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                   # [BQ, D]
        k = k_ref[0].astype(jnp.float32)                   # [BK, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[:, 0]                               # [BQ]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
        m_ref[:, 0] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False,
                           sm_scale: float | None = None) -> jax.Array:
    """q: [BH, Sq, D]; k, v: [BH, Sk, D] (heads folded into leading dim).
    ``sm_scale`` overrides 1/sqrt(D) when D was lane-padded by the caller."""
    BH, S, D = q.shape
    Sk = k.shape[1]
    assert S % block_q == 0 and Sk % block_k == 0, (S, Sk, block_q, block_k)
    nq, nk = S // block_q, Sk // block_k
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)

    kern = functools.partial(_kernel, causal=causal, scale=scale,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
