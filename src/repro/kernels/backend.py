"""The kernel-backend plane: one resolved config for every data-plane op.

Every compute hot spot the engine dispatches — the read-phase
latest-visible-version selection (``ops.version_scan``, paper §IV-B CID
rule), the anti-dependency candidate build (``ops.potential_matrix``, CV
rule 6 / PostSI negotiation input) — routes through a single
:class:`KernelConfig` instead of a per-op module global.  The config is
resolved ONCE (``auto`` never survives resolution) and then *threaded as a
field of the data-access substrate* (``core.substrate``), so a jitted
engine has its backend baked in at trace time and two engines with
different backends coexist in one process.

Backends:

  ``pallas``           Mosaic-compiled kernels (TPU).
  ``pallas_interpret`` the same kernel bodies, interpreted (CPU fallback;
                       how CI exercises the kernels — bit-identical to
                       ``pallas`` by construction).
  ``jnp``              pure-jnp references (``kernels.ref``) — the escape
                       hatch and the differential-test oracle.
  ``auto``             resolves to ``pallas`` on TPU, ``pallas_interpret``
                       elsewhere.  Only accepted as *input*; a resolved
                       :class:`KernelConfig` never carries it.

Process default: ``default_backend()`` reads env ``REPRO_KERNEL_BACKEND``
(falling back to the pre-refactor ``REPRO_POTENTIAL_BACKEND`` name, then
``auto``); ``set_default_backend`` switches it and clears every jit cache
registered via :func:`register_cache_clear`, because engines that defaulted
to the process config baked it in at trace time.  Explicitly-threaded
configs need no cache clearing: a different resolved config is a different
static jit argument.
"""
from __future__ import annotations

import dataclasses
import os

import jax

BACKENDS = ("pallas", "pallas_interpret", "jnp")
_INPUT_BACKENDS = BACKENDS + ("auto",)


def _resolve_name(name: str) -> str:
    assert name in _INPUT_BACKENDS, (name, _INPUT_BACKENDS)
    if name != "auto":
        return name
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Resolved kernel-backend choice for one substrate/engine instance.

    Frozen + hashable so it can ride as a static jit argument and as an
    ``lru_cache`` key for the shard_map executors.  ``backend`` is always a
    concrete member of :data:`BACKENDS` — construct via :func:`resolve` (or
    pass ``"auto"`` to ``KernelConfig`` itself, which resolves eagerly).
    """
    backend: str = "auto"

    def __post_init__(self):
        object.__setattr__(self, "backend", _resolve_name(self.backend))

    @property
    def use_pallas(self) -> bool:
        """The ``use_pallas`` flag of the ``kernels.ops`` wrappers."""
        return self.backend != "jnp"

    @property
    def interpret(self) -> bool:
        """The ``interpret`` flag of the ``kernels.ops`` wrappers."""
        return self.backend == "pallas_interpret"


def resolve(spec=None) -> KernelConfig:
    """Normalize ``None`` (process default) / backend name / config into a
    resolved :class:`KernelConfig`."""
    if spec is None:
        spec = default_backend()
    if isinstance(spec, KernelConfig):
        return spec
    return KernelConfig(spec)


# ---------------------------------------------------------------------------
# process default + jit-cache invalidation for engines that bake it in
# ---------------------------------------------------------------------------

_default = os.environ.get(
    "REPRO_KERNEL_BACKEND",
    os.environ.get("REPRO_POTENTIAL_BACKEND", "auto"))
_clear_hooks: list = []


def register_cache_clear(jitted) -> None:
    """Engines whose traces read the *process default* register their jitted
    entry points here; :func:`set_default_backend` clears them so a switch
    takes effect on the next dispatch."""
    _clear_hooks.append(jitted)


def set_default_backend(name: str) -> None:
    """Switch the process-default backend (accepts ``auto``) and clear the
    registered jit caches."""
    global _default
    assert name in _INPUT_BACKENDS, (name, _INPUT_BACKENDS)
    _default = name
    for fn in _clear_hooks:
        try:
            fn.clear_cache()
        except Exception:
            pass


def default_backend() -> str:
    """The resolved (never ``auto``) process-default backend name."""
    return _resolve_name(_default)
