"""The kernel-backend plane: one resolved config for every data-plane op.

Every compute hot spot the engine dispatches — the read-phase
latest-visible-version selection (``ops.version_scan``, paper §IV-B CID
rule), the anti-dependency candidate build (``ops.potential_matrix``, CV
rule 6 / PostSI negotiation input) — routes through a single
:class:`KernelConfig` instead of a per-op module global.  The config is
resolved ONCE (``auto`` never survives resolution) and then *threaded as a
field of the data-access substrate* (``core.substrate``), so a jitted
engine has its backend baked in at trace time and two engines with
different backends coexist in one process.

Backends:

  ``pallas``           Mosaic-compiled kernels (TPU).
  ``pallas_interpret`` the same kernel bodies, interpreted (CPU fallback;
                       how CI exercises the kernels — bit-identical to
                       ``pallas`` by construction).
  ``jnp``              pure-jnp references (``kernels.ref``) — the escape
                       hatch and the differential-test oracle.
  ``auto``             resolves to ``pallas`` on TPU, ``pallas_interpret``
                       elsewhere.  Only accepted as *input*; a resolved
                       :class:`KernelConfig` never carries it.

Fusion: orthogonally to the backend, ``KernelConfig(fused=True)`` routes
the whole wave read phase (slot selection + rule-3 interval seed +
anti-dependency build) through the single-launch ``ops.wave_commit``
megakernel instead of three separate dispatches — bit-identical by
construction (DESIGN.md §7).  A backend spec string may carry it as a
``"+fused"`` suffix (``"pallas_interpret+fused"``) so the knob threads
through every name-typed seam (env var, CLI, bench labels) unchanged.

Process default: ``default_backend()`` reads env ``REPRO_KERNEL_BACKEND``
(falling back to the pre-refactor ``REPRO_POTENTIAL_BACKEND`` name, then
``auto``), with env ``REPRO_KERNEL_FUSED=1`` forcing the fused route;
``set_default_backend`` switches it and clears every jit cache
registered via :func:`register_cache_clear`, because engines that defaulted
to the process config baked it in at trace time.  Explicitly-threaded
configs need no cache clearing: a different resolved config is a different
static jit argument.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax

BACKENDS = ("pallas", "pallas_interpret", "jnp")
_INPUT_BACKENDS = BACKENDS + ("auto",)
_FUSED_SUFFIX = "+fused"


def _parse_spec(name: str):
    """Split an input spec into (base backend name, fused flag)."""
    fused = name.endswith(_FUSED_SUFFIX)
    return (name[:-len(_FUSED_SUFFIX)] if fused else name), fused


def _resolve_name(name: str) -> str:
    assert name in _INPUT_BACKENDS, (name, _INPUT_BACKENDS)
    if name != "auto":
        return name
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Resolved kernel-backend choice for one substrate/engine instance.

    Frozen + hashable so it can ride as a static jit argument and as an
    ``lru_cache`` key for the shard_map executors.  ``backend`` is always a
    concrete member of :data:`BACKENDS` — construct via :func:`resolve` (or
    pass ``"auto"`` to ``KernelConfig`` itself, which resolves eagerly; a
    ``"+fused"`` suffix on the name sets ``fused``).

    ``fused`` selects the single-launch ``ops.wave_commit`` read-phase
    megakernel over the three-dispatch route; it composes with any backend
    (the jnp leg runs the fused reference composition in ``kernels.ref``).
    """
    backend: str = "auto"
    fused: bool = False

    def __post_init__(self):
        base, fused = _parse_spec(self.backend)
        object.__setattr__(self, "backend", _resolve_name(base))
        if fused:
            object.__setattr__(self, "fused", True)

    @property
    def use_pallas(self) -> bool:
        """The ``use_pallas`` flag of the ``kernels.ops`` wrappers."""
        return self.backend != "jnp"

    @property
    def interpret(self) -> bool:
        """The ``interpret`` flag of the ``kernels.ops`` wrappers."""
        return self.backend == "pallas_interpret"

    @property
    def name(self) -> str:
        """Round-trippable spec string (``resolve(cfg.name) == cfg``)."""
        return self.backend + (_FUSED_SUFFIX if self.fused else "")


def resolve(spec=None) -> KernelConfig:
    """Normalize ``None`` (process default) / backend name / config into a
    resolved :class:`KernelConfig`."""
    if spec is None:
        spec = default_backend()
    if isinstance(spec, KernelConfig):
        return spec
    return KernelConfig(spec)


# ---------------------------------------------------------------------------
# capability probe: can THIS process actually compile-and-run a Mosaic
# Pallas kernel?  The mesh path (``substrate.mesh_kernels``) degrades
# ``pallas`` to the bit-identical ``jnp`` reference only when this says no —
# per-shard block shapes are static under shard_map, so compiled kernels are
# legal whenever the platform can lower them at all.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def can_compile_pallas() -> bool:
    """True iff a non-interpret ``pl.pallas_call`` compiles AND runs here.

    Probes by executing a tiny aligned kernel once per process (cached).
    On CPU this fails (Mosaic needs a TPU target), which is exactly the
    signal the mesh dispatch uses to gate its explicit jnp fallback.
    """
    try:
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _probe(x_ref, o_ref):
            o_ref[...] = x_ref[...] + 1

        out = pl.pallas_call(
            _probe,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.int32),
        )(jnp.zeros((8, 128), jnp.int32))
        jax.block_until_ready(out)
        return True
    except Exception:
        return False


# ---------------------------------------------------------------------------
# process default + jit-cache invalidation for engines that bake it in
# ---------------------------------------------------------------------------

_default = os.environ.get(
    "REPRO_KERNEL_BACKEND",
    os.environ.get("REPRO_POTENTIAL_BACKEND", "auto"))
if os.environ.get("REPRO_KERNEL_FUSED", "") not in ("", "0") \
        and not _default.endswith(_FUSED_SUFFIX):
    _default = _default + _FUSED_SUFFIX
_clear_hooks: list = []


def register_cache_clear(jitted) -> None:
    """Engines whose traces read the *process default* register their jitted
    entry points here; :func:`set_default_backend` clears them so a switch
    takes effect on the next dispatch."""
    _clear_hooks.append(jitted)


def set_default_backend(name: str) -> None:
    """Switch the process-default backend (accepts ``auto`` and a
    ``"+fused"`` suffix) and clear the registered jit caches."""
    global _default
    base, _ = _parse_spec(name)
    assert base in _INPUT_BACKENDS, (name, _INPUT_BACKENDS)
    _default = name
    for fn in _clear_hooks:
        try:
            fn.clear_cache()
        except Exception:
            pass


def default_backend() -> str:
    """The resolved (never ``auto``) process-default backend spec — the
    backend name plus an optional ``"+fused"`` suffix."""
    base, fused = _parse_spec(_default)
    return _resolve_name(base) + (_FUSED_SUFFIX if fused else "")
