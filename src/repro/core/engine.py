"""Vectorized wave-execution engine for all six schedulers.

The paper's asynchronous shared-nothing execution is mapped onto *waves*
(DESIGN.md §2): a wave is a batch of transactions whose lifespans all overlap
— they read the wave-start snapshot in parallel, keep write sets private
(paper §IV-C) and then commit one-by-one in a deterministic order, which is
where the paper's rules fire:

  read phase   — CV rule 4 / PostSI §IV-B CID visibility + PostSI rule 3
                 (raise s_lo/c_lo to the CID of every version read),
  commit phase — CV rules 5-6 (write validation, anti-dependency capture) and
                 PostSI rule 4 (a: pick own interval from SIDs + ongoing
                 readers' s_lo; b: push bounds of conflicting ongoing txns;
                 c: stamp CIDs, bump SIDs) and rule 5 (abort on s_lo > s_hi).

The anti-dependency table is the dense boolean matrix ``potential[i, j]`` =
"txn i read a key that txn j writes"; an edge *exists* (paper's table entry)
once j commits, and is consulted only while i/j are ongoing — committed
readers hand over via SIDs exactly as in the paper.

Schedulers:
  postsi   — the paper's contribution (decentralized, negotiated intervals)
  cv       — Consistent Visibility only (no interval induction)
  si       — conventional SI: central coordinator allocates snapshots
             (2 coordinator round-trips per txn, counted)
  optimal  — conventional procedure minus all coordination (upper bound;
             not guaranteed correct, per the paper)
  dsi      — incremental-snapshot DSI: coordinator involved for distributed
             txns; remote-read snapshot mismatch aborts
  clocksi  — loosely synchronized per-node clocks with ``skew`` (in waves);
             behind-host txns read stale snapshots, ahead-remote reads wait

Drivers (DESIGN.md §7): ``run_workload_fused`` stacks a whole workload into
[W, T, O] batches and executes it as ONE device program — a single
``lax.scan`` over waves carrying (store, clock), no per-wave host round
trips.  ``run_workload`` dispatches one jitted wave at a time and syncs each
WaveOut to host; it is kept as the debug/differential path and the fused
executor is bit-identical to it (tests/test_fused_executor.py).

The commit-phase arithmetic (rules 3/4/5, the ``potential`` matrix build)
lives in ``commit_phase`` and is shared with the shard_map engine in
``dist_engine.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import KernelConfig, register_cache_clear, resolve
from .commit_phase import (ABORTED, COMMITTED, NOP, READ, RMW, RUNNING, WRITE,
                           creator_slots, lost_update, ongoing_readers_of,
                           postsi_bounds, push_bounds, rw_edge_to_creator)
from .store import (INF, MVStore, PlacementArrays, as_placement_arrays,
                    node_of_key)
from .substrate import LocalSubstrate

SCHEDULERS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")
WAVE_STRIDE = 1 << 16      # logical clock stride per wave for clocked baselines


class Wave(NamedTuple):
    op_kind: jax.Array    # [T, O] int32
    op_key: jax.Array     # [T, O] int32
    op_val: jax.Array     # [T, O] int32
    host: jax.Array       # [T] int32 host node per txn
    tid: jax.Array        # [T] int32 global tids (unique, > 0)


class WaveOut(NamedTuple):
    status: jax.Array     # [T] RUNNING/COMMITTED/ABORTED
    s: jax.Array          # [T] final start time
    c: jax.Array          # [T] final commit time
    read_key: jax.Array   # [T, O] (-1 where not a read)
    read_cid: jax.Array   # [T, O]
    write_key: jax.Array  # [T, O] (-1 where not a write)
    write_cid: jax.Array  # [T, O] cid stamped on installed versions
    # stats
    msgs_cross: jax.Array  # scalar: cross-node data/negotiation messages
    msgs_coord: jax.Array  # scalar: messages through the central coordinator
    waits: jax.Array       # scalar: clock-si skew waits
    evicted_visible: jax.Array  # scalar: ring-slot reuses of still-visible
                                # versions (GC watermark violations, §8)


def run_wave_on(sub, store: MVStore, wave: Wave, wave_idx: jax.Array,
                clock: jax.Array, n_nodes: jax.Array = 8,
                sched: str = "postsi", skew: int = 0,
                host_skew: jax.Array | None = None,
                watermark: jax.Array | None = None, gc_track: bool = False,
                gc_block: bool = False,
                placement: PlacementArrays | None = None,
                ) -> Tuple[MVStore, WaveOut, jax.Array]:
    """Execute one wave on a data-access substrate (DESIGN.md §4).

    This function is the ONLY copy of the concurrency-control rules for all
    six schedulers; every data-plane access (read-phase lookup, commit-phase
    re-validation read, version install, SID bump, GC watermark consult)
    goes through ``sub`` — ``substrate.LocalSubstrate`` under the jitted
    single-device ``run_wave`` below, or ``substrate.MeshSubstrate`` inside
    the ``shard_map`` bodies of ``dist_engine``, which is how one commit
    loop serves every placement.  Pure trace-level function: callers own
    jit / shard_map / scan wrapping.  Returns (store', out, clock').

    ``placement`` (elastic routing, DESIGN.md §11): when given, logical
    keys are translated ONCE here — ``pkeys = slot[key]`` is the physical
    store row every substrate access uses.  Placement changes WHERE a ring
    lives, never WHAT the schedulers decide: the locality model the rules
    and message stats consult (dsi remoteness, clocksi node skew,
    msgs_cross) stays the logical ``key % n_nodes``, so any injective slot
    map — including one that changes mid-stream via range moves — yields
    bit-identical statuses/timestamps/history to ``placement=None``.  That
    invariance is what makes live repartitioning a pure data-plane
    operation (and what the static-vs-elastic differentials pin).
    Placement-aware load/occupancy accounting is host-side, from
    ``PlacementMap.owner`` (repro.placement).  Everything the caller sees
    (``read_key``/``write_key``, statuses, timestamps) stays LOGICAL."""
    assert sched in SCHEDULERS, sched
    T, O = wave.op_kind.shape
    clock0 = clock          # wave-entry clock = snapshot time for clocked scheds
    track_gc = gc_track or gc_block
    wm = clock if watermark is None else watermark
    is_read = (wave.op_kind == READ) | (wave.op_kind == RMW)
    is_write = (wave.op_kind == WRITE) | (wave.op_kind == RMW)
    keys = wave.op_key
    if placement is None:
        pkeys = keys                                   # slot[k] == k
    else:
        nk = placement.slot.shape[0]
        kc = jnp.clip(keys, 0, nk - 1)
        # negative NOP sentinels pass through untranslated — the substrates'
        # sentinel-drop / clamp handling must keep seeing them
        pkeys = jnp.where(keys >= 0, placement.slot[kc], keys)

    # ------------------------------------------------------------------ reads
    if sched == "clocksi":
        hs = host_skew if host_skew is not None else jnp.zeros((1,), jnp.int32)
        my_skew = hs[wave.host]                                   # [T]
        cutoff_wave = wave_idx - my_skew                          # snapshot wave
        # visible: newest version whose wave tag < cutoff (stale snapshot)
        key_wave, head_cid = sub.key_staleness(store, pkeys)      # [T,O] each
        stale = key_wave >= cutoff_wave[:, None]
        max_cid = jnp.where(stale, head_cid - 1, INF)
    else:
        max_cid = jnp.broadcast_to(jnp.int32(INF), keys.shape)

    # the whole read phase — slot selection, the PostSI rule-3 seed (raise
    # s_lo/c_lo to the CID of every version read) and the anti-dependency
    # candidate build — is one substrate call, so the fused ``wave_commit``
    # megakernel and the three-dispatch route swap under the engine without
    # the rules seeing a difference (DESIGN.md §7)
    # the potential matrix only needs key EQUALITY, which the injective slot
    # map preserves — so building it over pkeys is identical to logical keys
    (r_val, r_tid, r_cid, r_sid, r_slot, s_lo0,
     potential) = sub.read_phase(store, pkeys, max_cid, is_read, is_write)

    read_key = jnp.where(is_read, keys, -1)
    read_cid = jnp.where(is_read, r_cid, -1)
    c_lo0 = s_lo0
    s_hi0 = jnp.full((T,), INF, jnp.int32)

    # --------------------------------------------------------------- commits
    # deterministic commit order = wave-local index (tids ascend within wave)
    def commit_one(i, carry):
        (st, s_lo, s_hi, c_lo, status, s_arr, c_arr, wcid, clk, ev_cnt) = carry
        active = status[i] == RUNNING

        k_i = keys[i]                                             # [O] logical
        pk_i = pkeys[i]                                           # [O] physical
        w_i = is_write[i]
        r_i = is_read[i]
        nv_val, nv_tid, nv_cid, nv_sid, nv_slot = sub.read_newest(st, pk_i)

        # map newest creators to wave-local ids (or -1 if older wave)
        local, creator_committed = creator_slots(nv_tid, wave.tid[0], T, status)

        # lost update: an RMW whose read version is no longer newest
        lost = lost_update(r_i, w_i, nv_cid, r_cid[i])
        # CV rule 5(ii): newest creator has an rw edge from me (I read data it
        # overwrote) -> it is invisible to me -> cannot overwrite its version
        if sched in ("postsi", "cv"):
            rw_to_creator = rw_edge_to_creator(w_i, local, creator_committed,
                                               potential[i])
        else:
            rw_to_creator = jnp.array(False)

        if sched in ("si", "dsi", "clocksi", "optimal"):
            # first-committer-wins: any write over a same-wave commit aborts
            ww_conc = (w_i & (local >= 0) & creator_committed).any()
        else:  # postsi / cv allow overwriting a committed peer (Fig.1 t2/t3)
            ww_conc = jnp.array(False)

        abort = lost | rw_to_creator | ww_conc

        if sched == "dsi":
            # incremental snapshot: a *remote* read whose key was meanwhile
            # overwritten implies a local/global timestamp mismatch -> abort
            remote = node_of_key(k_i, n_nodes) != wave.host[i]
            stale_remote = (r_i & remote & (nv_cid != r_cid[i])).any()
            abort = abort | stale_remote

        if sched == "postsi":
            # rules 3/4(a)/5 (commit_phase.postsi_bounds); SIDs of read slots
            # are re-gathered: peers may have bumped them while we ran
            cur_sid = sub.read_sid(st, pk_i, r_slot[i])
            ongoing_reader = ongoing_readers_of(i, potential, status)
            s_i, c_i, iv_abort = postsi_bounds(
                s_lo[i], s_hi[i], c_lo[i], r_i, w_i, nv_cid, nv_sid, cur_sid,
                ongoing_reader, s_lo)
            abort = abort | iv_abort
        else:
            # clocked baselines: snapshot = wave-entry clock; commit = clock++
            s_i = clock0
            c_i = clk + 1

        # GC watermark consult (DESIGN.md §8): does any write reuse a ring
        # slot whose version is still visible above the watermark?
        if track_gc:
            evict_unsafe = w_i & sub.evicting_visible(st, pk_i, wm)   # [O]
        if gc_block:
            # blocked install: abort instead of corrupting still-visible
            # reads; retried once the watermark passes the superseder
            abort = abort | evict_unsafe.any()

        commit = active & ~abort
        new_status = jnp.where(active, jnp.where(abort, ABORTED, COMMITTED), status[i])

        # ---- install writes (masked scatter; owner/OOB handling is the
        # substrate's concern: sentinel-drop locally, owner-only on the mesh)
        wmask = w_i & commit
        val_new = jnp.where(wave.op_kind[i] == RMW, r_val[i] + wave.op_val[i],
                            wave.op_val[i])
        st = sub.install(st, wmask, pk_i, val_new, wave.tid[i], c_i, wave_idx)
        wcid = wcid.at[i].set(jnp.where(wmask, c_i, -1))

        # ---- rule 4(c): bump SIDs of read versions to my start time --------
        # guarded: skip if the ring slot was recycled since our wave-start read
        st = sub.bump_sid(st, r_i & commit, pk_i, r_slot[i], r_tid[i], s_i)

        # ---- rule 4(b): push bounds of conflicting *ongoing* transactions --
        if sched == "postsi":
            s_lo, s_hi, c_lo = push_bounds(i, commit, s_i, c_i, potential,
                                           status, s_lo, s_hi, c_lo)

        status = status.at[i].set(new_status)
        s_arr = s_arr.at[i].set(jnp.where(commit, s_i, -1))
        c_arr = c_arr.at[i].set(jnp.where(commit, c_i, -1))
        clk = jnp.where(commit, jnp.maximum(clk, c_i), clk)
        if track_gc:
            ev_cnt = ev_cnt + jnp.where(
                commit, evict_unsafe.astype(jnp.int32).sum(), 0)
        return (st, s_lo, s_hi, c_lo, status, s_arr, c_arr, wcid, clk, ev_cnt)

    status0 = jnp.full((T,), RUNNING, jnp.int32)
    s0 = jnp.full((T,), -1, jnp.int32)
    c0 = jnp.full((T,), -1, jnp.int32)
    wcid0 = jnp.full((T, O), -1, jnp.int32)

    (store, s_lo, s_hi, c_lo, status, s_arr, c_arr, wcid, clock,
     evicted) = lax.fori_loop(
        0, T, commit_one,
        (store, s_lo0, s_hi0, c_lo0, status0, s0, c0, wcid0, clock,
         jnp.int32(0)))

    write_key = jnp.where(is_write & (status[:, None] == COMMITTED), keys, -1)

    # ------------------------------------------------------------------ stats
    # work delegation batches per (txn, remote node) pair (paper §IV-A), so
    # cross-node messages count DISTINCT remote nodes touched, not raw ops
    MAX_NODES = 32
    op_node = node_of_key(keys, n_nodes)                               # [T,O]
    active_op = wave.op_kind != NOP
    node_ids = jnp.arange(MAX_NODES)[None, None, :]
    touch = (op_node[..., None] == node_ids) & active_op[..., None]    # [T,O,MN]
    node_touched = touch.any(axis=1)                                   # [T,MN]
    remote_mask = jnp.arange(MAX_NODES)[None, :] != wave.host[:, None]
    remote_nodes = (node_touched & remote_mask)
    msgs_cross = remote_nodes.sum()
    remote_op = (op_node != wave.host[:, None]) & active_op
    committed = status == COMMITTED
    if sched == "postsi":
        # negotiation: one message per DISTINCT peer host per committer
        edge = potential & committed[None, :]
        peer_host_hot = (wave.host[None, :, None] == node_ids) & edge[:, :, None]
        peer_hosts = peer_host_hot.any(axis=1)                         # [T,MN]
        cross_peer = peer_hosts & (jnp.arange(MAX_NODES)[None, :] != wave.host[:, None])
        msgs_cross = msgs_cross + cross_peer.sum()
        msgs_coord = jnp.int32(0)
    elif sched == "cv":
        # anti-dependency entries stored on both endpoint hosts (§IV-A):
        # insertion crosses hosts like PostSI negotiation ...
        edge = potential & committed[None, :]
        peer_host_hot = (wave.host[None, :, None] == node_ids) & edge[:, :, None]
        peer_hosts = peer_host_hot.any(axis=1)
        cross_peer = peer_hosts & (jnp.arange(MAX_NODES)[None, :] != wave.host[:, None])
        msgs_cross = msgs_cross + cross_peer.sum()
        # ... and reads consult the table on remote hosts (paper §V-D):
        # batched per (txn, remote node) visited for reading
        read_touch = (op_node[..., None] == node_ids) & (is_read & active_op)[..., None]
        read_nodes = (read_touch.any(axis=1) & remote_mask)
        msgs_cross = msgs_cross + read_nodes.sum()
        msgs_coord = jnp.int32(0)
    elif sched == "si":
        msgs_coord = jnp.int32(2 * T)                  # begin + end, per txn
    elif sched == "dsi":
        distributed = remote_op.any(axis=1)
        msgs_coord = 2 * distributed.sum()             # global txns pay globally
    elif sched == "clocksi":
        msgs_coord = jnp.int32(0)
    else:  # optimal
        msgs_coord = jnp.int32(0)

    waits = jnp.int32(0)
    if sched == "clocksi" and host_skew is not None:
        # ahead-snapshot reads on behind remote nodes must wait (paper §II)
        node_skew = host_skew[node_of_key(keys, n_nodes)]
        my_skew = host_skew[wave.host][:, None]
        waits = jnp.maximum(node_skew - my_skew, 0).sum(where=remote_op & is_read)

    out = WaveOut(status, s_arr, c_arr, read_key, read_cid, write_key, wcid,
                  msgs_cross, msgs_coord, waits, evicted)
    return store, out, clock


@functools.partial(jax.jit,
                   static_argnames=("sched", "skew", "gc_track", "gc_block",
                                    "kernels"))
def _run_wave_jit(store, wave, wave_idx, clock, n_nodes, sched, skew,
                  host_skew, watermark, gc_track, gc_block,
                  kernels: KernelConfig, placement=None):
    return run_wave_on(LocalSubstrate(kernels), store, wave, wave_idx, clock,
                       n_nodes, sched=sched, skew=skew, host_skew=host_skew,
                       watermark=watermark, gc_track=gc_track,
                       gc_block=gc_block, placement=placement)


def run_wave(store: MVStore, wave: Wave, wave_idx: jax.Array, clock: jax.Array,
             n_nodes: jax.Array = 8, sched: str = "postsi", skew: int = 0,
             host_skew: jax.Array | None = None,
             watermark: jax.Array | None = None, gc_track: bool = False,
             gc_block: bool = False,
             kernels: KernelConfig | str | None = None,
             placement=None) -> Tuple[MVStore, WaveOut, jax.Array]:
    """Execute one wave single-device. Returns (store', out, clock').
    ``n_nodes`` is traced, so scaling sweeps don't recompile.

    Thin jit wrapper: ``run_wave_on`` over a ``LocalSubstrate`` — the
    mesh engine wraps the very same function over a ``MeshSubstrate``
    (``dist_engine.run_wave_dist``).

    ``kernels`` picks the kernel backend for every data-plane hot spot — a
    resolved ``repro.kernels.KernelConfig``, a backend name (``"pallas"`` /
    ``"pallas_interpret"`` / ``"jnp"``), or ``None`` for the process
    default (env ``REPRO_KERNEL_BACKEND``).  It is resolved HERE, outside
    the jit boundary, so equivalent specs (a name, a config, or a matching
    process default) share one trace; the substrate is then built per
    trace with the resolved config baked in as a static argument.

    ``watermark`` is the GC watermark for version reclamation (DESIGN.md §8):
    the decentralized min over live readers' ``s_lo``.  In the wave model
    every reader's snapshot is pinned at a wave boundary, so the min
    collapses to the wave-entry clock; ``None`` defaults to exactly that.
    The closed-loop service passes an explicit (possibly lower) value when
    external readers pin it — e.g. clock-skewed hosts or retry pins.

    GC accounting is opt-in (static flags) so the pure replay path pays
    nothing for it.  With ``gc_track=True`` each install that would evict a
    version still visible above the watermark is counted in
    ``WaveOut.evicted_visible``; with ``gc_block=True`` the writer is
    aborted instead (and the counter stays 0), so the retry pipeline
    re-runs it after the watermark has advanced past the ring."""
    return _run_wave_jit(store, wave, wave_idx, clock, n_nodes, sched=sched,
                         skew=skew, host_skew=host_skew, watermark=watermark,
                         gc_track=gc_track, gc_block=gc_block,
                         kernels=resolve(kernels),
                         placement=as_placement_arrays(placement))


class RunStats(NamedTuple):
    committed: int
    aborted: int
    msgs_cross: int
    msgs_coord: int
    waits: int
    evicted_visible: int   # still-visible versions destroyed by ring reuse
    waves: int


def step_wave(store: MVStore, wave: Wave, wave_idx: int, clock,
              *, sched: str = "postsi", n_nodes: int = 8, skew: int = 0,
              host_skew: np.ndarray | None = None, watermark=None,
              gc_track: bool = True, gc_block: bool = False,
              kernels: KernelConfig | str | None = None, placement=None):
    """Closed-loop step API (DESIGN.md §8): execute ONE wave and sync the
    per-txn outcomes to host so a caller can requeue aborted transactions.

    Unlike the replay drivers below, the caller owns the loop: it keeps the
    device-resident ``store``/``clock`` opaque between steps and receives a
    numpy ``WaveOut`` whose ``status``/``s``/``c`` rows line up with
    ``wave.tid`` — everything the wave former and retry pipeline in
    ``repro.service`` need.  ``watermark``/``gc_block`` plumb the service's
    GC policy into the engine's install path.

    Returns ``(store', out_np, clock')``.
    """
    hs = None if host_skew is None else jnp.asarray(host_skew, jnp.int32)
    wm = None if watermark is None else jnp.int32(watermark)
    store, out, clock = run_wave(store, wave, jnp.int32(wave_idx), clock,
                                 jnp.int32(n_nodes), sched=sched, skew=skew,
                                 host_skew=hs, watermark=wm,
                                 gc_track=gc_track, gc_block=gc_block,
                                 kernels=kernels, placement=placement)
    return store, jax.tree_util.tree_map(np.asarray, out), clock


def run_workload(store: MVStore, waves, sched: str = "postsi", skew: int = 0,
                 host_skew: np.ndarray | None = None, n_nodes: int = 8,
                 gc_track: bool = False, gc_block: bool = False,
                 kernels: KernelConfig | str | None = None, placement=None):
    """Per-wave debug driver: one jitted dispatch + host sync per wave.

    Returns (store, history, stats); history is a list of numpy-ified
    WaveOut for the verifier.  The measured hot path is
    ``run_workload_fused`` (bit-identical output); this driver is kept as
    the reference for differential tests and wave-by-wave debugging.
    """
    clock = jnp.int32(1)
    hs = None if host_skew is None else jnp.asarray(host_skew, jnp.int32)
    history = []
    for w_idx, wave in enumerate(waves):
        store, out, clock = run_wave(store, wave, jnp.int32(w_idx + 1), clock,
                                     jnp.int32(n_nodes), sched=sched,
                                     skew=skew, host_skew=hs,
                                     gc_track=gc_track, gc_block=gc_block,
                                     kernels=kernels, placement=placement)
        history.append((np.asarray(wave.tid),
                        jax.tree_util.tree_map(np.asarray, out)))
    return store, history, _stats_of(history)


def _stats_of(history) -> RunStats:
    tot = dict(committed=0, aborted=0, msgs_cross=0, msgs_coord=0, waits=0,
               evicted_visible=0)
    for _, o in history:
        tot["committed"] += int((o.status == COMMITTED).sum())
        tot["aborted"] += int((o.status == ABORTED).sum())
        tot["msgs_cross"] += int(o.msgs_cross)
        tot["msgs_coord"] += int(o.msgs_coord)
        tot["waits"] += int(o.waits)
        tot["evicted_visible"] += int(o.evicted_visible)
    return RunStats(waves=len(history), **tot)


# ---------------------------------------------------------------------------
# fused multi-wave executor (DESIGN.md §7)
# ---------------------------------------------------------------------------

def stack_waves(waves) -> Wave:
    """Stack per-wave [T, O] arrays into one [W, T, O] batch (leading axis =
    wave index) — the scan carrier for the fused executor."""
    return Wave(*(jnp.stack([getattr(w, f) for w in waves])
                  for f in Wave._fields))


@functools.partial(jax.jit,
                   static_argnames=("sched", "skew", "gc_track", "gc_block",
                                    "kernels"))
def _scan_waves(store: MVStore, stacked: Wave, clock: jax.Array,
                n_nodes: jax.Array, sched: str = "postsi", skew: int = 0,
                host_skew: jax.Array | None = None, gc_track: bool = False,
                gc_block: bool = False,
                kernels: KernelConfig | str | None = None, placement=None):
    """One device program for a whole workload: lax.scan over the wave axis
    carrying (store, clock); each step is the run_wave computation inlined.
    ``run_workload_fused`` resolves ``kernels`` before this jit boundary.
    Returns (store', WaveOut with leading [W] axis, clock')."""
    W = stacked.op_kind.shape[0]

    def body(carry, xs):
        st, clk = carry
        wave, w_idx = xs
        st, out, clk = run_wave(st, wave, w_idx, clk, n_nodes, sched=sched,
                                skew=skew, host_skew=host_skew,
                                gc_track=gc_track, gc_block=gc_block,
                                kernels=kernels, placement=placement)
        return (st, clk), out

    (store, clock), outs = lax.scan(
        body, (store, clock), (stacked, jnp.arange(1, W + 1, dtype=jnp.int32)))
    return store, outs, clock


def run_workload_fused(store: MVStore, waves, sched: str = "postsi",
                       skew: int = 0, host_skew: np.ndarray | None = None,
                       n_nodes: int = 8, gc_track: bool = False,
                       gc_block: bool = False,
                       kernels: KernelConfig | str | None = None,
                       placement=None):
    """Fused driver: the entire workload as a single jitted dispatch.

    Same signature and same (store, history, stats) contract as
    ``run_workload``, with bit-identical WaveOut history — only the host
    round-trips per wave are gone.
    """
    stacked = stack_waves(waves)
    hs = None if host_skew is None else jnp.asarray(host_skew, jnp.int32)
    store, outs, _ = _scan_waves(store, stacked, jnp.int32(1),
                                 jnp.int32(n_nodes), sched=sched, skew=skew,
                                 host_skew=hs, gc_track=gc_track,
                                 gc_block=gc_block, kernels=resolve(kernels),
                                 placement=as_placement_arrays(placement))
    outs = jax.tree_util.tree_map(np.asarray, outs)
    history = [(np.asarray(w.tid), WaveOut(*(f[i] for f in outs)))
               for i, w in enumerate(waves)]
    return store, history, _stats_of(history)


# ---------------------------------------------------------------------------
# fused block dispatch for the streaming service plane (DESIGN.md §8)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("sched", "skew", "gc_track", "gc_block",
                                    "kernels"))
def _scan_block(store: MVStore, stacked: Wave, wave_idx0: jax.Array,
                clock: jax.Array, n_nodes: jax.Array, host_skew, watermark,
                sched: str = "postsi", skew: int = 0, gc_track: bool = False,
                gc_block: bool = False,
                kernels: KernelConfig = KernelConfig("jnp"), placement=None):
    """One device program for a block of B pre-formed waves: lax.scan over
    the leading wave axis carrying (store, clock), exactly ``_scan_waves``
    but resumable — the caller owns the wave-index origin and the GC
    watermark, so consecutive blocks stitch into one continuous closed-loop
    history.  ``watermark`` (or None for the engine's own wave-boundary
    collapse) applies to every wave of the block: it is computed by the
    service at dispatch time from the *retired* prefix of the stream, which
    can only under-estimate the true floor — safe, never unsafe."""
    B = stacked.op_kind.shape[0]
    sub = LocalSubstrate(kernels)

    def body(carry, xs):
        st, clk = carry
        wave, w_idx = xs
        st, out, clk = run_wave_on(sub, st, wave, w_idx, clk, n_nodes,
                                   sched=sched, skew=skew,
                                   host_skew=host_skew, watermark=watermark,
                                   gc_track=gc_track, gc_block=gc_block,
                                   placement=placement)
        return (st, clk), out

    (store, clock), outs = lax.scan(
        body, (store, clock),
        (stacked, wave_idx0 + jnp.arange(B, dtype=jnp.int32)))
    return store, outs, clock


def run_block(store: MVStore, stacked: Wave, wave_idx0: int, clock,
              *, sched: str = "postsi", n_nodes: int = 8, skew: int = 0,
              host_skew: np.ndarray | None = None, watermark=None,
              gc_track: bool = True, gc_block: bool = False,
              kernels: KernelConfig | str | None = None, placement=None):
    """Dispatch a block of B formed waves (``stacked`` has leading [B] axis,
    from ``stack_waves``) as ONE device program and return device-resident
    results: ``(store', outs, clock')`` where ``outs`` is a ``WaveOut``
    whose every leaf carries the leading [B] wave axis.

    Nothing here blocks on the device: under JAX async dispatch the returned
    arrays are futures, so a pipelined caller (``service.stream``) can keep
    forming the next block on the host — and even dispatch it, chaining on
    the returned store/clock — while this one executes.  Materializing the
    outcomes (``np.asarray``) is the caller's explicit synchronization
    point; ``step_block`` below does exactly that for step-style callers."""
    hs = None if host_skew is None else jnp.asarray(host_skew, jnp.int32)
    wm = None if watermark is None else jnp.int32(watermark)
    return _scan_block(store, stacked, jnp.int32(wave_idx0), clock,
                       jnp.int32(n_nodes), hs, wm, sched=sched, skew=skew,
                       gc_track=gc_track, gc_block=gc_block,
                       kernels=resolve(kernels),
                       placement=as_placement_arrays(placement))


def step_block(store: MVStore, stacked: Wave, wave_idx0: int, clock, **kw):
    """Synchronous block step: ``run_block`` + host sync of the per-wave
    outcomes (mirror of ``step_wave`` for a [B]-stacked wave block).
    Returns ``(store', outs_np, clock')``."""
    store, outs, clock = run_block(store, stacked, wave_idx0, clock, **kw)
    return store, jax.tree_util.tree_map(np.asarray, outs), clock


# stale-trace hygiene: a process-default backend switch drops traces baked
# with the old default (correctness needs no clearing — the resolved config
# is part of the static key, so the new default is a fresh entry)
register_cache_clear(_run_wave_jit)
register_cache_clear(_scan_waves)
register_cache_clear(_scan_block)
