"""Vectorized wave-execution engine for all six schedulers.

The paper's asynchronous shared-nothing execution is mapped onto *waves*
(DESIGN.md §2): a wave is a batch of transactions whose lifespans all overlap
— they read the wave-start snapshot in parallel, keep write sets private
(paper §IV-C) and then commit one-by-one in a deterministic order, which is
where the paper's rules fire:

  read phase   — CV rule 4 / PostSI §IV-B CID visibility + PostSI rule 3
                 (raise s_lo/c_lo to the CID of every version read),
  commit phase — CV rules 5-6 (write validation, anti-dependency capture) and
                 PostSI rule 4 (a: pick own interval from SIDs + ongoing
                 readers' s_lo; b: push bounds of conflicting ongoing txns;
                 c: stamp CIDs, bump SIDs) and rule 5 (abort on s_lo > s_hi).

The anti-dependency table is the dense boolean matrix ``potential[i, j]`` =
"txn i read a key that txn j writes"; an edge *exists* (paper's table entry)
once j commits, and is consulted only while i/j are ongoing — committed
readers hand over via SIDs exactly as in the paper.

Schedulers:
  postsi   — the paper's contribution (decentralized, negotiated intervals)
  cv       — Consistent Visibility only (no interval induction)
  si       — conventional SI: central coordinator allocates snapshots
             (2 coordinator round-trips per txn, counted)
  optimal  — conventional procedure minus all coordination (upper bound;
             not guaranteed correct, per the paper)
  dsi      — incremental-snapshot DSI: coordinator involved for distributed
             txns; remote-read snapshot mismatch aborts
  clocksi  — loosely synchronized per-node clocks with ``skew`` (in waves);
             behind-host txns read stale snapshots, ahead-remote reads wait
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .store import (INF, MVStore, NO_TID, bump_sid, install_version,
                    make_store, node_of_key, read_newest, read_visible)

# op kinds
NOP, READ, WRITE, RMW = 0, 1, 2, 3
# txn status
RUNNING, COMMITTED, ABORTED = 0, 1, 2

SCHEDULERS = ("postsi", "cv", "si", "optimal", "dsi", "clocksi")
WAVE_STRIDE = 1 << 16      # logical clock stride per wave for clocked baselines


class Wave(NamedTuple):
    op_kind: jax.Array    # [T, O] int32
    op_key: jax.Array     # [T, O] int32
    op_val: jax.Array     # [T, O] int32
    host: jax.Array       # [T] int32 host node per txn
    tid: jax.Array        # [T] int32 global tids (unique, > 0)


class WaveOut(NamedTuple):
    status: jax.Array     # [T] RUNNING/COMMITTED/ABORTED
    s: jax.Array          # [T] final start time
    c: jax.Array          # [T] final commit time
    read_key: jax.Array   # [T, O] (-1 where not a read)
    read_cid: jax.Array   # [T, O]
    write_key: jax.Array  # [T, O] (-1 where not a write)
    write_cid: jax.Array  # [T, O] cid stamped on installed versions
    # stats
    msgs_cross: jax.Array  # scalar: cross-node data/negotiation messages
    msgs_coord: jax.Array  # scalar: messages through the central coordinator
    waits: jax.Array       # scalar: clock-si skew waits


def _potential_antidep(read_key, write_key, read_mask, write_mask):
    """potential[i, j] = txn i read a key txn j writes (i != j)."""
    rk = jnp.where(read_mask, read_key, -1)
    wk = jnp.where(write_mask, write_key, -2)
    eq = rk[:, None, :, None] == wk[None, :, None, :]     # [T,T,O,O]
    pot = eq.any(axis=(2, 3))
    T = read_key.shape[0]
    return pot & ~jnp.eye(T, dtype=bool)


@functools.partial(jax.jit, static_argnames=("sched", "skew"))
def run_wave(store: MVStore, wave: Wave, wave_idx: jax.Array, clock: jax.Array,
             n_nodes: jax.Array = 8, sched: str = "postsi", skew: int = 0,
             host_skew: jax.Array | None = None) -> Tuple[MVStore, WaveOut, jax.Array]:
    """Execute one wave. Returns (store', out, clock').
    ``n_nodes`` is traced, so scaling sweeps don't recompile."""
    assert sched in SCHEDULERS, sched
    T, O = wave.op_kind.shape
    clock0 = clock          # wave-entry clock = snapshot time for clocked scheds
    is_read = (wave.op_kind == READ) | (wave.op_kind == RMW)
    is_write = (wave.op_kind == WRITE) | (wave.op_kind == RMW)
    keys = wave.op_key

    # ------------------------------------------------------------------ reads
    if sched == "clocksi":
        hs = host_skew if host_skew is not None else jnp.zeros((1,), jnp.int32)
        my_skew = hs[wave.host]                                   # [T]
        cutoff_wave = wave_idx - my_skew                          # snapshot wave
        # visible: newest version whose wave tag < cutoff (stale snapshot)
        key_wave = store.wave[keys]                               # [T,O]
        head_cid = jnp.take_along_axis(store.cid[keys], store.head[keys][..., None],
                                       axis=-1)[..., 0]
        stale = key_wave >= cutoff_wave[:, None]
        max_cid = jnp.where(stale, head_cid - 1, INF)
        r_val, r_tid, r_cid, r_sid, r_slot = read_visible(store, keys, max_cid)
    else:
        r_val, r_tid, r_cid, r_sid, r_slot = read_newest(store, keys)

    read_key = jnp.where(is_read, keys, -1)
    read_cid = jnp.where(is_read, r_cid, -1)

    # PostSI rule 3 at read time: creator of every read version must be
    # visible -> raise s_lo and c_lo to its CID.
    s_lo0 = jnp.where(is_read, r_cid, 0).max(axis=1)              # [T]
    c_lo0 = s_lo0
    s_hi0 = jnp.full((T,), INF, jnp.int32)

    potential = _potential_antidep(keys, keys, is_read, is_write)  # [T,T]

    # --------------------------------------------------------------- commits
    # deterministic commit order = wave-local index (tids ascend within wave)
    def commit_one(i, carry):
        (st, s_lo, s_hi, c_lo, status, s_arr, c_arr, wcid, clk) = carry
        active = status[i] == RUNNING

        k_i = keys[i]                                             # [O]
        w_i = is_write[i]
        r_i = is_read[i]
        nv_val, nv_tid, nv_cid, nv_sid, nv_slot = read_newest(st, k_i)

        # map newest creators to wave-local ids (or -1 if older wave)
        local = nv_tid - wave.tid[0]
        local = jnp.where((local >= 0) & (local < T), local, -1)
        creator_committed = jnp.where(local >= 0, status[jnp.maximum(local, 0)] == COMMITTED, False)

        # lost update: an RMW whose read version is no longer newest
        lost = (r_i & w_i & (nv_cid != r_cid[i])).any()
        # CV rule 5(ii): newest creator has an rw edge from me (I read data it
        # overwrote) -> it is invisible to me -> cannot overwrite its version
        if sched in ("postsi", "cv"):
            rw_to_creator = jnp.where(
                w_i & (local >= 0) & creator_committed,
                potential[i, jnp.maximum(local, 0)], False).any()
        else:
            rw_to_creator = jnp.array(False)

        if sched in ("si", "dsi", "clocksi", "optimal"):
            # first-committer-wins: any write over a same-wave commit aborts
            ww_conc = (w_i & (local >= 0) & creator_committed).any()
        else:  # postsi / cv allow overwriting a committed peer (Fig.1 t2/t3)
            ww_conc = jnp.array(False)

        abort = lost | rw_to_creator | ww_conc

        if sched == "dsi":
            # incremental snapshot: a *remote* read whose key was meanwhile
            # overwritten implies a local/global timestamp mismatch -> abort
            remote = node_of_key(k_i, n_nodes) != wave.host[i]
            stale_remote = (r_i & remote & (nv_cid != r_cid[i])).any()
            abort = abort | stale_remote

        if sched == "postsi":
            # rule 3 for overwrites: creators of overwritten versions must be
            # visible
            s_lo_i = jnp.maximum(s_lo[i], jnp.where(w_i, nv_cid, 0).max())
            c_lo_i = jnp.maximum(c_lo[i], jnp.where(w_i, nv_cid, 0).max())
            # rule 4(a): commit time above SIDs of read versions (re-gathered:
            # peers may have bumped them while we ran)
            cur_sid = st.sid[k_i, r_slot[i]]
            c_lo_i = jnp.maximum(c_lo_i, jnp.where(r_i, cur_sid, 0).max())
            # ... and above SIDs of versions we *overwrite* (blind writes):
            # SID passes committed readers' start times to later writers
            c_lo_i = jnp.maximum(c_lo_i, jnp.where(w_i, nv_sid, 0).max())
            # ... and above s_lo of every ongoing reader of my write set
            ongoing_reader = potential[:, i] & (status == RUNNING)
            ongoing_reader = ongoing_reader.at[i].set(False)
            c_lo_i = jnp.maximum(c_lo_i, jnp.where(ongoing_reader, s_lo, 0).max())
            # rule 5: no valid start time left
            abort = abort | (s_lo_i > s_hi[i])
            s_i = s_lo_i
            c_i = jnp.maximum(c_lo_i, s_i) + 1
        else:
            # clocked baselines: snapshot = wave-entry clock; commit = clock++
            s_i = clock0
            c_i = clk + 1

        commit = active & ~abort
        new_status = jnp.where(active, jnp.where(abort, ABORTED, COMMITTED), status[i])

        # ---- install writes (masked scatter; OOB key drops inactive ops) ----
        wmask = w_i & commit
        k_install = jnp.where(wmask, k_i, st.n_keys)              # OOB -> drop
        h_new = (st.head[jnp.minimum(k_i, st.n_keys - 1)] + 1) % st.n_versions
        val_new = jnp.where(wave.op_kind[i] == RMW, r_val[i] + wave.op_val[i],
                            wave.op_val[i])
        st = st._replace(
            val=st.val.at[k_install, h_new].set(val_new, mode="drop"),
            tid=st.tid.at[k_install, h_new].set(wave.tid[i], mode="drop"),
            cid=st.cid.at[k_install, h_new].set(c_i, mode="drop"),
            sid=st.sid.at[k_install, h_new].set(0, mode="drop"),
            head=st.head.at[k_install].set(h_new, mode="drop"),
            wave=st.wave.at[k_install].set(wave_idx, mode="drop"),
        )
        wcid = wcid.at[i].set(jnp.where(wmask, c_i, -1))

        # ---- rule 4(c): bump SIDs of read versions to my start time --------
        # guarded: skip if the ring slot was recycled since our wave-start read
        rmask = r_i & commit & (st.tid[k_i, r_slot[i]] == r_tid[i])
        k_sid = jnp.where(rmask, k_i, st.n_keys)
        st = st._replace(sid=st.sid.at[k_sid, r_slot[i]].max(s_i, mode="drop"))

        # ---- rule 4(b): push bounds of conflicting *ongoing* transactions --
        if sched == "postsi":
            running = status == RUNNING
            i_reads_them = potential[i, :] & running              # j -rw-> k := me -> them
            c_lo = jnp.where(commit & i_reads_them, jnp.maximum(c_lo, s_i + 1), c_lo)
            they_read_mine = potential[:, i] & running
            s_hi = jnp.where(commit & they_read_mine, jnp.minimum(s_hi, c_i - 1), s_hi)
            s_lo = s_lo.at[i].set(jnp.where(commit, s_i, s_lo[i]))

        status = status.at[i].set(new_status)
        s_arr = s_arr.at[i].set(jnp.where(commit, s_i, -1))
        c_arr = c_arr.at[i].set(jnp.where(commit, c_i, -1))
        clk = jnp.where(commit, jnp.maximum(clk, c_i), clk)
        return (st, s_lo, s_hi, c_lo, status, s_arr, c_arr, wcid, clk)

    status0 = jnp.full((T,), RUNNING, jnp.int32)
    s0 = jnp.full((T,), -1, jnp.int32)
    c0 = jnp.full((T,), -1, jnp.int32)
    wcid0 = jnp.full((T, O), -1, jnp.int32)

    (store, s_lo, s_hi, c_lo, status, s_arr, c_arr, wcid, clock) = lax.fori_loop(
        0, T, commit_one,
        (store, s_lo0, s_hi0, c_lo0, status0, s0, c0, wcid0, clock))

    write_key = jnp.where(is_write & (status[:, None] == COMMITTED), keys, -1)

    # ------------------------------------------------------------------ stats
    # work delegation batches per (txn, remote node) pair (paper §IV-A), so
    # cross-node messages count DISTINCT remote nodes touched, not raw ops
    MAX_NODES = 32
    op_node = node_of_key(keys, n_nodes)                               # [T,O]
    active_op = wave.op_kind != NOP
    node_ids = jnp.arange(MAX_NODES)[None, None, :]
    touch = (op_node[..., None] == node_ids) & active_op[..., None]    # [T,O,MN]
    node_touched = touch.any(axis=1)                                   # [T,MN]
    remote_mask = jnp.arange(MAX_NODES)[None, :] != wave.host[:, None]
    remote_nodes = (node_touched & remote_mask)
    msgs_cross = remote_nodes.sum()
    remote_op = (op_node != wave.host[:, None]) & active_op
    committed = status == COMMITTED
    if sched == "postsi":
        # negotiation: one message per DISTINCT peer host per committer
        edge = potential & committed[None, :]
        peer_host_hot = (wave.host[None, :, None] == node_ids) & edge[:, :, None]
        peer_hosts = peer_host_hot.any(axis=1)                         # [T,MN]
        cross_peer = peer_hosts & (jnp.arange(MAX_NODES)[None, :] != wave.host[:, None])
        msgs_cross = msgs_cross + cross_peer.sum()
        msgs_coord = jnp.int32(0)
    elif sched == "cv":
        # anti-dependency entries stored on both endpoint hosts (§IV-A):
        # insertion crosses hosts like PostSI negotiation ...
        edge = potential & committed[None, :]
        peer_host_hot = (wave.host[None, :, None] == node_ids) & edge[:, :, None]
        peer_hosts = peer_host_hot.any(axis=1)
        cross_peer = peer_hosts & (jnp.arange(MAX_NODES)[None, :] != wave.host[:, None])
        msgs_cross = msgs_cross + cross_peer.sum()
        # ... and reads consult the table on remote hosts (paper §V-D):
        # batched per (txn, remote node) visited for reading
        read_touch = (op_node[..., None] == node_ids) & (is_read & active_op)[..., None]
        read_nodes = (read_touch.any(axis=1) & remote_mask)
        msgs_cross = msgs_cross + read_nodes.sum()
        msgs_coord = jnp.int32(0)
    elif sched == "si":
        msgs_coord = jnp.int32(2 * T)                  # begin + end, per txn
    elif sched == "dsi":
        distributed = remote_op.any(axis=1)
        msgs_coord = 2 * distributed.sum()             # global txns pay globally
    elif sched == "clocksi":
        msgs_coord = jnp.int32(0)
    else:  # optimal
        msgs_coord = jnp.int32(0)

    waits = jnp.int32(0)
    if sched == "clocksi" and host_skew is not None:
        # ahead-snapshot reads on behind remote nodes must wait (paper §II)
        node_skew = host_skew[node_of_key(keys, n_nodes)]
        my_skew = host_skew[wave.host][:, None]
        waits = jnp.maximum(node_skew - my_skew, 0).sum(where=remote_op & is_read)

    out = WaveOut(status, s_arr, c_arr, read_key, read_cid, write_key, wcid,
                  msgs_cross, msgs_coord, waits)
    return store, out, clock


def set_n_nodes(n: int) -> None:   # kept for API compat; n_nodes is traced now
    pass


class RunStats(NamedTuple):
    committed: int
    aborted: int
    msgs_cross: int
    msgs_coord: int
    waits: int
    waves: int


def run_workload(store: MVStore, waves, sched: str = "postsi", skew: int = 0,
                 host_skew: np.ndarray | None = None, n_nodes: int = 8):
    """Python driver: execute a list of Waves; returns (store, history, stats).

    history is a list of numpy-ified WaveOut for the verifier.
    """
    clock = jnp.int32(1)
    hs = None if host_skew is None else jnp.asarray(host_skew, jnp.int32)
    history = []
    tot = dict(committed=0, aborted=0, msgs_cross=0, msgs_coord=0, waits=0)
    for w_idx, wave in enumerate(waves):
        store, out, clock = run_wave(store, wave, jnp.int32(w_idx + 1), clock,
                                     jnp.int32(n_nodes), sched=sched,
                                     skew=skew, host_skew=hs)
        o = jax.tree_util.tree_map(np.asarray, out)
        history.append((np.asarray(wave.tid), o))
        tot["committed"] += int((o.status == COMMITTED).sum())
        tot["aborted"] += int((o.status == ABORTED).sum())
        tot["msgs_cross"] += int(o.msgs_cross)
        tot["msgs_coord"] += int(o.msgs_coord)
        tot["waits"] += int(o.waits)
    stats = RunStats(waves=len(waves), **tot)
    return store, history, stats
