"""Shard_map PostSI engine: the paper's shared-nothing cluster as a JAX mesh.

The version store is block-partitioned over a 1-D ``("node",)`` mesh axis
(node = key // keys_per_node); transaction state (interval bounds, status)
is *replicated* and updated by identical deterministic computation on every
node, while all data accesses are peer collectives:

  read phase     all_gather the wave's key requests; each node answers for
                 its block (others masked); psum merges the responses —
                 the lockstep equivalent of the paper's work delegation.
  commit phase   per-commit re-validation reads use the same gather+psum;
                 version installs and SID bumps apply only on the owning
                 node (masked local scatter); PostSI rule 4(b) bound pushes
                 are replicated arithmetic — **zero coordinator anywhere**.

Semantics are bit-identical to the single-device engine (same commit order,
same rules) — tests/test_distribution.py checks the differential.  The
commit-phase arithmetic (CV rules 5-6, PostSI rules 3/4/5 and the dense
``potential`` build) is the shared ``commit_phase`` module, so this engine
and ``engine.py`` execute the exact same replicated math by construction;
only the paper's scheduler (postsi) is implemented on the mesh.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .commit_phase import (ABORTED, COMMITTED, NOP, READ, RMW, RUNNING, WRITE,
                           creator_slots, lost_update, ongoing_readers_of,
                           postsi_bounds, potential_matrix_jnp, push_bounds,
                           rw_edge_to_creator)
from .engine import Wave
from .store import INF, MVStore, NO_TID, make_store


def make_node_mesh(n_nodes: int) -> Mesh:
    devs = jax.devices()[:n_nodes]
    return Mesh(np.array(devs), ("node",))


def shard_store(store: MVStore, mesh: Mesh) -> MVStore:
    sh = NamedSharding(mesh, P("node"))
    return MVStore(*(jax.device_put(a, sh) for a in store))


def _local_lookup(st_local: MVStore, keys: jax.Array, base: jax.Array,
                  n_local: int):
    """Gathered newest-version lookup answered from the local block.

    keys: [...] GLOBAL key ids; returns fields with zeros for keys owned by
    other nodes (psum merges)."""
    lk = keys - base
    mine = (lk >= 0) & (lk < n_local)
    lk = jnp.clip(lk, 0, n_local - 1)
    cids = st_local.cid[lk]
    tids = st_local.tid[lk]
    ok = tids != NO_TID
    masked = jnp.where(ok, cids, -1)
    slot = jnp.argmax(masked, axis=-1)
    take = lambda a: jnp.take_along_axis(a[lk], slot[..., None], -1)[..., 0]
    zero = lambda x: jnp.where(mine, x, 0)
    return (zero(take(st_local.val)), zero(take(st_local.tid)),
            zero(take(st_local.cid)), zero(take(st_local.sid)),
            zero(slot), mine)


def run_wave_postsi_dist(store: MVStore, wave: Wave, wave_idx, mesh: Mesh,
                         keys_per_node: int):
    """One PostSI wave on the node mesh. Returns (store', status, s, c)."""
    n_nodes = mesh.devices.size
    T, O = wave.op_kind.shape

    def node_fn(val, tid, cid, sid, head, wv, op_kind, op_key, op_val, tids_g):
        st = MVStore(val, tid, cid, sid, head, wv)
        n_local = val.shape[0]
        base = lax.axis_index("node") * n_local

        is_read = (op_kind == READ) | (op_kind == RMW)
        is_write = (op_kind == WRITE) | (op_kind == RMW)

        def read_all(st_l, keys):
            parts = _local_lookup(st_l, keys, base, n_local)
            merged = [lax.psum(p, "node") for p in parts[:5]]
            return merged  # val, tid, cid, sid, slot

        r_val, r_tid, r_cid, r_sid, r_slot = read_all(st, op_key)

        s_lo0 = jnp.where(is_read, r_cid, 0).max(axis=1)
        c_lo0 = s_lo0
        s_hi0 = jnp.full((T,), INF, jnp.int32)

        # replicated dense build (the Pallas kernel is not used inside
        # shard_map — every node computes the same [T, T] matrix)
        potential = potential_matrix_jnp(op_key, op_key, is_read, is_write)

        def commit_one(i, carry):
            st_l, s_lo, s_hi, c_lo, status, s_arr, c_arr = carry
            k_i = op_key[i]
            w_i = is_write[i]
            r_i = is_read[i]
            nv_val, nv_tid, nv_cid, nv_sid, nv_slot = read_all(st_l, k_i)

            local, creator_committed = creator_slots(nv_tid, tids_g[0], T,
                                                     status)
            lost = lost_update(r_i, w_i, nv_cid, r_cid[i])
            rw_to_creator = rw_edge_to_creator(w_i, local, creator_committed,
                                               potential[i])
            abort = lost | rw_to_creator

            cur_sid = read_sid(st_l, k_i, r_slot[i])
            ongoing_reader = ongoing_readers_of(i, potential, status)
            s_i, c_i, iv_abort = postsi_bounds(
                s_lo[i], s_hi[i], c_lo[i], r_i, w_i, nv_cid, nv_sid, cur_sid,
                ongoing_reader, s_lo)
            abort = abort | iv_abort

            active = status[i] == RUNNING
            commit = active & ~abort
            new_status = jnp.where(active, jnp.where(abort, ABORTED, COMMITTED),
                                   status[i])

            # install writes on the owning node only
            lk = k_i - base
            mine = (lk >= 0) & (lk < n_local)
            wmask = w_i & commit & mine
            lk_safe = jnp.where(wmask, jnp.clip(lk, 0, n_local - 1), n_local)
            h_new = (st_l.head[jnp.clip(lk, 0, n_local - 1)] + 1) % st_l.n_versions
            val_new = jnp.where(op_kind[i] == RMW, r_val[i] + op_val[i],
                                op_val[i])
            st_l = st_l._replace(
                val=st_l.val.at[lk_safe, h_new].set(val_new, mode="drop"),
                tid=st_l.tid.at[lk_safe, h_new].set(tids_g[i], mode="drop"),
                cid=st_l.cid.at[lk_safe, h_new].set(c_i, mode="drop"),
                sid=st_l.sid.at[lk_safe, h_new].set(0, mode="drop"),
                head=st_l.head.at[lk_safe].set(h_new, mode="drop"),
                wave=st_l.wave.at[lk_safe].set(wave_idx, mode="drop"),
            )
            # SID bump on owning node (guarded against recycled slots)
            rmask = r_i & commit & mine & (
                st_l.tid[jnp.clip(lk, 0, n_local - 1), r_slot[i]] == r_tid[i])
            lk_sid = jnp.where(rmask, jnp.clip(lk, 0, n_local - 1), n_local)
            st_l = st_l._replace(
                sid=st_l.sid.at[lk_sid, r_slot[i]].max(s_i, mode="drop"))

            # rule 4(b): replicated bound pushes
            s_lo, s_hi, c_lo = push_bounds(i, commit, s_i, c_i, potential,
                                           status, s_lo, s_hi, c_lo)

            status = status.at[i].set(new_status)
            s_arr = s_arr.at[i].set(jnp.where(commit, s_i, -1))
            c_arr = c_arr.at[i].set(jnp.where(commit, c_i, -1))
            return (st_l, s_lo, s_hi, c_lo, status, s_arr, c_arr)

        def read_sid(st_l, keys, slots):
            lk = keys - base
            mine = (lk >= 0) & (lk < n_local)
            lk = jnp.clip(lk, 0, n_local - 1)
            v = jnp.where(mine, st_l.sid[lk, slots], 0)
            return lax.psum(v, "node")

        status0 = jnp.full((T,), RUNNING, jnp.int32)
        init = (st, s_lo0, s_hi0, c_lo0, status0,
                jnp.full((T,), -1, jnp.int32), jnp.full((T,), -1, jnp.int32))
        st, s_lo, s_hi, c_lo, status, s_arr, c_arr = lax.fori_loop(
            0, T, commit_one, init)
        return (st.val, st.tid, st.cid, st.sid, st.head, st.wave,
                status, s_arr, c_arr)

    spec_store = P("node")
    spec_rep = P()
    out = shard_map(
        node_fn, mesh=mesh,
        in_specs=(spec_store,) * 6 + (spec_rep,) * 4,
        out_specs=(spec_store,) * 6 + (spec_rep,) * 3,
        check_rep=False,
    )(store.val, store.tid, store.cid, store.sid, store.head, store.wave,
      wave.op_kind, wave.op_key, wave.op_val, wave.tid)
    new_store = MVStore(*out[:6])
    return new_store, out[6], out[7], out[8]
