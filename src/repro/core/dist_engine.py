"""Shard_map wave engine: the paper's shared-nothing cluster as a JAX mesh.

The version store is block-partitioned over a 1-D ``("node",)`` mesh axis
(node = key // keys_per_node); transaction state (interval bounds, status)
is *replicated* and updated by identical deterministic computation on every
node, while all data accesses are peer collectives:

  read phase     each node answers the wave's key requests from its block
                 (others masked to zero); psum merges the responses — the
                 lockstep equivalent of the paper's work delegation.
  commit phase   per-commit re-validation reads use the same masked-answer
                 + psum; version installs and SID bumps apply only on the
                 owning node (masked local scatter); PostSI rule 4(b) bound
                 pushes are replicated arithmetic — **zero coordinator
                 anywhere**.

This module contains NO concurrency-control rules.  The single commit loop
lives in ``engine.run_wave_on``; here it is merely *wired* to a
``substrate.MeshSubstrate`` inside ``shard_map`` bodies, which lifts all
six schedulers (postsi, cv, si, optimal, dsi, clocksi) onto the mesh at
once.  Drivers mirror the single-device engine one-for-one:

  ``run_wave_dist``           one wave          <->  ``engine.run_wave``
  ``run_workload_dist``       per-wave driver   <->  ``engine.run_workload``
  ``run_workload_fused_dist`` one lax.scan
                              device program    <->  ``run_workload_fused``
  ``step_wave_dist``          closed-loop step  <->  ``engine.step_wave``

plus ``mesh_watermark``, the decentralized GC-watermark merge: per-node
live-reader floors reduced with ``lax.pmin`` on the mesh (DESIGN.md §8).
Semantics are bit-identical to the single-device engine — same commit sets,
same induced intervals, same final stores — for every scheduler on both the
per-wave and fused paths (tests/test_distribution.py).
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import KernelConfig
from .engine import Wave, WaveOut, _stats_of, run_wave_on
from .store import MVStore, PlacementArrays, as_placement_arrays, make_store
from .substrate import MeshSubstrate, mesh_kernels


def make_node_mesh(n_nodes: int) -> Mesh:
    """1-D ``("node",)`` mesh over the first ``n_nodes`` XLA devices.

    Raises ``ValueError`` when the platform exposes fewer devices than
    requested — ``jax.devices()[:n]`` would otherwise silently build an
    under-provisioned mesh (fewer shards than the caller sized for).
    """
    devs = jax.devices()
    if len(devs) < n_nodes:
        raise ValueError(
            f"make_node_mesh({n_nodes}): only {len(devs)} XLA device(s) "
            f"available; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_nodes} (or run on a platform with >= {n_nodes} devices)")
    return Mesh(np.array(devs[:n_nodes]), ("node",))


def shard_store(store: MVStore, mesh: Mesh,
                n_slots: int | None = None) -> MVStore:
    """Block-partition a store over the mesh's ``node`` axis.

    A key space that does not divide the node count is PADDED: trailing
    empty rows (all ``tid == NO_TID`` — never visible, never routed to by
    any valid key or placement) bring the row count up to the next multiple
    of ``n_nodes``, so the substrate's ``base = axis_index * n_local`` block
    arithmetic stays exact.  (This used to be a hard ``ValueError``; padding
    is strictly better — the pad rows are unreachable by construction.)

    ``n_slots`` (elastic placement) requests a specific padded row count —
    ``PlacementMap.n_slots``, i.e. ``capacity * n_nodes`` with headroom for
    range moves; it must be a multiple of ``n_nodes`` and >= the store's
    current rows.
    """
    n_nodes = mesh.devices.size
    n_rows = store.n_keys
    if n_slots is None:
        n_slots = -(-n_rows // n_nodes) * n_nodes        # ceil to a multiple
    if n_slots % n_nodes != 0:
        raise ValueError(f"shard_store: n_slots={n_slots} is not a multiple "
                         f"of the mesh's {n_nodes} node(s)")
    if n_slots < n_rows:
        raise ValueError(f"shard_store: n_slots={n_slots} < store rows "
                         f"{n_rows}; the store does not shrink")
    if n_slots > n_rows:
        pad = make_store(n_slots - n_rows, store.n_versions)
        # pad rows are EMPTY, not bootstrap rows: no key maps to them
        pad = pad._replace(tid=jnp.full_like(pad.tid, -1))
        store = MVStore(*(jnp.concatenate([a, b])
                          for a, b in zip(store, pad)))
    sh = NamedSharding(mesh, P("node"))
    return MVStore(*(jax.device_put(a, sh) for a in store))


# ---------------------------------------------------------------------------
# shard_map wiring: flatten (MVStore, Wave) <-> leaf arrays at the boundary
# ---------------------------------------------------------------------------

_N_STORE = len(MVStore._fields)
_N_WAVE = len(Wave._fields)
_N_OUT = len(WaveOut._fields)


def _norm_placement(placement) -> Tuple[jax.Array, jax.Array]:
    """Placement tables as two replicated leaves for the shard_map boundary
    (None cannot cross it): empty ``(0,)`` arrays are the no-placement
    sentinel — a STATIC shape, so the placement-free trace stays exactly
    the historical program."""
    p = as_placement_arrays(placement)
    if p is None:
        z = jnp.zeros((0,), jnp.int32)
        return z, z
    return p.owner, p.slot


def _denorm_placement(owner: jax.Array, slot: jax.Array):
    return (None if owner.shape[0] == 0
            else PlacementArrays(owner, slot))


def _placement_check(store: MVStore, mesh: Mesh, placement, op_key) -> None:
    """REPRO_PLACEMENT_CHECK=1: validate owner/slot routing against the
    sharded store's block layout before dispatching (host-side, off the hot
    path unless the env knob is set)."""
    if os.environ.get("REPRO_PLACEMENT_CHECK", "0") in ("", "0"):
        return
    from repro.placement.map import validate_routing
    validate_routing(int(store.head.shape[0]), mesh.devices.size,
                     as_placement_arrays(placement), op_key)


@functools.lru_cache(maxsize=None)
def _wave_fn(mesh: Mesh, sched: str, skew: int, gc_track: bool,
             gc_block: bool, kernels: KernelConfig = KernelConfig("jnp"),
             jit: bool = True):
    """Single-wave mesh executor: shard_map around ``engine.run_wave_on``
    over a ``MeshSubstrate`` carrying the resolved kernel config.
    Takes/returns flat leaves (store sharded P("node"), everything else
    replicated).  ``kernels`` must already be resolved AND mesh-degraded (it
    is part of the lru_cache key; the public drivers normalize via
    ``substrate.mesh_kernels`` so equivalent configs — e.g. ``pallas`` and
    its mesh degrade ``jnp`` — share one compile, and a process-default
    switch lands on a fresh cache entry)."""
    sub = MeshSubstrate("node", kernels)

    def node_fn(*args):
        st = MVStore(*args[:_N_STORE])
        wave = Wave(*args[_N_STORE:_N_STORE + _N_WAVE])
        wave_idx, clock, n_nodes, hs, wm, p_own, p_slot = \
            args[_N_STORE + _N_WAVE:]
        st, out, clk = run_wave_on(sub, st, wave, wave_idx, clock, n_nodes,
                                   sched=sched, skew=skew, host_skew=hs,
                                   watermark=wm, gc_track=gc_track,
                                   gc_block=gc_block,
                                   placement=_denorm_placement(p_own, p_slot))
        return (*st, *out, clk)

    mapped = shard_map(
        node_fn, mesh=mesh,
        in_specs=(P("node"),) * _N_STORE + (P(),) * (_N_WAVE + 7),
        out_specs=(P("node"),) * _N_STORE + (P(),) * (_N_OUT + 1),
        check_rep=False,
    )
    return jax.jit(mapped) if jit else mapped


@functools.lru_cache(maxsize=None)
def _scan_fn(mesh: Mesh, sched: str, skew: int, gc_track: bool,
             gc_block: bool, kernels: KernelConfig = KernelConfig("jnp")):
    """Fused multi-wave mesh executor: ONE device program for a whole
    workload — lax.scan over the wave axis *inside* the shard_map body, so
    the host is not touched between waves (mesh mirror of
    ``engine._scan_waves``).  ``kernels`` must already be resolved."""
    sub = MeshSubstrate("node", kernels)

    def node_fn(*args):
        st = MVStore(*args[:_N_STORE])
        stacked = Wave(*args[_N_STORE:_N_STORE + _N_WAVE])   # [W, ...] leaves
        clock, n_nodes, hs, p_own, p_slot = args[_N_STORE + _N_WAVE:]
        W = stacked.op_kind.shape[0]
        pl = _denorm_placement(p_own, p_slot)

        def body(carry, xs):
            st, clk = carry
            wave, w_idx = xs
            st, out, clk = run_wave_on(sub, st, wave, w_idx, clk, n_nodes,
                                       sched=sched, skew=skew, host_skew=hs,
                                       gc_track=gc_track, gc_block=gc_block,
                                       placement=pl)
            return (st, clk), out

        (st, clock), outs = lax.scan(
            body, (st, clock),
            (stacked, jnp.arange(1, W + 1, dtype=jnp.int32)))
        return (*st, *outs, clock)

    mapped = shard_map(
        node_fn, mesh=mesh,
        in_specs=(P("node"),) * _N_STORE + (P(),) * (_N_WAVE + 5),
        out_specs=(P("node"),) * _N_STORE + (P(),) * (_N_OUT + 1),
        check_rep=False,
    )
    return jax.jit(mapped)


@functools.lru_cache(maxsize=None)
def _block_fn(mesh: Mesh, sched: str, skew: int, gc_track: bool,
              gc_block: bool, kernels: KernelConfig = KernelConfig("jnp")):
    """Fused block executor on the mesh: lax.scan over a [B]-stacked wave
    block *inside* the shard_map body, resumable (caller-owned wave-index
    origin + GC watermark) — the mesh twin of ``engine._scan_block`` and
    the device program behind the streaming service's sharded data plane.
    ``kernels`` must already be resolved and mesh-degraded."""
    sub = MeshSubstrate("node", kernels)

    def node_fn(*args):
        st = MVStore(*args[:_N_STORE])
        stacked = Wave(*args[_N_STORE:_N_STORE + _N_WAVE])   # [B, ...] leaves
        wave_idx0, clock, n_nodes, hs, wm, p_own, p_slot = \
            args[_N_STORE + _N_WAVE:]
        B = stacked.op_kind.shape[0]
        pl = _denorm_placement(p_own, p_slot)

        def body(carry, xs):
            st, clk = carry
            wave, w_idx = xs
            # wm < 0 is the "no external pin" sentinel (None cannot cross the
            # shard_map leaf boundary): collapse to the wave-entry clock, the
            # same per-wave default the local scan gets from watermark=None
            wm_i = jnp.where(wm < 0, clk, wm)
            st, out, clk = run_wave_on(sub, st, wave, w_idx, clk, n_nodes,
                                       sched=sched, skew=skew, host_skew=hs,
                                       watermark=wm_i, gc_track=gc_track,
                                       gc_block=gc_block, placement=pl)
            return (st, clk), out

        (st, clock), outs = lax.scan(
            body, (st, clock),
            (stacked, wave_idx0 + jnp.arange(B, dtype=jnp.int32)))
        return (*st, *outs, clock)

    mapped = shard_map(
        node_fn, mesh=mesh,
        in_specs=(P("node"),) * _N_STORE + (P(),) * (_N_WAVE + 7),
        out_specs=(P("node"),) * _N_STORE + (P(),) * (_N_OUT + 1),
        check_rep=False,
    )
    return jax.jit(mapped)


def _norm_hs(host_skew) -> jax.Array:
    """None -> zeros: the engine's clocksi path clamp-gathers, so a length-1
    zero vector means 'no skew anywhere' (same as the local default)."""
    if host_skew is None:
        return jnp.zeros((1,), jnp.int32)
    return jnp.asarray(host_skew, jnp.int32)


def dist_wave_traceable(mesh: Mesh, sched: str = "postsi", skew: int = 0,
                        gc_track: bool = False, gc_block: bool = False,
                        kernels=None):
    """Unjitted traceable single-wave mesh executor over the NamedTuples —
    for callers that lower/compile themselves (repro.launch.dryrun_postsi).
    Returns ``f(store, wave, wave_idx, clock, n_nodes, host_skew=None,
    watermark=None) -> (store', WaveOut, clock')``."""
    fn = _wave_fn(mesh, sched, skew, gc_track, gc_block,
                  mesh_kernels(kernels), jit=False)

    def call(store, wave, wave_idx, clock, n_nodes, host_skew=None,
             watermark=None, placement=None):
        wm = clock if watermark is None else watermark
        out = fn(*store, *wave, jnp.int32(wave_idx), jnp.int32(clock),
                 jnp.int32(n_nodes), _norm_hs(host_skew), jnp.int32(wm),
                 *_norm_placement(placement))
        return (MVStore(*out[:_N_STORE]),
                WaveOut(*out[_N_STORE:_N_STORE + _N_OUT]), out[-1])

    return call


def run_wave_dist(store: MVStore, wave: Wave, wave_idx, clock, mesh: Mesh,
                  n_nodes=None, sched: str = "postsi", skew: int = 0,
                  host_skew=None, watermark=None, gc_track: bool = False,
                  gc_block: bool = False, kernels=None,
                  placement=None) -> Tuple[MVStore, WaveOut, jax.Array]:
    """One wave on the node mesh, any scheduler; mesh twin of
    ``engine.run_wave`` (same contract: (store', WaveOut, clock')).

    ``n_nodes`` is the *logical* cluster model the rules and message
    accounting use (dsi locality, clocksi skew, msgs_cross); it defaults to
    the physical node count of ``mesh`` so a resized mesh cannot silently
    run under a stale cluster model — pass it explicitly to decouple the
    two (e.g. an 8-node logical workload served from 4 physical shards).

    ``kernels`` routes every data-plane hot spot (version scan, potential
    build) per ``repro.kernels.resolve`` — same knob as ``engine.run_wave``."""
    n_nodes = mesh.devices.size if n_nodes is None else n_nodes
    wm = clock if watermark is None else watermark
    _placement_check(store, mesh, placement, np.asarray(wave.op_key))
    out = _wave_fn(mesh, sched, skew, gc_track, gc_block,
                   mesh_kernels(kernels))(
        *store, *wave, jnp.int32(wave_idx), jnp.int32(clock),
        jnp.int32(n_nodes), _norm_hs(host_skew), jnp.int32(wm),
        *_norm_placement(placement))
    return (MVStore(*out[:_N_STORE]),
            WaveOut(*out[_N_STORE:_N_STORE + _N_OUT]), out[-1])


def step_wave_dist(store: MVStore, wave: Wave, wave_idx: int, clock,
                   mesh: Mesh, *, sched: str = "postsi",
                   n_nodes: int | None = None, skew: int = 0, host_skew=None,
                   watermark=None, gc_track: bool = True,
                   gc_block: bool = False, kernels=None, placement=None):
    """Closed-loop step API on the mesh (DESIGN.md §8): one wave in, numpy
    per-txn outcomes out, store/clock kept device-resident (sharded)
    between steps — drop-in for ``engine.step_wave`` so ``TxnService``
    serves an open stream from the whole mesh."""
    store, out, clock = run_wave_dist(
        store, wave, wave_idx, clock, mesh, n_nodes=n_nodes, sched=sched,
        skew=skew, host_skew=host_skew, watermark=watermark,
        gc_track=gc_track, gc_block=gc_block, kernels=kernels,
        placement=placement)
    return store, jax.tree_util.tree_map(np.asarray, out), clock


def run_block_dist(store: MVStore, stacked: Wave, wave_idx0: int, clock,
                   mesh: Mesh, *, sched: str = "postsi",
                   n_nodes: int | None = None, skew: int = 0, host_skew=None,
                   watermark=None, gc_track: bool = True,
                   gc_block: bool = False, kernels=None, placement=None):
    """Dispatch a [B]-stacked wave block as one shard_map device program;
    mesh twin of ``engine.run_block`` (same contract: device-resident
    ``(store', outs[B], clock')``, nothing blocks on the device — the
    streaming driver materializes outcomes when it retires the block)."""
    n_nodes = mesh.devices.size if n_nodes is None else n_nodes
    wm = -1 if watermark is None else watermark
    _placement_check(store, mesh, placement, np.asarray(stacked.op_key))
    out = _block_fn(mesh, sched, skew, gc_track, gc_block,
                    mesh_kernels(kernels))(
        *store, *stacked, jnp.int32(wave_idx0), jnp.int32(clock),
        jnp.int32(n_nodes), _norm_hs(host_skew), jnp.int32(wm),
        *_norm_placement(placement))
    return (MVStore(*out[:_N_STORE]),
            WaveOut(*out[_N_STORE:_N_STORE + _N_OUT]), out[-1])


def step_block_dist(store: MVStore, stacked: Wave, wave_idx0: int, clock,
                    mesh: Mesh, **kw):
    """Synchronous mesh block step: ``run_block_dist`` + host sync of the
    per-wave outcomes (mesh mirror of ``engine.step_block``)."""
    store, outs, clock = run_block_dist(store, stacked, wave_idx0, clock,
                                        mesh, **kw)
    return store, jax.tree_util.tree_map(np.asarray, outs), clock


def run_workload_dist(store: MVStore, waves, mesh: Mesh,
                      sched: str = "postsi", skew: int = 0, host_skew=None,
                      n_nodes: int | None = None, gc_track: bool = False,
                      gc_block: bool = False, kernels=None, placement=None):
    """Per-wave mesh driver (debug/differential twin of
    ``engine.run_workload``): one dispatch + host sync per wave.
    Returns (store, history, stats)."""
    clock = jnp.int32(1)
    history = []
    for w_idx, wave in enumerate(waves):
        store, out, clock = run_wave_dist(
            store, wave, w_idx + 1, clock, mesh, n_nodes=n_nodes, sched=sched,
            skew=skew, host_skew=host_skew, gc_track=gc_track,
            gc_block=gc_block, kernels=kernels, placement=placement)
        history.append((np.asarray(wave.tid),
                        jax.tree_util.tree_map(np.asarray, out)))
    return store, history, _stats_of(history)


def run_workload_fused_dist(store: MVStore, waves, mesh: Mesh,
                            sched: str = "postsi", skew: int = 0,
                            host_skew=None, n_nodes: int | None = None,
                            gc_track: bool = False, gc_block: bool = False,
                            kernels=None, placement=None):
    """Fused mesh driver: the whole workload as a single jitted shard_map
    dispatch (scan-over-waves inside).  Same (store, history, stats)
    contract and bit-identical history to every other driver."""
    from .engine import stack_waves
    n_nodes = mesh.devices.size if n_nodes is None else n_nodes
    stacked = stack_waves(waves)
    _placement_check(store, mesh, placement, np.asarray(stacked.op_key))
    out = _scan_fn(mesh, sched, skew, gc_track, gc_block,
                   mesh_kernels(kernels))(
        *store, *stacked, jnp.int32(1), jnp.int32(n_nodes),
        _norm_hs(host_skew), *_norm_placement(placement))
    store = MVStore(*out[:_N_STORE])
    outs = jax.tree_util.tree_map(
        np.asarray, WaveOut(*out[_N_STORE:_N_STORE + _N_OUT]))
    history = [(np.asarray(w.tid), WaveOut(*(f[i] for f in outs)))
               for i, w in enumerate(waves)]
    return store, history, _stats_of(history)


# ---------------------------------------------------------------------------
# decentralized GC watermark merge (DESIGN.md §8)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pmin_fn(mesh: Mesh):
    return jax.jit(shard_map(
        lambda f: lax.pmin(jnp.min(f), "node"), mesh=mesh,
        in_specs=P("node"), out_specs=P(), check_rep=False))


def mesh_watermark(mesh: Mesh, node_floors) -> int:
    """Merge per-node live-reader snapshot floors into the global GC
    watermark with ``lax.pmin`` on the mesh — the decentralized min the
    paper's visibility argument calls for: each node contributes the lowest
    ``s_lo`` any of its live readers may still take, and no coordinator ever
    owns the result (``service.VisibilityGC.node_floors`` produces the
    per-node inputs)."""
    floors = jnp.asarray(node_floors, jnp.int32).reshape(mesh.devices.size)
    return int(_pmin_fn(mesh)(floors))
