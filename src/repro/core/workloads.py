"""Workload generators: SmallBank and TPC-C-lite (the paper's §V benchmarks)
plus a parametric microbenchmark for the §V-D characteristic studies.

Keys are interleaved across nodes (``node = key % n_nodes``, matching
``store.node_of_key``): local key ``i`` of node ``h`` is ``i * n_nodes + h``.
Transactions are generated in waves; each txn runs on a host node, local
txns touch only host-partition keys, distributed txns touch 2-3 nodes
(paper §V-A).
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from .engine import NOP, READ, RMW, WRITE, Wave


def _key(local_idx, node, n_nodes):
    return local_idx * n_nodes + node


def _mk_wave(op_kind, op_key, op_val, host, tid0):
    T = op_kind.shape[0]
    return Wave(
        op_kind=jnp.asarray(op_kind, jnp.int32),
        op_key=jnp.asarray(op_key, jnp.int32),
        op_val=jnp.asarray(op_val, jnp.int32),
        host=jnp.asarray(host, jnp.int32),
        tid=jnp.asarray(tid0 + np.arange(T), jnp.int32),
    )


def _pick_nodes(rng, host, n_nodes, distributed):
    """Host plus 1-2 extra nodes for distributed txns."""
    if not distributed or n_nodes == 1:
        return [host]
    extra = rng.choice([n for n in range(n_nodes) if n != host],
                       size=min(rng.randint(1, 3), n_nodes - 1), replace=False)
    return [host] + list(extra)


SMALLBANK_O = 4


def smallbank_txn(rng: np.random.RandomState, host: int, n_nodes: int,
                  keys_per_node: int, dist_frac: float = 0.2,
                  hot_frac: float = 0.0, hot_per_node: int = 20):
    """One SmallBank transaction on ``host``: balance (2 reads), deposit
    (1 rmw), transfer (2 rmw), write-check (1 read + 1 rmw).

    Returns ``(op_kind, op_key, op_val)`` as ``[SMALLBANK_O]`` int32 arrays —
    the per-txn building block shared by the batch generator below and the
    open-stream request generator in ``repro.service``."""
    O = SMALLBANK_O
    op_kind = np.zeros(O, np.int32)
    op_key = np.zeros(O, np.int32)
    op_val = np.zeros(O, np.int32)
    nodes = _pick_nodes(rng, host, n_nodes, rng.rand() < dist_frac)
    hot = rng.rand() < hot_frac

    def draw(node):
        pool = hot_per_node if hot else keys_per_node
        return _key(rng.randint(0, pool), node, n_nodes)

    kind = rng.randint(0, 4)
    if kind == 0:      # balance: read two accounts
        op_kind[:2] = READ
        op_key[0] = draw(nodes[0])
        op_key[1] = draw(nodes[-1])
    elif kind == 1:    # deposit
        op_kind[0] = RMW
        op_key[0] = draw(nodes[0])
        op_val[0] = rng.randint(1, 100)
    elif kind == 2:    # transfer: two rmws (possibly cross-node)
        op_kind[:2] = RMW
        op_key[0] = draw(nodes[0])
        op_key[1] = draw(nodes[-1])
        amt = rng.randint(1, 100)
        op_val[0] = -amt
        op_val[1] = amt
    else:              # write-check: read one, rmw another
        op_kind[0] = READ
        op_kind[1] = RMW
        op_key[0] = draw(nodes[0])
        op_key[1] = draw(nodes[-1])
        op_val[1] = -rng.randint(1, 50)
    # de-dup keys inside a txn (engine assumes distinct write keys); a
    # NOP-ed slot drops its payload too, so padding is canonical
    seen = {}
    for o in range(O):
        if op_kind[o] != NOP:
            k = op_key[o]
            if k in seen:
                op_kind[o] = NOP
                op_key[o] = 0
                op_val[o] = 0
                continue
            seen[k] = True
    return op_kind, op_key, op_val


def smallbank_waves(rng: np.random.RandomState, n_waves: int, T: int,
                    n_nodes: int, keys_per_node: int, dist_frac: float = 0.2,
                    hot_frac: float = 0.0, hot_per_node: int = 20,
                    tid0: int = 1) -> List[Wave]:
    """SmallBank in closed batches: ``n_waves`` waves of ``T`` txns drawn
    from ``smallbank_txn``.  ``hot_frac`` of txns draw keys from the
    per-node hotspot (paper §V-D contention study)."""
    O = SMALLBANK_O
    waves = []
    for w in range(n_waves):
        op_kind = np.zeros((T, O), np.int32)
        op_key = np.zeros((T, O), np.int32)
        op_val = np.zeros((T, O), np.int32)
        host = rng.randint(0, n_nodes, T)
        for t in range(T):
            op_kind[t], op_key[t], op_val[t] = smallbank_txn(
                rng, host[t], n_nodes, keys_per_node, dist_frac, hot_frac,
                hot_per_node)
        waves.append(_mk_wave(op_kind, op_key, op_val, host, tid0 + w * T))
    return waves


def tpcc_waves(rng: np.random.RandomState, n_waves: int, T: int, n_nodes: int,
               keys_per_node: int, dist_frac: float = 0.2,
               districts_per_node: int = 50, tid0: int = 1) -> List[Wave]:
    """TPC-C-lite: new-order (1 district rmw + 5 stock rmws + 3 item reads)
    and payment (1 warehouse rmw + 1 customer rmw).  Districts/warehouse rows
    live in the low key range -> natural contention."""
    O = 12
    waves = []
    for w in range(n_waves):
        op_kind = np.zeros((T, O), np.int32)
        op_key = np.zeros((T, O), np.int32)
        op_val = np.zeros((T, O), np.int32)
        host = rng.randint(0, n_nodes, T)
        for t in range(T):
            nodes = _pick_nodes(rng, host[t], n_nodes, rng.rand() < dist_frac)
            if rng.rand() < 0.6:   # new-order
                op_kind[t, 0] = RMW      # district next-o-id
                op_key[t, 0] = _key(rng.randint(0, districts_per_node), host[t], n_nodes)
                op_val[t, 0] = 1
                for j in range(5):       # stock updates, maybe remote
                    node = nodes[rng.randint(0, len(nodes))]
                    op_kind[t, 1 + j] = RMW
                    op_key[t, 1 + j] = _key(
                        districts_per_node + rng.randint(0, keys_per_node - districts_per_node),
                        node, n_nodes)
                    op_val[t, 1 + j] = -rng.randint(1, 10)
                for j in range(3):       # item reads
                    node = nodes[rng.randint(0, len(nodes))]
                    op_kind[t, 6 + j] = READ
                    op_key[t, 6 + j] = _key(
                        districts_per_node + rng.randint(0, keys_per_node - districts_per_node),
                        node, n_nodes)
            else:                  # payment
                op_kind[t, 0] = RMW      # warehouse ytd (hot)
                op_key[t, 0] = _key(rng.randint(0, 10), host[t], n_nodes)
                op_val[t, 0] = rng.randint(1, 100)
                node = nodes[-1]
                op_kind[t, 1] = RMW      # customer balance
                op_key[t, 1] = _key(
                    districts_per_node + rng.randint(0, keys_per_node - districts_per_node),
                    node, n_nodes)
                op_val[t, 1] = -rng.randint(1, 100)
            seen = {}
            for o in range(O):
                if op_kind[t, o] != NOP:
                    k = op_key[t, o]
                    if k in seen:
                        op_kind[t, o] = NOP
                        op_key[t, o] = 0
                        op_val[t, o] = 0
                        continue
                    seen[k] = True
        waves.append(_mk_wave(op_kind, op_key, op_val, host, tid0 + w * T))
    return waves


def micro_waves(rng: np.random.RandomState, n_waves: int, T: int, n_nodes: int,
                keys_per_node: int, n_ops: int = 4, read_ratio: float = 0.8,
                dist_frac: float = 0.3, hot_frac: float = 0.0,
                hot_per_node: int = 20, blind_frac: float = 0.0,
                tid0: int = 1) -> List[Wave]:
    """Parametric microbenchmark for §V-D: vary txn length (n_ops), read mix,
    distribution fraction and contention.  ``blind_frac`` of non-read ops are
    blind WRITEs — the paper's Figure-1 case where PostSI commits and
    first-committer-wins SI aborts."""
    O = n_ops
    waves = []
    for w in range(n_waves):
        op_kind = np.zeros((T, O), np.int32)
        op_key = np.zeros((T, O), np.int32)
        op_val = np.zeros((T, O), np.int32)
        host = rng.randint(0, n_nodes, T)
        for t in range(T):
            nodes = _pick_nodes(rng, host[t], n_nodes, rng.rand() < dist_frac)
            hot = rng.rand() < hot_frac
            pool = hot_per_node if hot else keys_per_node
            ks = set()
            for o in range(O):
                node = nodes[rng.randint(0, len(nodes))]
                k = _key(rng.randint(0, pool), node, n_nodes)
                if k in ks:
                    continue
                ks.add(k)
                if rng.rand() < read_ratio:
                    op_kind[t, o] = READ
                elif rng.rand() < blind_frac:
                    op_kind[t, o] = WRITE
                    op_val[t, o] = rng.randint(1, 10)
                else:
                    op_kind[t, o] = RMW
                    op_val[t, o] = rng.randint(1, 10)
                op_key[t, o] = k
        waves.append(_mk_wave(op_kind, op_key, op_val, host, tid0 + w * T))
    return waves


# ---------------------------------------------------------------------------
# YCSB-style zipfian transactions (paper §V-D skew/contention regime)
# ---------------------------------------------------------------------------

YCSB_O = 4

_zipf_cdf_cache: dict = {}


def zipf_cdf(n: int, theta: float) -> np.ndarray:
    """CDF of the bounded zipfian over ranks ``0..n-1``:
    ``P(rank=k) ∝ 1/(k+1)^theta`` — rank 0 is the hottest key, YCSB's key
    popularity model.  ``theta=0`` degenerates to uniform.  Cached per
    ``(n, theta)``: the zeta normalization is O(n) and the open-stream
    generator draws one rank per op."""
    key = (n, round(float(theta), 6))
    cdf = _zipf_cdf_cache.get(key)
    if cdf is None:
        w = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), theta)
        cdf = np.cumsum(w / w.sum())
        cdf[-1] = 1.0                      # guard fp drift at the top rank
        _zipf_cdf_cache[key] = cdf
    return cdf


def zipf_rank(rng: np.random.RandomState, cdf: np.ndarray) -> int:
    """Draw one zipfian rank by inverting the cached CDF."""
    return int(np.searchsorted(cdf, rng.rand(), side="right"))


def zipf_hot_keys(n_nodes: int, keys_per_node: int, theta: float,
                  mass: float = 0.5, max_frac: float = 0.25) -> np.ndarray:
    """The hot-key set a zipfian YCSB stream concentrates on: the smallest
    rank prefix covering ``mass`` of the per-node popularity curve, expanded
    across every host's partition via the interleaved key encoding
    (``_key(rank, node, n) = rank * n_nodes + node``) — i.e. the LOW keys
    ``arange(R * n_nodes)``.  Because the physical store is partitioned in
    contiguous blocks, this entire set lands in node 0's block: the hot
    shard the elastic plane replicates and splits.  ``max_frac`` caps the
    set at that fraction of the key space (replicating everything is not a
    replica strategy)."""
    cdf = zipf_cdf(keys_per_node, theta)
    ranks = int(np.searchsorted(cdf, mass, side="left")) + 1
    ranks = max(1, min(ranks, int(keys_per_node * max_frac) or 1))
    return np.arange(ranks * n_nodes, dtype=np.int64)


def ycsb_txn(rng: np.random.RandomState, host: int, n_nodes: int,
             keys_per_node: int, theta: float = 0.9, read_frac: float = 0.8,
             dist_frac: float = 0.1, n_ops: int = YCSB_O):
    """One YCSB-style transaction on ``host``: ``n_ops`` ops, each a READ
    with probability ``read_frac`` else an RMW, over zipfian-skewed keys
    (skew ``theta``; every node's partition shares the popularity curve, so
    rank 0 of each node is hot).  With probability ``dist_frac`` the txn is
    distributed and ops spread over 2-3 nodes, else all ops stay on
    ``host`` — the open-stream analogue of ``micro_waves`` with the §V-D
    skew knob the uniform SmallBank stream cannot reach.

    Returns ``(op_kind, op_key, op_val)`` as ``[n_ops]`` int32 arrays;
    duplicate keys inside the txn are NOP-ed out like every generator here
    (the engine assumes distinct write keys per txn)."""
    O = n_ops
    op_kind = np.zeros(O, np.int32)
    op_key = np.zeros(O, np.int32)
    op_val = np.zeros(O, np.int32)
    nodes = _pick_nodes(rng, host, n_nodes, rng.rand() < dist_frac)
    cdf = zipf_cdf(keys_per_node, theta)
    seen = set()
    for o in range(O):
        node = nodes[rng.randint(0, len(nodes))]
        k = _key(zipf_rank(rng, cdf), node, n_nodes)
        if k in seen:
            continue                       # leave the slot as NOP padding
        seen.add(k)
        op_key[o] = k
        if rng.rand() < read_frac:
            op_kind[o] = READ
        else:
            op_kind[o] = RMW
            op_val[o] = rng.randint(1, 100)
    return op_kind, op_key, op_val


def rmw_hot_txn(rng: np.random.RandomState, host: int, n_nodes: int,
                keys_per_node: int, theta: float = 0.99,
                n_ops: int = YCSB_O, val_max: int = 8):
    """One single-op zipfian RMW transaction on ``host``: op slot 0 carries
    an RMW with a small positive delta on a zipf(``theta``)-ranked key of
    the host's partition; slots 1.. stay NOP padding.  This is the
    write-hot regime of DESIGN.md §12.2 — at θ=0.99 the stream piles onto
    each host's rank-0 key, where unfolded same-key RMWs serialize one
    commit per wave via lost-update retries and the former's commutative
    fold turns the pile-up into a single delta-summed row.

    Returns ``(op_kind, op_key, op_val)`` as ``[n_ops]`` int32 arrays."""
    op_kind = np.zeros(n_ops, np.int32)
    op_key = np.zeros(n_ops, np.int32)
    op_val = np.zeros(n_ops, np.int32)
    cdf = zipf_cdf(keys_per_node, theta)
    op_kind[0] = RMW
    op_key[0] = _key(zipf_rank(rng, cdf), host, n_nodes)
    op_val[0] = rng.randint(1, val_max)
    return op_kind, op_key, op_val


def ycsb_waves(rng: np.random.RandomState, n_waves: int, T: int, n_nodes: int,
               keys_per_node: int, theta: float = 0.9, read_frac: float = 0.8,
               dist_frac: float = 0.1, n_ops: int = YCSB_O,
               tid0: int = 1) -> List[Wave]:
    """YCSB in closed batches (the replay-driver twin of the open-stream
    generator ``repro.service.ycsb_txn_gen``)."""
    waves = []
    for w in range(n_waves):
        op_kind = np.zeros((T, n_ops), np.int32)
        op_key = np.zeros((T, n_ops), np.int32)
        op_val = np.zeros((T, n_ops), np.int32)
        host = rng.randint(0, n_nodes, T)
        for t in range(T):
            op_kind[t], op_key[t], op_val[t] = ycsb_txn(
                rng, host[t], n_nodes, keys_per_node, theta, read_frac,
                dist_frac, n_ops)
        waves.append(_mk_wave(op_kind, op_key, op_val, host, tid0 + w * T))
    return waves


CHAIN_O = 2


def chain_txn(prev_key, link_key: int, kind: str = "raw",
              n_ops: int = CHAIN_O, val: int = 1):
    """One link of a deliberate intra-wave dependency chain (DESIGN.md §10).

    Every other generator here NOP-dedups duplicate keys *within* a txn and
    draws keys independently *across* txns, so same-wave dependency chains
    only arise by collision.  Chains build them on purpose — the structure
    the planner's lanes exist to serialize:

    * ``raw`` — READ the predecessor's ``prev_key`` (head links skip it),
      then RMW this link's own fresh ``link_key``: a write→read chain
      across consecutive txns.  Optimistic waves commit these but every
      reader sees the *wave-start* snapshot, never its predecessor; planned
      lanes place each link after its predecessor's commit.
    * ``waw`` — RMW ``link_key`` (the chain's single shared key; callers
      pass ``prev_key`` through as ``link_key``): successive RMWs of one
      key, which rule 4(a) serializes the hard way — in an optimistic wave
      all but the first link lose their update and abort.

    Pure function of its arguments (the rng lives in ``chain_waves``);
    returns ``(op_kind, op_key, op_val)`` as ``[n_ops]`` int32 arrays."""
    if kind not in ("raw", "waw"):
        raise ValueError(f"unknown chain link kind {kind!r}")
    if n_ops < CHAIN_O:
        raise ValueError(f"chain links need n_ops >= {CHAIN_O}, got {n_ops}")
    op_kind = np.zeros(n_ops, np.int32)
    op_key = np.zeros(n_ops, np.int32)
    op_val = np.zeros(n_ops, np.int32)
    if kind == "raw" and prev_key is not None:
        op_kind[0], op_key[0] = READ, prev_key
    op_kind[1], op_key[1], op_val[1] = RMW, link_key, val
    return op_kind, op_key, op_val


def chain_waves(rng: np.random.RandomState, n_waves: int, T: int,
                n_nodes: int, keys_per_node: int, chain_len: int = 4,
                kind: str = "raw", n_ops: int = CHAIN_O,
                tid0: int = 1) -> List[Wave]:
    """Waves of intra-wave dependency chains: consecutive txns
    ``[t, t+chain_len)`` form one chain on one host node (rows are tid
    order, so chain depth == conflict-chain depth for the planner's layered
    coloring).  ``kind``: ``raw`` / ``waw`` as in ``chain_txn``, or
    ``mixed`` — chains alternate raw and waw links (both edge flavors in
    one wave).  Fresh keys come from a per-host shuffled permutation of the
    host's partition, so chains never collide with each other and every key
    obeys the partition invariant (``key % n_nodes == host``)."""
    if kind not in ("raw", "waw", "mixed"):
        raise ValueError(f"unknown chain kind {kind!r}")
    waves = []
    for w in range(n_waves):
        perms = [rng.permutation(keys_per_node) for _ in range(n_nodes)]
        used = np.zeros(n_nodes, np.int64)

        def fresh(h):
            if used[h] >= keys_per_node:
                raise ValueError(
                    f"host {h} partition exhausted: T={T} chains need more "
                    f"than keys_per_node={keys_per_node} fresh keys")
            k = _key(int(perms[h][used[h]]), h, n_nodes)
            used[h] += 1
            return k

        op_kind = np.zeros((T, n_ops), np.int32)
        op_key = np.zeros((T, n_ops), np.int32)
        op_val = np.zeros((T, n_ops), np.int32)
        host = np.zeros(T, np.int32)
        h = prev = None
        for t in range(T):
            pos = t % chain_len
            if pos == 0:                       # new chain, new host
                h, prev = int(rng.randint(0, n_nodes)), None
            link_kind = kind if kind != "mixed" else \
                ("raw" if pos % 2 == 0 else "waw")
            if link_kind == "waw":
                # continue on the shared chain key (head draws it fresh)
                link = prev if prev is not None else fresh(h)
            else:
                link = fresh(h)
            op_kind[t], op_key[t], op_val[t] = chain_txn(
                prev, link, link_kind, n_ops, val=int(rng.randint(1, 10)))
            host[t] = h
            prev = link
        waves.append(_mk_wave(op_kind, op_key, op_val, host, tid0 + w * T))
    return waves


# ---------------------------------------------------------------------------
# open-stream arrival processes (DESIGN.md §8)
# ---------------------------------------------------------------------------

def poisson_arrivals(rng: np.random.RandomState, rate: float,
                     n_ticks: int) -> np.ndarray:
    """Open-system arrivals: i.i.d. ``Poisson(rate)`` new requests per
    scheduler tick (one tick = one wave slot of the closed-loop service)."""
    return rng.poisson(rate, size=n_ticks).astype(np.int64)


def tenant_poisson_arrivals(rng: np.random.RandomState, rates,
                            n_ticks: int) -> np.ndarray:
    """Multi-tenant open-system arrivals: ``[n_ticks, n_tenants]`` i.i.d.
    ``Poisson(rates[t])`` new requests per tenant per tick.  Feed the 2-D
    array straight to ``TxnService.run_stream``/``run_streaming`` with a
    ``tenant_txn_gen`` — column ``t`` arrives tagged as tenant ``t``
    (DESIGN.md §12.1)."""
    rates = np.asarray(rates, np.float64)
    return rng.poisson(rates, size=(n_ticks, rates.size)).astype(np.int64)


def bursty_arrivals(rng: np.random.RandomState, rate: float, n_ticks: int,
                    burst_factor: float = 6.0, p_enter: float = 0.08,
                    p_exit: float = 0.35) -> np.ndarray:
    """Two-state Markov-modulated Poisson process: a calm state at ``rate``
    and a burst state at ``rate * burst_factor``; geometric sojourns with
    entry/exit probabilities per tick.  Mean offered load exceeds ``rate``
    by the burst duty cycle — bursts model flash crowds, the case where the
    wave former's admission control and the retry pipeline's backoff earn
    their keep."""
    counts = np.zeros(n_ticks, np.int64)
    burst = False
    for t in range(n_ticks):
        burst = (rng.rand() < p_enter) if not burst else (rng.rand() >= p_exit)
        counts[t] = rng.poisson(rate * burst_factor if burst else rate)
    return counts
