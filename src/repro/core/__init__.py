"""PostSI / Consistent Visibility — the paper's contribution, in JAX.

Decentralized MVCC: transactions negotiate logical time intervals from
visibility relationships; no central clock exists anywhere in this package.
"""
from .engine import (NOP, READ, RMW, WRITE, RUNNING, COMMITTED, ABORTED,
                     SCHEDULERS, Wave, WaveOut, RunStats, run_wave,
                     run_workload, set_n_nodes)
from .store import MVStore, make_store, read_newest, read_visible, node_of_key
from .verify import verify_cv, verify_si
from . import workloads

__all__ = [
    "NOP", "READ", "RMW", "WRITE", "RUNNING", "COMMITTED", "ABORTED",
    "SCHEDULERS", "Wave", "WaveOut", "RunStats", "run_wave", "run_workload",
    "set_n_nodes", "MVStore", "make_store", "read_newest", "read_visible",
    "node_of_key", "verify_cv", "verify_si", "workloads",
]
