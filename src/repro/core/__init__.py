"""PostSI / Consistent Visibility — the paper's contribution, in JAX.

Decentralized MVCC: transactions negotiate logical time intervals from
visibility relationships; no central clock exists anywhere in this package.
"""
from repro.kernels import (KernelConfig, default_backend, resolve,
                           set_default_backend)
from .commit_phase import potential_backend, set_potential_backend
from .engine import (NOP, READ, RMW, WRITE, RUNNING, COMMITTED, ABORTED,
                     SCHEDULERS, Wave, WaveOut, RunStats, run_block,
                     run_wave, run_wave_on, run_workload,
                     run_workload_fused, stack_waves, step_block, step_wave)
from .store import (MVStore, PlacementArrays, as_placement_arrays,
                    evicting_visible, make_store, read_newest,
                    read_visible, node_of_key)
from .substrate import LocalSubstrate, MeshSubstrate
from .verify import verify_cv, verify_si
from . import workloads

__all__ = [
    "NOP", "READ", "RMW", "WRITE", "RUNNING", "COMMITTED", "ABORTED",
    "SCHEDULERS", "Wave", "WaveOut", "RunStats", "run_block", "run_wave",
    "run_wave_on", "run_workload", "run_workload_fused", "stack_waves",
    "step_block", "step_wave",
    "KernelConfig", "default_backend", "resolve", "set_default_backend",
    "potential_backend", "set_potential_backend", "MVStore",
    "PlacementArrays", "as_placement_arrays",
    "evicting_visible", "make_store", "read_newest", "read_visible",
    "node_of_key", "LocalSubstrate", "MeshSubstrate", "verify_cv",
    "verify_si", "workloads",
]
