"""Data-access substrate: the engine/placement seam (DESIGN.md §4).

``engine.run_wave_on`` holds the only copy of the concurrency-control rules
(read-phase visibility, CV rules 5-6, PostSI rules 3/4/5).  Everything that
rule arithmetic needs from the *data plane* — the read-phase lookup, the
commit-phase re-validation read, the version install, the SID bump and the
GC watermark consult — goes through the small interface below, so the same
commit loop runs on any placement:

* ``LocalSubstrate`` — the store is one dense array per field; every access
  is direct indexing / masked scatter (``store.py`` ops).  This is the
  single-device engine.
* ``MeshSubstrate`` — the store is block-partitioned over a 1-D mesh axis
  (``node = key // keys_per_node``) and the substrate runs *inside* a
  ``shard_map`` body: reads are answered by the owning node from its local
  block (others contribute zeros) and merged with ``lax.psum`` — the
  lockstep equivalent of the paper's work delegation — while installs and
  SID bumps are masked local scatters applied only on the owner.  No
  coordinator exists anywhere: every collective is a peer merge.

Both substrates are stateless and cheap to construct; the mesh one derives
its block base from ``lax.axis_index`` at trace time, so one traced program
serves every node (SPMD).  ``tests/test_distribution.py`` pins the two
substrates bit-identical for all six schedulers, per-wave and fused.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .commit_phase import build_potential, potential_matrix_jnp
from .store import INF, MVStore
from . import store as store_ops


class LocalSubstrate:
    """Direct-indexing data plane: the whole key space lives in one store."""

    def read_visible(self, store: MVStore, keys, max_cid):
        """Latest version with CID <= max_cid per key (paper §IV-B read rule).
        Returns (val, tid, cid, sid, slot), shaped like ``keys``."""
        return store_ops.read_visible(store, keys, max_cid)

    def read_newest(self, store: MVStore, keys):
        """Newest committed version (PostSI reads start with s_hi = +inf)."""
        return store_ops.read_newest(store, keys)

    def read_sid(self, store: MVStore, keys, slots):
        """Re-gather SIDs of previously read (key, slot) pairs — peers may
        have bumped them since the read phase (rule 4(a) input)."""
        return store.sid[keys, slots]

    def key_staleness(self, store: MVStore, keys):
        """Per-key (last-commit wave tag, head CID) — the clocksi stale-read
        cutoff inputs."""
        key_wave = store.wave[keys]
        head_cid = jnp.take_along_axis(
            store.cid[keys], store.head[keys][..., None], axis=-1)[..., 0]
        return key_wave, head_cid

    def evicting_visible(self, store: MVStore, keys, watermark):
        """Would installing into ``keys`` evict a version still visible above
        the GC watermark?  (store.evicting_visible; DESIGN.md §8)."""
        return store_ops.evicting_visible(store, keys, watermark)

    def install(self, store: MVStore, mask, keys, values, tid, cid, wave_idx):
        """Masked version install: push a new ring version for every key with
        ``mask`` set (rule 4(c) CID stamping).  OOB sentinel drops the rest."""
        k_install = jnp.where(mask, keys, store.n_keys)
        h_new = (store.head[jnp.minimum(keys, store.n_keys - 1)] + 1
                 ) % store.n_versions
        return store._replace(
            val=store.val.at[k_install, h_new].set(values, mode="drop"),
            tid=store.tid.at[k_install, h_new].set(tid, mode="drop"),
            cid=store.cid.at[k_install, h_new].set(cid, mode="drop"),
            sid=store.sid.at[k_install, h_new].set(0, mode="drop"),
            head=store.head.at[k_install].set(h_new, mode="drop"),
            wave=store.wave.at[k_install].set(wave_idx, mode="drop"),
        )

    def bump_sid(self, store: MVStore, mask, keys, slots, expect_tid, s_val):
        """Rule 4(c) SID bump: raise SID of read versions to the reader's
        start time, guarded against ring slots recycled since the read."""
        ok = mask & (store.tid[keys, slots] == expect_tid)
        k_sid = jnp.where(ok, keys, store.n_keys)
        return store._replace(
            sid=store.sid.at[k_sid, slots].max(s_val, mode="drop"))

    def build_potential(self, keys, is_read, is_write):
        """Anti-dependency candidate matrix [T, T] — routed through the
        configured backend (Pallas kernel / interpret / jnp)."""
        return build_potential(keys, is_read, is_write)


_LOCAL = LocalSubstrate()


class MeshSubstrate:
    """Peer-collective data plane for a block-partitioned store.

    Must be used inside a ``shard_map`` body whose store arguments carry the
    per-node block (P(axis) over the key dim); all key arguments are GLOBAL
    ids, replicated on every node.  Reads: masked local answer + psum merge.
    Writes: owner-only masked scatter.

    There is deliberately no second copy of the data-plane logic here:
    every method translates global keys to local block indices and then
    *delegates* to the LocalSubstrate / ``store.py`` body on the local
    block (the per-node ``MVStore`` is itself a complete store with
    ``n_keys == n_local``), masking non-owned answers to zero before the
    psum merge and masking non-owned writes off entirely.  A rule or
    GC-formula fix in ``store.py`` therefore reaches both placements by
    construction.
    """

    def __init__(self, axis: str = "node"):
        self.axis = axis

    # ------------------------------------------------------------ helpers
    def _local(self, store: MVStore, keys):
        """(local_idx clipped, mine mask, n_local) for global ``keys``."""
        n_local = store.val.shape[0]
        base = lax.axis_index(self.axis) * n_local
        lk = keys - base
        mine = (lk >= 0) & (lk < n_local)
        return jnp.clip(lk, 0, n_local - 1), mine, n_local

    def _merge(self, mine, *parts):
        """Owner keeps its answer, others contribute 0; psum merges."""
        return tuple(lax.psum(jnp.where(mine, p, 0), self.axis)
                     for p in parts)

    # -------------------------------------------------------------- reads
    def read_visible(self, store: MVStore, keys, max_cid):
        lk, mine, _ = self._local(store, keys)
        return self._merge(mine, *_LOCAL.read_visible(store, lk, max_cid))

    def read_newest(self, store: MVStore, keys):
        return self.read_visible(store, keys,
                                 jnp.broadcast_to(INF, keys.shape))

    def read_sid(self, store: MVStore, keys, slots):
        lk, mine, _ = self._local(store, keys)
        (sid,) = self._merge(mine, _LOCAL.read_sid(store, lk, slots))
        return sid

    def key_staleness(self, store: MVStore, keys):
        lk, mine, _ = self._local(store, keys)
        return self._merge(mine, *_LOCAL.key_staleness(store, lk))

    def evicting_visible(self, store: MVStore, keys, watermark):
        lk, mine, _ = self._local(store, keys)
        ev = _LOCAL.evicting_visible(store, lk, watermark).astype(jnp.int32)
        (ev,) = self._merge(mine, ev)
        return ev.astype(bool)

    # ------------------------------------------------------------- writes
    def install(self, store: MVStore, mask, keys, values, tid, cid, wave_idx):
        lk, mine, _ = self._local(store, keys)
        return _LOCAL.install(store, mask & mine, lk, values, tid, cid,
                              wave_idx)

    def bump_sid(self, store: MVStore, mask, keys, slots, expect_tid, s_val):
        lk, mine, _ = self._local(store, keys)
        return _LOCAL.bump_sid(store, mask & mine, lk, slots, expect_tid,
                               s_val)

    def build_potential(self, keys, is_read, is_write):
        # replicated dense build: the Pallas kernel is not used inside
        # shard_map — every node computes the same [T, T] matrix
        return potential_matrix_jnp(keys, keys, is_read, is_write)
