"""Data-access substrate: the engine/placement seam (DESIGN.md §4).

``engine.run_wave_on`` holds the only copy of the concurrency-control rules
(read-phase visibility, CV rules 5-6, PostSI rules 3/4/5).  Everything that
rule arithmetic needs from the *data plane* — the read-phase lookup, the
commit-phase re-validation read, the version install, the SID bump and the
GC watermark consult — goes through the small interface below, so the same
commit loop runs on any placement:

* ``LocalSubstrate`` — the store is one dense array per field; every access
  is direct indexing / masked scatter.  This is the single-device engine.
* ``MeshSubstrate`` — the store is block-partitioned over a 1-D mesh axis
  (``node = key // keys_per_node``) and the substrate runs *inside* a
  ``shard_map`` body: reads are answered by the owning node from its local
  block (others contribute zeros) and merged with ``lax.psum`` — the
  lockstep equivalent of the paper's work delegation — while installs and
  SID bumps are masked local scatters applied only on the owner.  No
  coordinator exists anywhere: every collective is a peer merge.

Both substrates carry a resolved :class:`repro.kernels.KernelConfig` and
dispatch every compute hot spot through the kernel plane (``kernels.ops``):
the read-phase latest-visible-slot selection via ``ops.version_scan`` (the
paper's §IV-B CID rule — lane padding handled by the op wrapper), the
anti-dependency candidate build via ``commit_phase.build_potential``, and
the batched install / SID-bump scatters via ``ops.masked_install`` /
``ops.masked_sid_bump``.  ``kernels=None`` resolves the process default
once at construction; substrates stay stateless and cheap to construct —
the engines build one per trace with the config baked in.

The mesh one derives its block base from ``lax.axis_index`` at trace time,
so one traced program serves every node (SPMD).

Slot-space contract (DESIGN.md §11): substrates index *physical store
rows*, not logical keys.  Under the default identity placement the two
coincide; under an elastic ``PlacementMap`` the engine translates each
wave's logical keys through ``placement.slot`` ONCE at wave entry and hands
the substrate physical rows only.  Because any placement is an injective
key->row map, key-equality structure (the anti-dependency ``potential``)
and per-row ring semantics are preserved — which is why outcomes are
bit-identical under every placement, including mid-stream moves.
``tests/test_distribution.py`` pins the two substrates bit-identical for
all six schedulers, per-wave and fused; ``tests/test_kernel_backend.py``
pins every backend route bit-identical on both.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp
from jax import lax

from repro.kernels import KernelConfig, can_compile_pallas, ops, resolve
from .commit_phase import build_potential
from .store import INF, MVStore
from . import store as store_ops

# mesh-degrade accounting: how many times a compiled-Mosaic ``pallas``
# request was served by the ``jnp`` reference on the mesh path, surfaced so
# benchmarks can label affected rows honestly (``benchmarks.bench_dist``)
# instead of silently reporting pallas numbers that never ran as pallas
_degrades = 0
_degrade_warned = False


def mesh_degrade_count() -> int:
    """Times ``mesh_kernels`` degraded a ``pallas`` request to ``jnp``."""
    return _degrades


def effective_mesh_backend(kernels: KernelConfig | str | None = None) -> str:
    """Honest label for what the mesh path runs under this request:
    the resolved backend spec, or ``"jnp (degraded from pallas)"`` when the
    capability probe says compiled Mosaic cannot run in this process."""
    cfg = resolve(kernels)
    if cfg.backend == "pallas" and not can_compile_pallas():
        return "jnp (degraded from pallas)" + ("+fused" if cfg.fused else "")
    return cfg.name


def mesh_kernels(kernels: KernelConfig | str | None = None) -> KernelConfig:
    """The config a ``MeshSubstrate`` will actually run.

    Per-shard local block shapes are static under shard_map, so compiled
    Mosaic kernels are legal on the mesh path whenever the platform can
    lower them at all — ``pallas`` now passes through when the
    once-per-process capability probe (``kernels.can_compile_pallas``)
    succeeds, and degrades to the bit-identical ``jnp`` reference ONLY when
    it fails (e.g. the CPU backend, which has no Mosaic target).
    ``pallas_interpret``/``jnp`` always pass through.  The mesh drivers
    normalize through this BEFORE using the config as a jit/lru cache key,
    so a degraded ``pallas`` request and a ``jnp`` request share one trace
    instead of compiling identical programs twice.

    The degradation is *not* silent: the first occurrence per process emits
    a ``RuntimeWarning`` and every occurrence bumps ``mesh_degrade_count()``
    so callers (benchmarks, services) can report what actually ran."""
    cfg = resolve(kernels)
    if cfg.backend == "pallas" and not can_compile_pallas():
        global _degrades, _degrade_warned
        _degrades += 1
        if not _degrade_warned:
            _degrade_warned = True
            warnings.warn(
                "KernelConfig('pallas') degrades to the bit-identical 'jnp' "
                "reference on the mesh path: the capability probe found no "
                "compiled-Mosaic support in this process (CPU backend); "
                "mesh results are correct but do not measure compiled "
                "kernels — request 'pallas_interpret' or 'jnp' explicitly "
                "to silence this",
                RuntimeWarning, stacklevel=2)
        return KernelConfig("jnp", fused=cfg.fused)
    return cfg


class LocalSubstrate:
    """Direct-indexing data plane: the whole key space lives in one store."""

    def __init__(self, kernels: KernelConfig | str | None = None):
        self.kernels = resolve(kernels)

    def read_visible(self, store: MVStore, keys, max_cid):
        """Latest version with CID <= max_cid per key (paper §IV-B read rule).
        Returns (val, tid, cid, sid, slot), shaped like ``keys``.

        The ring gather stays here (data movement); slot *selection* — the
        per-request scan the paper's read rule pays on every access — is
        dispatched through ``ops.version_scan`` on the configured backend.
        Masked/NOP keys (possibly negative padding) are clamped so they can
        never wrap to the last key.
        """
        k = jnp.clip(keys, 0, store.n_keys - 1)
        cids = store.cid[k]                          # [..., V]
        tids = store.tid[k]
        V = store.n_versions
        mc = jnp.broadcast_to(max_cid, k.shape)
        slot, _ = ops.version_scan(
            cids.reshape(-1, V), tids.reshape(-1, V), mc.reshape(-1),
            use_pallas=self.kernels.use_pallas,
            interpret=self.kernels.interpret)
        slot = slot.reshape(k.shape)
        take = lambda a: jnp.take_along_axis(a[k], slot[..., None],
                                             axis=-1)[..., 0]
        return take(store.val), take(store.tid), take(store.cid), \
            take(store.sid), slot

    def read_newest(self, store: MVStore, keys):
        """Newest committed version (PostSI reads start with s_hi = +inf)."""
        return self.read_visible(store, keys,
                                 jnp.broadcast_to(INF, keys.shape))

    def read_sid(self, store: MVStore, keys, slots):
        """Re-gather SIDs of previously read (key, slot) pairs — peers may
        have bumped them since the read phase (rule 4(a) input)."""
        return ops.sid_regather(store.sid, keys, slots)

    def key_staleness(self, store: MVStore, keys):
        """Per-key (last-commit wave tag, head CID) — the clocksi stale-read
        cutoff inputs.  NOP/padding keys (possibly negative) are clamped
        like every other gather so they can never wrap to the last key."""
        k = jnp.clip(keys, 0, store.n_keys - 1)
        key_wave = store.wave[k]
        head_cid = jnp.take_along_axis(
            store.cid[k], store.head[k][..., None], axis=-1)[..., 0]
        return key_wave, head_cid

    def evicting_visible(self, store: MVStore, keys, watermark):
        """Would installing into ``keys`` evict a version still visible above
        the GC watermark?  (store.evicting_visible; DESIGN.md §8)."""
        return store_ops.evicting_visible(store, keys, watermark)

    def install(self, store: MVStore, mask, keys, values, tid, cid, wave_idx):
        """Masked version install: push a new ring version for every key with
        ``mask`` set (rule 4(c) CID stamping).  OOB sentinel drops the rest
        (``ops.masked_install``)."""
        val, tid_, cid_, sid, head, wave = ops.masked_install(
            store.val, store.tid, store.cid, store.sid, store.head,
            store.wave, mask=mask, keys=keys, values=values, new_tid=tid,
            new_cid=cid, wave_idx=wave_idx)
        return store._replace(val=val, tid=tid_, cid=cid_, sid=sid,
                              head=head, wave=wave)

    def bump_sid(self, store: MVStore, mask, keys, slots, expect_tid, s_val):
        """Rule 4(c) SID bump: raise SID of read versions to the reader's
        start time, guarded against ring slots recycled since the read
        (``ops.masked_sid_bump``)."""
        return store._replace(sid=ops.masked_sid_bump(
            store.sid, store.tid, mask=mask, keys=keys, slots=slots,
            expect_tid=expect_tid, s_val=s_val))

    def build_potential(self, keys, is_read, is_write):
        """Anti-dependency candidate matrix [T, T] — routed through the
        configured backend (Pallas kernel / interpret / jnp)."""
        return build_potential(keys, is_read, is_write, backend=self.kernels)

    def read_phase(self, store: MVStore, keys, max_cid, is_read, is_write):
        """The whole wave read phase (DESIGN.md §7): latest-visible slot
        selection, the PostSI rule-3 negotiation seed ``s_lo0`` and the
        anti-dependency candidate build.  Returns ``(r_val, r_tid, r_cid,
        r_sid, r_slot, s_lo0 [T], potential [T, T] bool)``.

        With ``kernels.fused`` this is ONE ``ops.wave_commit`` launch over
        the gathered rings — no HBM round-trips between the three bodies;
        otherwise the three separate dispatches.  Bit-identical either way
        (tests/test_kernels.py, tests/test_kernel_backend.py).
        """
        mc = jnp.broadcast_to(max_cid, keys.shape)
        if not self.kernels.fused:
            r_val, r_tid, r_cid, r_sid, r_slot = self.read_visible(
                store, keys, mc)
            s_lo0 = jnp.where(is_read, r_cid, 0).max(axis=1)
            pot = self.build_potential(keys, is_read, is_write)
            return r_val, r_tid, r_cid, r_sid, r_slot, s_lo0, pot
        k = jnp.clip(keys, 0, store.n_keys - 1)
        slot, r_val, r_tid, r_cid, r_sid, s_lo0, pot = ops.wave_commit(
            store.cid[k], store.tid[k], store.sid[k], store.val[k], mc,
            jnp.where(is_read, keys, -1), jnp.where(is_write, keys, -1),
            is_read,
            use_pallas=self.kernels.use_pallas,
            interpret=self.kernels.interpret)
        return r_val, r_tid, r_cid, r_sid, slot, s_lo0, pot.astype(bool)


class MeshSubstrate:
    """Peer-collective data plane for a block-partitioned store.

    Must be used inside a ``shard_map`` body whose store arguments carry the
    per-node block (P(axis) over the key dim); all key arguments are GLOBAL
    ids, replicated on every node.  Reads: masked local answer + psum merge.
    Writes: owner-only masked scatter.

    There is deliberately no second copy of the data-plane logic here:
    every method translates global keys to local block indices and then
    *delegates* to a ``LocalSubstrate`` carrying the same
    :class:`KernelConfig` on the local block (the per-node ``MVStore`` is
    itself a complete store with ``n_keys == n_local``), masking non-owned
    answers to zero before the psum merge and masking non-owned writes off
    entirely.  A rule or kernel-route fix in the local plane therefore
    reaches both placements by construction — including the
    ``ops.version_scan`` dispatch, which runs on each node's local block
    before the merge.
    """

    def __init__(self, axis: str = "node",
                 kernels: KernelConfig | str | None = None):
        self.axis = axis
        self.kernels = mesh_kernels(kernels)
        self._local_sub = LocalSubstrate(self.kernels)

    # ------------------------------------------------------------ helpers
    def _local(self, store: MVStore, keys):
        """(local_idx clipped, mine mask, n_local) for global ``keys``."""
        n_local = store.val.shape[0]
        base = lax.axis_index(self.axis) * n_local
        lk = keys - base
        mine = (lk >= 0) & (lk < n_local)
        return jnp.clip(lk, 0, n_local - 1), mine, n_local

    def _merge(self, mine, *parts):
        """Owner keeps its answer, others contribute 0; psum merges."""
        return tuple(lax.psum(jnp.where(mine, p, 0), self.axis)
                     for p in parts)

    # -------------------------------------------------------------- reads
    def read_visible(self, store: MVStore, keys, max_cid):
        lk, mine, _ = self._local(store, keys)
        return self._merge(mine,
                           *self._local_sub.read_visible(store, lk, max_cid))

    def read_newest(self, store: MVStore, keys):
        return self.read_visible(store, keys,
                                 jnp.broadcast_to(INF, keys.shape))

    def read_sid(self, store: MVStore, keys, slots):
        lk, mine, _ = self._local(store, keys)
        (sid,) = self._merge(mine, self._local_sub.read_sid(store, lk, slots))
        return sid

    def key_staleness(self, store: MVStore, keys):
        lk, mine, _ = self._local(store, keys)
        return self._merge(mine, *self._local_sub.key_staleness(store, lk))

    def evicting_visible(self, store: MVStore, keys, watermark):
        lk, mine, _ = self._local(store, keys)
        ev = self._local_sub.evicting_visible(store, lk,
                                              watermark).astype(jnp.int32)
        (ev,) = self._merge(mine, ev)
        return ev.astype(bool)

    # ------------------------------------------------------------- writes
    def install(self, store: MVStore, mask, keys, values, tid, cid, wave_idx):
        lk, mine, _ = self._local(store, keys)
        return self._local_sub.install(store, mask & mine, lk, values, tid,
                                       cid, wave_idx)

    def bump_sid(self, store: MVStore, mask, keys, slots, expect_tid, s_val):
        lk, mine, _ = self._local(store, keys)
        return self._local_sub.bump_sid(store, mask & mine, lk, slots,
                                        expect_tid, s_val)

    def build_potential(self, keys, is_read, is_write):
        # replicated build: every node computes the same [T, T] matrix,
        # routed through the (possibly probe-degraded) config
        return build_potential(keys, is_read, is_write, backend=self.kernels)

    def read_phase(self, store: MVStore, keys, max_cid, is_read, is_write):
        """Mesh twin of ``LocalSubstrate.read_phase``.

        Fused route: each node runs the ``ops.wave_commit`` megakernel over
        its LOCAL gathered rings with ``rvalid = is_read & mine`` as the
        s_lo0 seed mask, then the scan outputs merge with the usual
        owner-keeps/psum pattern and the per-node partial ``s_lo0`` maxima
        merge with ``lax.pmax`` — equal to the unfused merge-then-reduce
        order because every contribution is a non-negative CID.  The
        potential tile is built from GLOBAL replicated keys, so it is
        replicated-identical on every node with no merge at all.
        """
        mc = jnp.broadcast_to(max_cid, keys.shape)
        if not self.kernels.fused:
            r_val, r_tid, r_cid, r_sid, r_slot = self.read_visible(
                store, keys, mc)
            s_lo0 = jnp.where(is_read, r_cid, 0).max(axis=1)
            pot = self.build_potential(keys, is_read, is_write)
            return r_val, r_tid, r_cid, r_sid, r_slot, s_lo0, pot
        lk, mine, _ = self._local(store, keys)
        slot, r_val, r_tid, r_cid, r_sid, s_lo0, pot = ops.wave_commit(
            store.cid[lk], store.tid[lk], store.sid[lk], store.val[lk], mc,
            jnp.where(is_read, keys, -1), jnp.where(is_write, keys, -1),
            is_read & mine,
            use_pallas=self.kernels.use_pallas,
            interpret=self.kernels.interpret)
        r_val, r_tid, r_cid, r_sid, slot = self._merge(
            mine, r_val, r_tid, r_cid, r_sid, slot)
        s_lo0 = lax.pmax(s_lo0, self.axis)
        return r_val, r_tid, r_cid, r_sid, slot, s_lo0, pot.astype(bool)
