"""History verifiers — the paper's correctness conditions, checked post-hoc.

The engine emits full histories (per-txn status, induced interval, read/write
sets with version CIDs).  We verify, in numpy on the host:

* ``verify_si`` — Definition 4 / Theorem 1: committed writers of the same key
  have pairwise-disjoint intervals, and every committed reader observed the
  snapshot at its start time (each read returned the newest committed version
  with CID <= s).
* ``verify_cv`` — Definition 5: atomic visibility (never partial) and no lost
  updates (every committed RMW read the version it overwrote).

These run over histories from *any* scheduler, so they double as differential
tests: postsi/si/dsi histories must pass verify_si; cv must pass verify_cv.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

COMMITTED = 1


def _collect(history, base_store=None):
    """Flatten wave outputs into per-txn records and per-key version lists.

    ``base_store`` seeds the version lists from a store's version rings —
    the committed-version set at a recovery snapshot boundary (DESIGN.md
    §9).  A post-restart history is a *suffix*: its reads may legally
    return versions committed before the snapshot, which the suffix alone
    cannot name.  The ring retains exactly the versions still readable at
    the boundary (anything evicted is below the GC watermark, which no
    later snapshot may take), so seeding makes the snapshot-read check
    sound on suffix histories."""
    txns = []        # (tid, s, c, reads[(k,cid)], writes[(k,cid)])
    versions = defaultdict(list)   # key -> [(cid, tid)]
    for tids, out in history:
        for i in range(len(tids)):
            if out.status[i] != COMMITTED:
                continue
            reads = [(int(k), int(c)) for k, c in zip(out.read_key[i], out.read_cid[i])
                     if k >= 0]
            writes = [(int(k), int(c)) for k, c in zip(out.write_key[i], out.write_cid[i])
                      if k >= 0]
            txns.append((int(tids[i]), int(out.s[i]), int(out.c[i]), reads, writes))
            for k, c in writes:
                versions[k].append((c, int(tids[i])))
    if base_store is not None:
        get = (base_store.get if isinstance(base_store, dict)
               else lambda f: getattr(base_store, f))
        cid = np.asarray(get("cid"))
        tid = np.asarray(get("tid"))
        for k, v in zip(*np.nonzero(cid > 0)):
            versions[int(k)].append((int(cid[k, v]), int(tid[k, v])))
    for k in versions:
        versions[k].sort()
        versions[k].insert(0, (0, 0))      # bootstrap version
    return txns, versions


def verify_si(history, base_store=None) -> List[str]:
    """Return a list of SI violations (empty == the schedule is SI).
    ``base_store`` makes suffix histories (post-recovery) checkable — see
    ``_collect``."""
    txns, versions = _collect(history, base_store)
    errors = []

    # (1) writers of the same key: pairwise-disjoint intervals
    by_key_writers = defaultdict(list)
    for tid, s, c, reads, writes in txns:
        for k, cid in writes:
            by_key_writers[k].append((c, s, tid))
    for k, ws in by_key_writers.items():
        ws.sort()
        for (c1, s1, t1), (c2, s2, t2) in zip(ws, ws[1:]):
            if s2 < c1:   # overlap: both modified k while concurrent
                errors.append(f"ww-overlap key={k}: t{t1}(s={s1},c={c1}) vs "
                              f"t{t2}(s={s2},c={c2})")

    # (2) snapshot reads: read(k) == newest committed version with cid <= s
    for tid, s, c, reads, writes in txns:
        own = dict(writes)
        for k, cid_ret in reads:
            cands = [cv for cv, ct in versions.get(k, [(0, 0)]) if cv <= s]
            expect = max(cands) if cands else 0
            if cid_ret != expect:
                # a txn may read a version it later overwrote; reads happen at
                # wave start, so own writes never appear in the read set
                errors.append(f"non-snapshot read t{tid} key={k}: got cid="
                              f"{cid_ret}, snapshot@s={s} expects {expect}")
    return errors


def verify_cv(history, base_store=None) -> List[str]:
    """Consistent Visibility: atomic visibility + no lost updates.
    ``base_store`` seeds pre-snapshot versions for suffix histories (the
    atomic-visibility pairing still only spans suffix writers — ring
    entries carry no write-sets)."""
    txns, versions = _collect(history, base_store)
    errors = []

    # no lost updates: a committed RMW must have read the version directly
    # below the one it installed
    for tid, s, c, reads, writes in txns:
        rk = dict(reads)
        for k, cid in writes:
            if k in rk:
                vs = [cv for cv, _ in versions[k] if cv < cid]
                below = max(vs) if vs else 0
                if rk[k] != below:
                    errors.append(f"lost-update t{tid} key={k}: read cid={rk[k]}"
                                  f" but overwrote cid={below}")

    # atomic visibility: for every writer i and reader j, j sees either all or
    # none of i's writes (among keys j read)
    writers = [(tid, dict(writes)) for tid, s, c, reads, writes in txns if writes]
    for tid_j, s, c, reads, writes in txns:
        if not reads:
            continue
        rd = dict(reads)
        for tid_i, wr in writers:
            if tid_i == tid_j:
                continue
            shared = [k for k in rd if k in wr]
            if len(shared) < 2:
                continue
            saw = [rd[k] >= wr[k] for k in shared]
            if any(saw) and not all(saw):
                errors.append(f"partial visibility: t{tid_i} -> t{tid_j} over "
                              f"keys {shared}")
    return errors


def final_values_ok(store, history, n_keys: int) -> List[str]:
    """Replay committed effects in commit order; compare with store state."""
    txns, versions = _collect(history)
    expect = np.zeros(n_keys, np.int64)
    # apply writes in cid order per key: newest value should match store head
    newest = {}
    for tid, s, c, reads, writes in txns:
        for k, cid in writes:
            if k not in newest or cid > newest[k][0]:
                newest[k] = (cid, tid)
    errors = []
    val = np.asarray(store.val)
    cid = np.asarray(store.cid)
    head = np.asarray(store.head)
    for k, (cmax, tid) in newest.items():
        got = cid[k, head[k]]
        if got != cmax:
            errors.append(f"store head key={k}: cid {got} != expected {cmax}")
    return errors
