"""Reference (sequential, pure-Python) CV and PostSI schedulers.

This is a line-by-line transcription of the paper's rules over *arbitrary
interleavings* — begin/read/write/commit events in any order — used as the
oracle for the vectorized wave engine and for reproducing the paper's worked
examples (Figure 1, Figure 3 Schedules III/IV/V, Figure 5).

CV scheduler (paper §III-C, rules 1-6):
  versions carry creator TID + visitor lists; an anti-dependency table holds
  rw edges among *ongoing* transactions; writes lock (here: private write
  sets, installed at commit per §IV-C) and validate rule 5.

PostSI scheduler (paper §III-D, complementary rules 1-5):
  per-txn bounds s_lo/s_hi/c_lo; rule 3 raises lower bounds on read/overwrite;
  rule 4(a) picks the interval, 4(b) pushes conflicting ongoing txns' bounds,
  4(c) stamps CIDs and bumps SIDs; rule 5 aborts when s_lo > s_hi.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

INF = 2 ** 30


@dataclasses.dataclass
class Version:
    value: int
    tid: int
    cid: int = 0
    sid: int = 0
    visitors: Set[int] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class Txn:
    tid: int
    status: str = "running"            # running | committed | aborted
    s_lo: int = 0
    s_hi: int = INF
    c_lo: int = 0
    s: Optional[int] = None
    c: Optional[int] = None
    reads: Dict[int, int] = dataclasses.field(default_factory=dict)   # key -> version idx
    writes: Dict[int, int] = dataclasses.field(default_factory=dict)  # key -> value (private)


class SeqScheduler:
    """mode='postsi' enforces SI; mode='cv' enforces Consistent Visibility."""

    def __init__(self, n_keys: int, mode: str = "postsi"):
        assert mode in ("postsi", "cv")
        self.mode = mode
        self.versions: Dict[int, List[Version]] = {
            k: [Version(0, 0, 0, 0)] for k in range(n_keys)}
        self.txns: Dict[int, Txn] = {}
        self.antidep: Set[Tuple[int, int]] = set()   # (i, j): t_i -rw-> t_j
        self._next_tid = 1

    # ------------------------------------------------------------------ API
    def begin(self, s_hi_pin: Optional[int] = None) -> int:
        """rule 1: s_lo=0, s_hi=inf, c_lo=0.  ``s_hi_pin`` implements the
        paper's §IV-B retry optimization: after an abort caused by a hot
        remote item, retry with the start-time upper bound pinned at the
        highest CID observed before the abort — the retried transaction then
        refuses versions newer than the pin instead of aborting again."""
        tid = self._next_tid
        self._next_tid += 1
        t = Txn(tid)
        if s_hi_pin is not None:
            t.s_hi = s_hi_pin
        self.txns[tid] = t
        return tid

    def max_observed_cid(self, tid: int) -> int:
        """Highest CID this transaction has encountered (for the retry pin)."""
        t = self.txns[tid]
        best = t.s_lo
        for key, idx in t.reads.items():
            best = max(best, self.versions[key][idx].cid)
        return best

    def read(self, tid: int, key: int) -> Optional[int]:
        """CV rule 4: read the latest *visible* version; PostSI §IV-B: a
        version is invisible if reading it would push s_lo past s_hi."""
        t = self.txns[tid]
        assert t.status == "running"
        if key in t.writes:                 # read-your-own-write
            return t.writes[key]
        chain = self.versions[key]
        for idx in range(len(chain) - 1, -1, -1):
            v = chain[idx]
            # CV rule 4: skip versions by creators I anti-depend on
            if (tid, v.tid) in self.antidep:
                continue
            if self.mode == "postsi" and v.cid > t.s_hi:
                continue                    # CID visibility rule (§IV-B)
            # found the latest visible version
            v.visitors.add(tid)             # visitor list insert (atomic)
            t.reads[key] = idx
            if self.mode == "postsi":       # rule 3: creator must be visible
                t.s_lo = max(t.s_lo, v.cid)
                t.c_lo = max(t.c_lo, v.cid)
                if t.s_lo > t.s_hi:         # rule 5
                    self.abort(tid)
                    return None
            return v.value
        self.abort(tid)                     # no visible version at all
        return None

    def write(self, tid: int, key: int, value: int) -> None:
        """Private write set (§IV-C); locks/validation at commit."""
        t = self.txns[tid]
        assert t.status == "running"
        t.writes[key] = value

    def abort(self, tid: int) -> None:
        t = self.txns[tid]
        t.status = "aborted"
        for key, idx in t.reads.items():
            self.versions[key][idx].visitors.discard(tid)
        self.antidep = {(a, b) for (a, b) in self.antidep if a != tid and b != tid}

    def commit(self, tid: int) -> bool:
        t = self.txns[tid]
        assert t.status == "running"

        # ---- CV rule 5 validation on the write set ----------------------
        for key in t.writes:
            newest = self.versions[key][-1]
            if key in t.reads and t.reads[key] != len(self.versions[key]) - 1:
                self.abort(tid)             # read version is no longer newest
                return False
            if (tid, newest.tid) in self.antidep:
                self.abort(tid)             # rule 5(ii)
                return False
            if self.mode == "postsi":       # rule 3 for overwrites
                t.s_lo = max(t.s_lo, newest.cid)
                t.c_lo = max(t.c_lo, newest.cid)
                # SID of the overwritten version: committed readers' start
                # times are passed to later writers through SIDs (§III-D)
                t.c_lo = max(t.c_lo, newest.sid)

        if self.mode == "postsi":
            if t.s_lo > t.s_hi:             # rule 5
                self.abort(tid)
                return False
            # ---- rule 4(a): determine own interval -----------------------
            t.s = t.s_lo
            for key, idx in t.reads.items():
                t.c_lo = max(t.c_lo, self.versions[key][idx].sid)
            for (i, j) in self.antidep:
                if j == tid and self.txns[i].status == "running":
                    t.c_lo = max(t.c_lo, self.txns[i].s_lo)
            t.c = max(t.c_lo, t.s) + 1
            # ---- rule 4(b): adjust conflicting ongoing transactions ------
            for (i, j) in list(self.antidep):
                if i == tid and self.txns[j].status == "running":
                    # tid -rw-> t_j : t_j invisible to me -> c_j > s_tid
                    self.txns[j].c_lo = max(self.txns[j].c_lo, t.s + 1)
                if j == tid and self.txns[i].status == "running":
                    # t_i -rw-> tid : tid invisible to t_i -> s_i < c_tid
                    self.txns[i].s_hi = min(self.txns[i].s_hi, t.c - 1)
        else:
            t.s, t.c = 0, 0                 # CV induces no timestamps

        # ---- install writes; CV rule 6: materialize rw edges -------------
        for key, value in t.writes.items():
            for reader in self.versions[key][-1].visitors:
                if reader != tid and self.txns[reader].status == "running":
                    self.antidep.add((reader, tid))
                    # rule 4(b) for readers of what I overwrite, applied at my
                    # commit: their start precedes my commit
                    if self.mode == "postsi":
                        self.txns[reader].s_hi = min(self.txns[reader].s_hi,
                                                     (t.c or 0) - 1)
            self.versions[key].append(Version(value, tid, t.c or 0))
        # ---- rule 4(c): bump SIDs of read versions -----------------------
        if self.mode == "postsi":
            for key, idx in t.reads.items():
                v = self.versions[key][idx]
                v.sid = max(v.sid, t.s)
        # ---- CV rule 6 cleanup -------------------------------------------
        for key, idx in t.reads.items():
            self.versions[key][idx].visitors.discard(tid)
        self.antidep = {(a, b) for (a, b) in self.antidep if b != tid and a != tid}
        t.status = "committed"
        return True

    # ------------------------------------------------------------- history
    def history(self):
        """In the wave-engine format, for verify_si / verify_cv."""
        import numpy as np
        txns = [t for t in self.txns.values()]
        T = len(txns)
        O = max([len(t.reads) + len(t.writes) for t in txns] + [1])

        class H:
            pass

        out = H()
        out.status = np.array([1 if t.status == "committed" else 2 for t in txns])
        out.s = np.array([t.s if t.s is not None else -1 for t in txns])
        out.c = np.array([t.c if t.c is not None else -1 for t in txns])
        out.read_key = np.full((T, O), -1)
        out.read_cid = np.full((T, O), -1)
        out.write_key = np.full((T, O), -1)
        out.write_cid = np.full((T, O), -1)
        for i, t in enumerate(txns):
            if t.status != "committed":
                continue
            for o, (k, idx) in enumerate(t.reads.items()):
                out.read_key[i, o] = k
                out.read_cid[i, o] = self.versions[k][idx].cid
            for o, k in enumerate(t.writes):
                out.write_key[i, o] = k
                # find the version this txn installed
                for v in self.versions[k]:
                    if v.tid == t.tid:
                        out.write_cid[i, o] = v.cid
        tids = np.array([t.tid for t in txns])
        return [(tids, out)]
