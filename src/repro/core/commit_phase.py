"""Shared commit-phase rules + anti-dependency matrix build (DESIGN.md §7).

Both wave engines — the single-device `engine.py` and the shard_map
`dist_engine.py` — execute the exact same commit-phase arithmetic (the
paper's CV rules 5-6 and PostSI rules 3/4/5); only the data-plane
primitives differ (direct store indexing vs. gather+psum peer collectives).
This module is the single home of that replicated arithmetic so the two
engines cannot drift, and of the ``potential`` anti-dependency matrix build,
which it routes to the tiled Pallas kernel
(`repro.kernels.interval_negotiate.potential_matrix_pallas`) or the dense
jnp reference depending on a process-wide backend config.

Backend selection (``set_potential_backend`` / env ``REPRO_POTENTIAL_BACKEND``):

  auto              -> "pallas" on TPU, "pallas_interpret" elsewhere (default)
  pallas            -> Mosaic-compiled kernel (TPU)
  pallas_interpret  -> the same kernel body, interpreted on CPU
  jnp               -> the dense [T,T,O,O] broadcast-compare reference
                       (escape hatch; bit-identical to the kernel by
                       tests/test_kernels.py and tests/test_fused_executor.py)

Because the engines jit-compile with the backend baked in at trace time,
``set_potential_backend`` clears the jit caches registered via
``register_cache_clear`` so a config change takes effect immediately.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# op kinds (one code per wave-op slot)
NOP, READ, WRITE, RMW = 0, 1, 2, 3
# txn status
RUNNING, COMMITTED, ABORTED = 0, 1, 2

POTENTIAL_BACKENDS = ("auto", "pallas", "pallas_interpret", "jnp")

_backend = os.environ.get("REPRO_POTENTIAL_BACKEND", "auto")
_clear_hooks = []


def register_cache_clear(jitted) -> None:
    """Engines register their jitted entry points; a backend switch clears
    them so the new backend is traced in."""
    _clear_hooks.append(jitted)


def set_potential_backend(name: str) -> None:
    global _backend
    assert name in POTENTIAL_BACKENDS, (name, POTENTIAL_BACKENDS)
    _backend = name
    for fn in _clear_hooks:
        try:
            fn.clear_cache()
        except Exception:
            pass


def potential_backend() -> str:
    """The resolved (non-auto) backend name."""
    if _backend != "auto":
        return _backend
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


# ---------------------------------------------------------------------------
# potential[i, j] = "txn i read a key that txn j writes"
# ---------------------------------------------------------------------------

def potential_matrix_jnp(read_key, write_key, read_mask, write_mask):
    """Dense reference build: [T,T,O,O] broadcast-compare, diagonal masked."""
    rk = jnp.where(read_mask, read_key, -1)
    wk = jnp.where(write_mask, write_key, -2)
    eq = rk[:, None, :, None] == wk[None, :, None, :]     # [T,T,O,O]
    pot = eq.any(axis=(2, 3))
    T = read_key.shape[0]
    return pot & ~jnp.eye(T, dtype=bool)


def build_potential(keys, is_read, is_write, backend: str | None = None):
    """Anti-dependency candidates for one wave: bool [T, T].

    keys: [T, O] int32 op keys (>= 0 where active); is_read / is_write:
    [T, O] bool op masks. Routed per ``backend`` (None = process config).
    """
    backend = backend or potential_backend()
    if backend == "jnp":
        return potential_matrix_jnp(keys, keys, is_read, is_write)
    from repro.kernels import ops
    rk = jnp.where(is_read, keys, -1)
    wk = jnp.where(is_write, keys, -1)
    out = ops.potential_matrix(rk, wk, use_pallas=True,
                               interpret=(backend == "pallas_interpret"))
    return out.astype(bool)


# ---------------------------------------------------------------------------
# commit-phase arithmetic shared by engine.py and dist_engine.py
# ---------------------------------------------------------------------------

def creator_slots(nv_tid, tid0, n_txns, status):
    """Map newest-version creator TIDs to wave-local txn ids.

    Returns (local [O] int32, creator_committed [O] bool): local is -1 for
    creators from older waves (their versions are settled and never block a
    same-wave commit)."""
    local = nv_tid - tid0
    local = jnp.where((local >= 0) & (local < n_txns), local, -1)
    committed = jnp.where(
        local >= 0, status[jnp.maximum(local, 0)] == COMMITTED, False)
    return local, committed


def lost_update(r_i, w_i, nv_cid, r_cid_i):
    """CV rule 5(i): an RMW whose read version is no longer newest."""
    return (r_i & w_i & (nv_cid != r_cid_i)).any()


def rw_edge_to_creator(w_i, local, creator_committed, potential_row):
    """CV rule 5(ii): the newest creator of a key I write has an rw edge
    from me (I read data it overwrote) -> it is invisible to me -> I cannot
    overwrite its version."""
    return jnp.where(w_i & (local >= 0) & creator_committed,
                     potential_row[jnp.maximum(local, 0)], False).any()


def ongoing_readers_of(i, potential, status):
    """Mask of still-RUNNING txns that read a key txn i writes (self off)."""
    readers = potential[:, i] & (status == RUNNING)
    return readers.at[i].set(False)


def postsi_bounds(s_lo_i, s_hi_i, c_lo_i, r_i, w_i, nv_cid, nv_sid, cur_sid,
                  ongoing_reader, s_lo):
    """PostSI rules 3/4(a)/5 for the committing txn i.

    Inputs: current bounds (s_lo_i, s_hi_i, c_lo_i), op masks r_i/w_i [O],
    newest-version cid/sid over i's keys (nv_cid/nv_sid [O]), re-gathered
    SIDs of i's read slots (cur_sid [O] — peers may have bumped them while i
    ran), ongoing_reader [T] mask and the wave s_lo vector [T].
    Returns (s_i, c_i, interval_abort)."""
    w_cid_max = jnp.where(w_i, nv_cid, 0).max()
    # rule 3 for overwrites: creators of overwritten versions must be visible
    s_lo_i = jnp.maximum(s_lo_i, w_cid_max)
    c_lo_i = jnp.maximum(c_lo_i, w_cid_max)
    # rule 4(a): commit time above SIDs of read versions ...
    c_lo_i = jnp.maximum(c_lo_i, jnp.where(r_i, cur_sid, 0).max())
    # ... and above SIDs of versions we *overwrite* (blind writes): SID
    # passes committed readers' start times to later writers
    c_lo_i = jnp.maximum(c_lo_i, jnp.where(w_i, nv_sid, 0).max())
    # ... and above s_lo of every ongoing reader of my write set
    c_lo_i = jnp.maximum(c_lo_i, jnp.where(ongoing_reader, s_lo, 0).max())
    # rule 5: no valid start time left
    interval_abort = s_lo_i > s_hi_i
    s_i = s_lo_i
    c_i = jnp.maximum(c_lo_i, s_i) + 1
    return s_i, c_i, interval_abort


def push_bounds(i, commit, s_i, c_i, potential, status, s_lo, s_hi, c_lo):
    """PostSI rule 4(b): a committing txn pushes the interval bounds of every
    conflicting *ongoing* transaction (replicated arithmetic — identical on
    every node of the dist engine)."""
    running = status == RUNNING
    i_reads_them = potential[i, :] & running          # me -rw-> them
    c_lo = jnp.where(commit & i_reads_them, jnp.maximum(c_lo, s_i + 1), c_lo)
    they_read_mine = potential[:, i] & running
    s_hi = jnp.where(commit & they_read_mine, jnp.minimum(s_hi, c_i - 1), s_hi)
    s_lo = s_lo.at[i].set(jnp.where(commit, s_i, s_lo[i]))
    return s_lo, s_hi, c_lo
