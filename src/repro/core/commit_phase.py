"""Shared commit-phase rules + anti-dependency matrix build (DESIGN.md §7).

Both wave engines — the single-device `engine.py` and the shard_map
`dist_engine.py` — execute the exact same commit-phase arithmetic (the
paper's CV rules 5-6 and PostSI rules 3/4/5); only the data-plane
primitives differ (direct store indexing vs. gather+psum peer collectives).
This module is the single home of that replicated arithmetic so the two
engines cannot drift, and of the ``potential`` anti-dependency matrix build,
which it routes to the tiled Pallas kernel
(`repro.kernels.interval_negotiate.potential_matrix_pallas`) or the dense
jnp reference per a resolved ``kernels.backend.KernelConfig``.

Backend selection lives in ``repro.kernels.backend`` (env
``REPRO_KERNEL_BACKEND``, ``set_default_backend``, or a ``KernelConfig``
threaded through the substrate/engine); ``set_potential_backend`` /
``potential_backend`` survive as deprecated shims forwarding there.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.kernels import backend as kernel_backend
from repro.kernels.backend import register_cache_clear  # re-export (compat)

# op kinds (one code per wave-op slot)
NOP, READ, WRITE, RMW = 0, 1, 2, 3
# txn status
RUNNING, COMMITTED, ABORTED = 0, 1, 2

POTENTIAL_BACKENDS = ("auto",) + kernel_backend.BACKENDS


def set_potential_backend(name: str) -> None:
    """Deprecated: forwards to ``kernels.backend.set_default_backend`` (the
    per-op global this shimmed is gone; one config now serves every op)."""
    warnings.warn(
        "set_potential_backend is deprecated; use "
        "repro.kernels.set_default_backend (process default) or thread a "
        "repro.kernels.KernelConfig through the engine/substrate",
        DeprecationWarning, stacklevel=2)
    kernel_backend.set_default_backend(name)


def potential_backend() -> str:
    """Deprecated alias of ``kernels.backend.default_backend``."""
    return kernel_backend.default_backend()


# ---------------------------------------------------------------------------
# potential[i, j] = "txn i read a key that txn j writes"
# ---------------------------------------------------------------------------

def build_potential(keys, is_read, is_write, backend=None):
    """Anti-dependency candidates for one wave: bool [T, T].

    keys: [T, O] int32 op keys (>= 0 where active); is_read / is_write:
    [T, O] bool op masks.  ``backend`` is anything ``kernels.backend.resolve``
    accepts — a resolved ``KernelConfig``, a backend name, or ``None`` for
    the process default.  All routes are bit-identical; the jnp body lives
    ONLY in ``kernels.ref.potential_matrix_ref`` (the test oracle), so there
    is exactly one copy of the rule per backend.
    """
    cfg = kernel_backend.resolve(backend)
    rk = jnp.where(is_read, keys, -1)
    wk = jnp.where(is_write, keys, -1)
    if not cfg.use_pallas:
        from repro.kernels import ref
        return ref.potential_matrix_ref(rk, wk).astype(bool)
    from repro.kernels import ops
    out = ops.potential_matrix(rk, wk, use_pallas=True,
                               interpret=cfg.interpret)
    return out.astype(bool)


# ---------------------------------------------------------------------------
# commit-phase arithmetic shared by engine.py and dist_engine.py
# ---------------------------------------------------------------------------

def creator_slots(nv_tid, tid0, n_txns, status):
    """Map newest-version creator TIDs to wave-local txn ids.

    Returns (local [O] int32, creator_committed [O] bool): local is -1 for
    creators from older waves (their versions are settled and never block a
    same-wave commit)."""
    local = nv_tid - tid0
    local = jnp.where((local >= 0) & (local < n_txns), local, -1)
    committed = jnp.where(
        local >= 0, status[jnp.maximum(local, 0)] == COMMITTED, False)
    return local, committed


def lost_update(r_i, w_i, nv_cid, r_cid_i):
    """CV rule 5(i): an RMW whose read version is no longer newest."""
    return (r_i & w_i & (nv_cid != r_cid_i)).any()


def rw_edge_to_creator(w_i, local, creator_committed, potential_row):
    """CV rule 5(ii): the newest creator of a key I write has an rw edge
    from me (I read data it overwrote) -> it is invisible to me -> I cannot
    overwrite its version."""
    return jnp.where(w_i & (local >= 0) & creator_committed,
                     potential_row[jnp.maximum(local, 0)], False).any()


def ongoing_readers_of(i, potential, status):
    """Mask of still-RUNNING txns that read a key txn i writes (self off)."""
    readers = potential[:, i] & (status == RUNNING)
    return readers.at[i].set(False)


def postsi_bounds(s_lo_i, s_hi_i, c_lo_i, r_i, w_i, nv_cid, nv_sid, cur_sid,
                  ongoing_reader, s_lo):
    """PostSI rules 3/4(a)/5 for the committing txn i.

    Inputs: current bounds (s_lo_i, s_hi_i, c_lo_i), op masks r_i/w_i [O],
    newest-version cid/sid over i's keys (nv_cid/nv_sid [O]), re-gathered
    SIDs of i's read slots (cur_sid [O] — peers may have bumped them while i
    ran), ongoing_reader [T] mask and the wave s_lo vector [T].
    Returns (s_i, c_i, interval_abort)."""
    w_cid_max = jnp.where(w_i, nv_cid, 0).max()
    # rule 3 for overwrites: creators of overwritten versions must be visible
    s_lo_i = jnp.maximum(s_lo_i, w_cid_max)
    c_lo_i = jnp.maximum(c_lo_i, w_cid_max)
    # rule 4(a): commit time above SIDs of read versions ...
    c_lo_i = jnp.maximum(c_lo_i, jnp.where(r_i, cur_sid, 0).max())
    # ... and above SIDs of versions we *overwrite* (blind writes): SID
    # passes committed readers' start times to later writers
    c_lo_i = jnp.maximum(c_lo_i, jnp.where(w_i, nv_sid, 0).max())
    # ... and above s_lo of every ongoing reader of my write set
    c_lo_i = jnp.maximum(c_lo_i, jnp.where(ongoing_reader, s_lo, 0).max())
    # rule 5: no valid start time left
    interval_abort = s_lo_i > s_hi_i
    s_i = s_lo_i
    c_i = jnp.maximum(c_lo_i, s_i) + 1
    return s_i, c_i, interval_abort


def push_bounds(i, commit, s_i, c_i, potential, status, s_lo, s_hi, c_lo):
    """PostSI rule 4(b): a committing txn pushes the interval bounds of every
    conflicting *ongoing* transaction (replicated arithmetic — identical on
    every node of the dist engine)."""
    running = status == RUNNING
    i_reads_them = potential[i, :] & running          # me -rw-> them
    c_lo = jnp.where(commit & i_reads_them, jnp.maximum(c_lo, s_i + 1), c_lo)
    they_read_mine = potential[:, i] & running
    s_hi = jnp.where(commit & they_read_mine, jnp.minimum(s_hi, c_i - 1), s_hi)
    s_lo = s_lo.at[i].set(jnp.where(commit, s_i, s_lo[i]))
    return s_lo, s_hi, c_lo
