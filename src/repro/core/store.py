"""Multi-version KV store as dense JAX arrays (the paper's version chains).

Each key owns a ring buffer of ``V`` versions carrying the paper's per-version
metadata: creator TID, CID (creator's commit time) and SID (max start time of
committed readers).  Keys are partitioned across ``n_nodes`` shared-nothing
nodes by ``key % n_nodes`` — visitor lists are co-located with their data
(paper §IV-A) by construction.

Timestamps are logical integers induced by PostSI; no real clock exists
anywhere in this module.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.int32(2 ** 30)
NO_TID = jnp.int32(-1)


class MVStore(NamedTuple):
    """Columnar version store. All arrays are device-resident."""
    val: jax.Array     # [n_keys, V] int32 payloads
    tid: jax.Array     # [n_keys, V] int32 creator TID (NO_TID = empty slot)
    cid: jax.Array     # [n_keys, V] int32 commit time of creator
    sid: jax.Array     # [n_keys, V] int32 max start time of committed readers
    head: jax.Array    # [n_keys]    int32 ring index of newest version
    wave: jax.Array    # [n_keys]    int32 wave index of last commit (staleness)

    @property
    def n_keys(self) -> int:
        return self.val.shape[0]

    @property
    def n_versions(self) -> int:
        return self.val.shape[1]


def make_store(n_keys: int, n_versions: int = 4, init_val: int = 0) -> MVStore:
    """Fresh store: every key has one initial version by bootstrap txn t0
    (tid 0, cid 0), matching the paper's 'original version of the database'."""
    val = jnp.full((n_keys, n_versions), init_val, jnp.int32)
    tid = jnp.full((n_keys, n_versions), NO_TID, jnp.int32)
    tid = tid.at[:, 0].set(0)
    cid = jnp.zeros((n_keys, n_versions), jnp.int32)
    sid = jnp.zeros((n_keys, n_versions), jnp.int32)
    head = jnp.zeros((n_keys,), jnp.int32)
    wave = jnp.zeros((n_keys,), jnp.int32)
    return MVStore(val, tid, cid, sid, head, wave)


def node_of_key(key: jax.Array, n_nodes: int) -> jax.Array:
    return key % n_nodes


class PlacementArrays(NamedTuple):
    """Device-resident placement tables for elastic key routing.

    The engine stays SPMD-oblivious: a logical key ``k`` is translated ONCE
    per wave into a physical store row (``slot[k]``) and an owning node
    (``owner[k]``), and every downstream substrate/kernel call operates on
    those.  Both tables are replicated on every node (they are tiny — one
    int32 each per logical key) so lookups are local gathers.

    ``None`` placement everywhere means the frozen ``key % n_nodes`` layout
    with ``slot[k] == k`` — the engine's placement-free fast path, kept
    bit-identical by construction.
    """
    owner: jax.Array   # [n_keys] int32 owning node of each logical key
    slot: jax.Array    # [n_keys] int32 physical store row of each logical key


def as_placement_arrays(p) -> PlacementArrays | None:
    """Normalize ``None | PlacementArrays | PlacementMap-like`` to device
    arrays (anything exposing ``.device_arrays()`` is accepted so callers can
    hand the host-side map straight to the drivers)."""
    if p is None:
        return None
    if isinstance(p, PlacementArrays):
        return p
    if hasattr(p, "device_arrays"):
        return p.device_arrays()
    owner, slot = p
    return PlacementArrays(jnp.asarray(owner, jnp.int32),
                           jnp.asarray(slot, jnp.int32))


def read_visible(store: MVStore, keys: jax.Array, max_cid: jax.Array):
    """Latest visible version per key: newest version with CID <= max_cid.

    This is the paper's §IV-B read rule ("a data item is visible only if its
    CID is smaller than the upper bound of the transaction's start time") and
    the hot spot targeted by kernels/version_scan.

    keys: [...] int32; max_cid: broadcastable to keys.
    Returns (val, tid, cid, sid, slot) of the selected version.
    """
    cids = store.cid[keys]                       # [..., V]
    tids = store.tid[keys]
    ok = (tids != NO_TID) & (cids <= max_cid[..., None])
    # newest visible = max cid among visible slots (cids are unique per key)
    masked = jnp.where(ok, cids, -1)
    slot = jnp.argmax(masked, axis=-1)
    take = lambda a: jnp.take_along_axis(a[keys], slot[..., None], axis=-1)[..., 0]
    return take(store.val), take(store.tid), take(store.cid), take(store.sid), slot


def read_newest(store: MVStore, keys: jax.Array):
    """Newest committed version (PostSI reads start with s_hi = +inf)."""
    return read_visible(store, keys, jnp.broadcast_to(INF, keys.shape))


def evicting_visible(store: MVStore, keys: jax.Array,
                     watermark: jax.Array) -> jax.Array:
    """GC watermark consult (DESIGN.md §8): would installing a new version of
    ``keys`` evict a version that is still visible to a live snapshot?

    The slot about to be reused (``head + 1``) holds the key's *oldest*
    version.  That version is dead — reclaimable — once its superseding
    version (the next-oldest slot) has ``CID <= watermark``: every snapshot a
    live or future reader can still take is ``>= watermark``, and all of them
    resolve to the superseder or newer.  Conversely, ``superseder.CID >
    watermark`` means some snapshot in ``[watermark, superseder.CID)`` still
    maps to the evicted version — reusing the slot silently corrupts that
    read.  ``watermark`` is the decentralized min over live readers'
    ``s_lo`` (plus any external pins; see repro/service/gc.py).

    Returns a bool mask shaped like ``keys`` (False for empty slots — a ring
    that has not wrapped yet never evicts anything).

    ``keys`` may contain masked/NOP padding, including negative sentinels;
    they are clamped into range (``jnp.minimum`` alone would let a negative
    key wrap to the LAST key via negative indexing and report that key's
    eviction state for a padding row).
    """
    k = jnp.clip(keys, 0, store.n_keys - 1)
    h_new = (store.head[k] + 1) % store.n_versions
    evicted_live = store.tid[k, h_new] != NO_TID
    superseder_cid = store.cid[k, (h_new + 1) % store.n_versions]
    return evicted_live & (superseder_cid > watermark)


def install_version(store: MVStore, key: jax.Array, value: jax.Array,
                    tid: jax.Array, cid: jax.Array, wave_idx: jax.Array,
                    watermark: jax.Array | None = None):
    """Push one new version onto a key's ring (commit-phase write install).

    Returns ``(store', evicted_visible)`` where ``evicted_visible`` counts
    ring-slot reuses that destroyed a version still visible to a live
    snapshot per ``evicting_visible`` — the silent ring-buffer overflow this
    store used to ignore.  With ``watermark=None`` the check is maximally
    conservative (watermark 0: any wrap of a superseded-after-bootstrap
    version counts); callers that maintain a real watermark pass it in and
    see 0 whenever V is sized to the read horizon.  (The wave engines
    inline this install as a masked scatter over a whole wave — see
    ``engine.run_wave`` — and apply the same ``evicting_visible`` check
    there; this host-level helper serves single-key callers and the unit
    tests that pin the shared semantics.)
    """
    wm = jnp.int32(0) if watermark is None else watermark
    evicted = evicting_visible(store, key, wm).astype(jnp.int32).sum()
    h = (store.head[key] + 1) % store.n_versions
    return store._replace(
        val=store.val.at[key, h].set(value),
        tid=store.tid.at[key, h].set(tid),
        cid=store.cid.at[key, h].set(cid),
        sid=store.sid.at[key, h].set(0),
        head=store.head.at[key].set(h),
        wave=store.wave.at[key].set(wave_idx),
    ), evicted


def bump_sid(store: MVStore, key: jax.Array, slot: jax.Array,
             start_time: jax.Array) -> MVStore:
    """Rule 4(c): raise SID of a read version to the reader's start time."""
    cur = store.sid[key, slot]
    return store._replace(sid=store.sid.at[key, slot].set(jnp.maximum(cur, start_time)))
