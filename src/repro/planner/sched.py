"""The seventh scheduler: ``"planned"`` — deterministic lane execution that
commits abort-free (DESIGN.md §10).

This module is the planner's back half: it takes one wave plus its ``Plan``
(lanes.py) and turns it into ONE ordinary wave *block* for the existing
engine — every lane becomes a wave in the stack, the spill set (if any)
becomes the final wave, and the whole block runs through
``engine.run_block`` / ``dist_engine.run_block_dist``, i.e. through
``engine.run_wave_on``.  There is **zero new copy of the CC rules**: a lane
is just a wave the planner has proven conflict-free, and the engine's own
rules then have nothing to abort:

* no same-lane writer of a read key  ⇒ re-validation finds the read version
  still newest (no rule-4(a) lost update, no dsi stale-remote);
* the potential anti-dependency matrix is empty  ⇒ no rule-5 RW edges, no
  first-committer-wins WW conflict (si/optimal/clocksi);
* s_hi stays unpinned (+inf)  ⇒ the PostSI interval can always be ordered.

The one honest exception: ``gc_block=True`` aborts *writers* whose ring
slot would destroy a still-visible version — a storage condition the
planner cannot see — so the zero-abort assertion is enforced only when it
is off (likewise under ``host_skew``, where clock-si's deliberately stale
snapshots reintroduce lost updates across lanes).

Shape discipline: lanes are ragged, so every lane/spill wave is padded with
NOP rows to one shared power-of-two width and the lane count is padded with
all-NOP waves to a power-of-two block — the jitted block engine sees at
most log2 × log2 shapes, and NOP rows/waves commit vacuously without
touching the store.  Each padded wave gets *fresh contiguous* transaction
ids: the commit loop's creator-slot map assumes a wave's tids are
``[tid0, tid0 + T)`` (commit_phase.creator_slots), so lane transactions are
relabeled from a monotone counter and the mapping back to the caller's rows
is returned (``PlannedWave.exec_tid``).

Host-side planning cost is real and on the critical path (graph build +
coloring + packing, all numpy); the crossover benchmark
(benchmarks/bench_engine.py) measures it honestly — planned wins only where
the abort rate it avoids exceeds what the planning and extra lane dispatch
cost, which is the high-skew regime.
"""
from __future__ import annotations

import time
from typing import List, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.commit_phase import ABORTED, NOP
from repro.core.engine import (SCHEDULERS, Wave, WaveOut, step_block,
                               _stats_of)

from .lanes import Plan, plan_wave

#: the planner registers as a seventh scheduler *above* the engine's six:
#: ``sched``/``base_sched`` below selects which of the six adjudicates each
#: lane, so "planned" composes with — never forks — the CC rules.
PLANNED = "planned"
ALL_SCHEDULERS = SCHEDULERS + (PLANNED,)

#: default lane budget for bounded planning (service hybrid mode); ``None``
#: disables spilling entirely (lane count = longest conflict chain + 1)
DEFAULT_MAX_LANES = 16

_STAT_FIELDS = ("msgs_cross", "msgs_coord", "waits", "evicted_visible")


class PlannerError(RuntimeError):
    """A planned lane aborted — a planner invariant violation, never an
    expected runtime condition."""


class PlannedWave(NamedTuple):
    """Outcome of one planned wave, host-side."""
    merged: WaveOut           # numpy, rows aligned with the input wave
    exec_tid: np.ndarray      # [T] the fresh tid each input row ran under
    plan: Plan                # lane assignment (lanes.py)
    stacked: Wave             # numpy [L, T_pad, O] block that was dispatched
    outs: WaveOut             # numpy raw per-wave outputs, leading [L] axis
    waves_consumed: int       # wave indices used (= L, incl. pow2 padding)
    tids_consumed: int        # tid counter advance (= L * T_pad)
    lane_waves: int           # real lane waves dispatched
    spill_waves: int          # 0 or 1


def _pow2ceil(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def build_planned_block(wave: Wave, plan: Plan, next_tid: int
                        ) -> Tuple[Wave, List[np.ndarray], int]:
    """Pack a wave's lanes (+ spill) into one pow2-padded numpy block.

    Returns ``(stacked, rows, T_pad)`` where ``stacked`` is a numpy
    ``Wave`` with leading [L] axis (L and the per-wave width both rounded
    up to powers of two, padding = NOP rows with tids still contiguous) and
    ``rows[l]`` holds the *input-wave* row indices occupying the first
    ``len(rows[l])`` slots of block wave ``l`` (empty for padding waves)."""
    groups = [lane for lane in plan.lanes]
    if len(plan.spill):
        groups.append(plan.spill)
    if not groups:                       # degenerate empty wave
        groups = [np.arange(0)]
    T_pad = _pow2ceil(max(len(g) for g in groups))
    L = _pow2ceil(len(groups))
    O = np.asarray(wave.op_kind).shape[1]
    op_kind = np.full((L, T_pad, O), NOP, np.int32)
    op_key = np.zeros((L, T_pad, O), np.int32)
    op_val = np.zeros((L, T_pad, O), np.int32)
    host = np.zeros((L, T_pad), np.int32)
    src = {f: np.asarray(getattr(wave, f)) for f in ("op_kind", "op_key",
                                                     "op_val", "host")}
    rows: List[np.ndarray] = []
    for l, g in enumerate(groups):
        n = len(g)
        op_kind[l, :n] = src["op_kind"][g]
        op_key[l, :n] = src["op_key"][g]
        op_val[l, :n] = src["op_val"][g]
        host[l, :n] = src["host"][g]
        rows.append(np.asarray(g))
    rows += [np.arange(0)] * (L - len(groups))
    tid = (next_tid + np.arange(L * T_pad, dtype=np.int64)
           ).reshape(L, T_pad).astype(np.int32)
    return Wave(op_kind, op_key, op_val, host, tid), rows, T_pad


def _merge_rows(wave: Wave, outs: WaveOut, rows: List[np.ndarray],
                n_real_waves: int) -> WaveOut:
    """Scatter the block's per-lane outcomes back to input-row order.
    Scalar stats are summed over the real (non-padding) waves only."""
    T = np.asarray(wave.tid).shape[0]
    O = np.asarray(wave.op_kind).shape[1]
    status = np.zeros(T, np.int32)
    s = np.zeros(T, np.int32)
    c = np.zeros(T, np.int32)
    read_key = np.full((T, O), -1, np.int32)
    read_cid = np.zeros((T, O), np.int32)
    write_key = np.full((T, O), -1, np.int32)
    write_cid = np.zeros((T, O), np.int32)
    for l, g in enumerate(rows):
        n = len(g)
        if not n:
            continue
        status[g] = outs.status[l, :n]
        s[g] = outs.s[l, :n]
        c[g] = outs.c[l, :n]
        read_key[g] = outs.read_key[l, :n]
        read_cid[g] = outs.read_cid[l, :n]
        write_key[g] = outs.write_key[l, :n]
        write_cid[g] = outs.write_cid[l, :n]
    stats = {f: np.asarray(getattr(outs, f))[:n_real_waves].sum()
             .astype(np.int32) for f in _STAT_FIELDS}
    return WaveOut(status=status, s=s, c=c, read_key=read_key,
                   read_cid=read_cid, write_key=write_key,
                   write_cid=write_cid, **stats)


def run_wave_planned(store, wave: Wave, clock, *, wave_idx0: int,
                     next_tid: int, sched: str = "postsi", n_nodes: int = 8,
                     mesh=None, kernels=None, watermark=None,
                     host_skew=None, gc_track: bool = True,
                     gc_block: bool = False,
                     max_lanes: Optional[int] = DEFAULT_MAX_LANES,
                     placement=None):
    """Execute one wave under the planned scheduler.

    Plans on the host (graph → lanes → pow2 block), relabels every row with
    a fresh contiguous tid from ``next_tid``, dispatches the block through
    the configured substrate (``engine.step_block`` locally,
    ``dist_engine.step_block_dist`` on a mesh — both land in
    ``engine.run_wave_on`` per lane), asserts zero aborts on planned lanes,
    and scatters outcomes back to input-row order.

    Returns ``(store', clock', PlannedWave)``; the caller advances its wave
    index by ``.waves_consumed`` and its tid counter by ``.tids_consumed``.
    """
    if sched not in SCHEDULERS:
        raise ValueError(f"base scheduler must be one of {SCHEDULERS}, "
                         f"got {sched!r}")
    plan = plan_wave(wave.op_kind, wave.op_key, max_lanes=max_lanes)
    stacked, rows, T_pad = build_planned_block(wave, plan, next_tid)
    L = stacked.op_kind.shape[0]
    n_real = plan.n_lanes + (1 if plan.n_spilled else 0)
    kw = dict(sched=sched, n_nodes=n_nodes, host_skew=host_skew,
              watermark=watermark, gc_track=gc_track, gc_block=gc_block,
              kernels=kernels, placement=placement)
    if mesh is None:
        store, outs, clock = step_block(store, stacked, wave_idx0, clock,
                                        **kw)
    else:
        from repro.core.dist_engine import step_block_dist
        store, outs, clock = step_block_dist(store, stacked, wave_idx0,
                                             clock, mesh, **kw)
    # zero-abort invariant on planned lanes (spill wave exempt — it is the
    # optimistic path); gc_block / host_skew legitimately abort laned
    # writers for reasons the conflict graph cannot see, so only assert
    # when neither is in play
    if not gc_block and host_skew is None:
        for l in range(plan.n_lanes):
            n = len(rows[l])
            bad = np.flatnonzero(outs.status[l, :n] == ABORTED)
            if len(bad):
                raise PlannerError(
                    f"planned lane {l} aborted rows {bad.tolist()} "
                    f"(wave_idx0={wave_idx0}, sched={sched}) — lanes are "
                    f"conflict-free by construction, this is a planner bug")
    merged = _merge_rows(wave, outs, rows, n_real)
    exec_tid = np.zeros(len(np.asarray(wave.tid)), np.int32)
    for l, g in enumerate(rows):
        if len(g):
            exec_tid[g] = stacked.tid[l, :len(g)]
    pw = PlannedWave(merged=merged, exec_tid=exec_tid, plan=plan,
                     stacked=stacked, outs=outs, waves_consumed=L,
                     tids_consumed=L * T_pad, lane_waves=plan.n_lanes,
                     spill_waves=1 if plan.n_spilled else 0)
    return store, clock, pw


class PlanRunStats(NamedTuple):
    """``RunStats`` superset for the planned replay driver (duck-compatible
    with the engine's: same leading fields)."""
    committed: int
    aborted: int
    msgs_cross: int
    msgs_coord: int
    waits: int
    evicted_visible: int
    waves: int                # source waves (history length)
    dispatched_waves: int     # lane + spill waves actually executed
    lane_waves: int
    spilled_txns: int
    max_lanes_seen: int       # deepest conflict chain over the run
    plan_s: float             # host-side planning + packing seconds


def run_workload_planned(store, waves, sched: str = "postsi",
                         n_nodes: int = 8, mesh=None, kernels=None,
                         host_skew=None, gc_track: bool = False,
                         gc_block: bool = False,
                         max_lanes: Optional[int] = None, placement=None):
    """Replay driver for the planned scheduler (mirror of
    ``engine.run_workload``): plans and executes each wave in order.

    Returns ``(store, history, stats)``.  History rows carry the *input*
    waves' tids aligned with the merged outcomes, so commit-set comparisons
    against the optimistic drivers and the sequential oracle are row-exact;
    the verifiers only consult CIDs, which are the executed ones.  Default
    ``max_lanes=None`` never spills — every transaction commits."""
    clock = jnp.int32(1)
    wave_idx0 = 1
    next_tid = 1 + max(int(np.asarray(w.tid).max()) for w in waves) \
        if waves else 1
    history = []
    dispatched = lane_waves = spilled = deepest = 0
    plan_s = 0.0
    for wave in waves:
        t0 = time.perf_counter()
        store, clock, pw = run_wave_planned(
            store, wave, clock, wave_idx0=wave_idx0, next_tid=next_tid,
            sched=sched, n_nodes=n_nodes, mesh=mesh, kernels=kernels,
            host_skew=host_skew, gc_track=gc_track, gc_block=gc_block,
            max_lanes=max_lanes, placement=placement)
        plan_s += time.perf_counter() - t0
        wave_idx0 += pw.waves_consumed
        next_tid += pw.tids_consumed
        dispatched += pw.lane_waves + pw.spill_waves
        lane_waves += pw.lane_waves
        spilled += pw.plan.n_spilled
        deepest = max(deepest, pw.plan.n_lanes)
        history.append((np.asarray(wave.tid), pw.merged))
    rs = _stats_of(history)
    return store, history, PlanRunStats(
        **rs._asdict(), dispatched_waves=dispatched, lane_waves=lane_waves,
        spilled_txns=spilled, max_lanes_seen=deepest,
        plan_s=round(plan_s, 6))


def run_workload_any(store, waves, sched: str, **kw):
    """Registry dispatch over all seven schedulers: the six optimistic ones
    go through the fused replay driver, ``"planned"`` through the planner
    (``base_sched=`` selects its lane adjudicator, default postsi)."""
    if sched == PLANNED:
        base = kw.pop("base_sched", "postsi")
        return run_workload_planned(store, waves, sched=base, **kw)
    if sched not in SCHEDULERS:
        raise ValueError(f"unknown scheduler {sched!r}; "
                         f"registry: {ALL_SCHEDULERS}")
    from repro.core.engine import run_workload_fused
    kw.pop("max_lanes", None)
    return run_workload_fused(store, waves, sched=sched, **kw)
