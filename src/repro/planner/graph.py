"""Per-wave conflict graph from the formed ``[T, O]`` op arrays (DESIGN.md
§10).

The wave former (and every replay generator) already holds each
transaction's full read/write set on the host *before* dispatch — the
``op_kind``/``op_key`` arrays are the declared footprint, not an estimate.
That makes the BOHM/DGCC move available to the wave engine: build the
intra-wave conflict graph up front and plan execution so conflicts never
meet inside one wave.

Edges (undirected in ``conflict``, directed views kept for the planner):

* WW — both transactions write some common key;
* RW — transaction *i* reads a key transaction *j* writes (the engine's
  anti-dependency ``potential[i, j]``, here over declared sets);
* WR — transaction *i* writes a key transaction *j* reads (``rw.T``).

READ contributes to the read side, WRITE to the write side, RMW to both.
NOP slots (padding, deduped duplicate keys) touch nothing: the masks route
them to distinct sentinels that can never collide with a real key (or with
each other), so an all-NOP padding row is an isolated vertex.

Two constructions, same output:

* ``dense`` — one broadcast compare over ``[T, T, O, O]``; this is the
  vectorized-numpy path and the default for service-sized waves (T ≤ a few
  hundred, O ≤ 16 ⇒ the intermediate is a few MB of bool);
* ``grouped`` — sort ops by key and emit cliques per contended key; memory
  is O(T² + total ops) regardless of O, used automatically when the dense
  intermediate would exceed ``_DENSE_LIMIT`` elements.

Both are pure host-side numpy on the formed arrays — nothing here touches
the device.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.commit_phase import NOP, READ, RMW, WRITE

# largest [T, T, O, O] bool intermediate the dense path may allocate (64 MB)
_DENSE_LIMIT = 1 << 26

# sentinel for masked (non-reading) op slots; real keys are >= 0.  Masked
# *write* slots get a unique negative sentinel per (txn, slot) instead —
# the WW compare puts write keys on both sides, so a shared sentinel would
# match itself across transactions and fabricate conflicts
_NO_READ = -1


class ConflictGraph(NamedTuple):
    """Boolean [T, T] adjacency views of one wave's conflicts.

    ``rw[i, j]`` — i reads a key j writes (anti-dependency, the declared
    twin of the engine's ``potential``); ``ww[i, j]`` — i and j write a
    common key (symmetric); ``conflict`` — any of WW/WR/RW, symmetric,
    diagonal clear.  ``active[t]`` — row t has at least one non-NOP op."""
    rw: np.ndarray
    ww: np.ndarray
    conflict: np.ndarray
    active: np.ndarray

    @property
    def wr(self) -> np.ndarray:
        """``wr[i, j]`` — i writes a key j reads (= ``rw.T``)."""
        return self.rw.T


def op_masks(op_kind: np.ndarray):
    """(reads, writes) boolean masks over ``[T, O]`` op slots: READ and RMW
    read; WRITE and RMW write; NOP does neither."""
    op_kind = np.asarray(op_kind)
    is_read = (op_kind == READ) | (op_kind == RMW)
    is_write = (op_kind == WRITE) | (op_kind == RMW)
    return is_read, is_write


def _edges_dense(rk: np.ndarray, wk: np.ndarray):
    """One broadcast compare: rw[i, j] = any read key of i equals any write
    key of j; ww likewise over write keys.  Sentinels never match."""
    rw = (rk[:, None, :, None] == wk[None, :, None, :]).any(axis=(2, 3))
    ww = (wk[:, None, :, None] == wk[None, :, None, :]).any(axis=(2, 3))
    return rw, ww


def _edges_grouped(rk: np.ndarray, wk: np.ndarray):
    """Key-grouped construction: for every key touched by >1 transaction,
    mark reader×writer and writer×writer pairs.  The python loop runs only
    over *contended* keys (hot keys under zipf, hash collisions under
    uniform), each iteration vectorized via ``np.ix_``."""
    T = rk.shape[0]
    rw = np.zeros((T, T), bool)
    ww = np.zeros((T, T), bool)
    tt = np.broadcast_to(np.arange(T)[:, None], rk.shape)
    r_mask, w_mask = rk >= 0, wk >= 0
    keys = np.concatenate([rk[r_mask], wk[w_mask]])
    txns = np.concatenate([tt[r_mask], tt[w_mask]])
    is_w = np.concatenate([np.zeros(r_mask.sum(), bool),
                           np.ones(w_mask.sum(), bool)])
    order = np.argsort(keys, kind="stable")
    keys, txns, is_w = keys[order], txns[order], is_w[order]
    bounds = np.flatnonzero(np.diff(keys)) + 1
    for lo, hi in zip(np.r_[0, bounds], np.r_[bounds, len(keys)]):
        if hi - lo < 2:
            continue
        writers = np.unique(txns[lo:hi][is_w[lo:hi]])
        if not len(writers):
            continue
        readers = np.unique(txns[lo:hi][~is_w[lo:hi]])
        ww[np.ix_(writers, writers)] = True
        if len(readers):
            rw[np.ix_(readers, writers)] = True
    return rw, ww


def conflict_graph(op_kind: np.ndarray, op_key: np.ndarray,
                   method: str = "auto") -> ConflictGraph:
    """Build the wave's conflict graph from its declared op arrays.

    ``method``: ``"dense"`` (vectorized broadcast), ``"grouped"`` (sorted
    key groups, O-independent memory), or ``"auto"`` (dense unless the
    intermediate would exceed ~64 MB).  Both produce identical graphs
    (property-tested in tests/test_planner.py)."""
    op_kind = np.asarray(op_kind)
    op_key = np.asarray(op_key)
    if op_kind.ndim != 2 or op_kind.shape != op_key.shape:
        raise ValueError(f"need matching [T, O] arrays, got "
                         f"{op_kind.shape} / {op_key.shape}")
    T, O = op_kind.shape
    is_read, is_write = op_masks(op_kind)
    rk = np.where(is_read, op_key, _NO_READ)
    no_write = -(2 + np.arange(T * O, dtype=np.int64).reshape(T, O))
    wk = np.where(is_write, op_key, no_write)
    if method == "auto":
        method = "dense" if T * T * O * O <= _DENSE_LIMIT else "grouped"
    if method == "dense":
        rw, ww = _edges_dense(rk, wk)
    elif method == "grouped":
        rw, ww = _edges_grouped(rk, wk)
    else:
        raise ValueError(f"unknown method {method!r}")
    eye = np.eye(T, dtype=bool)
    rw &= ~eye          # a txn reading its own write key is not a conflict
    ww &= ~eye
    conflict = rw | rw.T | ww
    return ConflictGraph(rw=rw, ww=ww, conflict=conflict,
                         active=(op_kind != NOP).any(axis=1))


def footprint_nodes(op_kind: np.ndarray, op_key: np.ndarray,
                    owner: np.ndarray, n_nodes: int) -> np.ndarray:
    """Placement-aware node footprint of a wave: boolean ``[T, n_nodes]``
    where ``[t, n]`` means transaction ``t`` touches at least one key whose
    ring physically lives on node ``n`` under the given placement
    (``owner`` = ``PlacementMap.owner``, or any ``[n_keys]`` node vector).

    This is the planner/balancer's locality view: lanes whose union
    footprint stays on one node are candidates for node-local dispatch, and
    ``cross_node_frac`` below is the honest "how much of this wave is
    visitor traffic under the CURRENT placement" measure the bench reports
    next to the engine's logical ``msgs_cross``."""
    op_kind = np.asarray(op_kind)
    op_key = np.asarray(op_key)
    owner = np.asarray(owner)
    T = op_kind.shape[0]
    out = np.zeros((T, n_nodes), bool)
    active = op_kind != NOP
    valid = active & (op_key >= 0) & (op_key < owner.shape[0])
    t_idx, o_idx = np.nonzero(valid)
    out[t_idx, owner[op_key[t_idx, o_idx]]] = True
    return out


def cross_node_frac(op_kind: np.ndarray, op_key: np.ndarray,
                    owner: np.ndarray, n_nodes: int) -> float:
    """Fraction of active transactions whose footprint spans > 1 physical
    node under the given placement."""
    fp = footprint_nodes(op_kind, op_key, owner, n_nodes)
    spans = fp.sum(axis=1)
    active = spans > 0
    if not active.any():
        return 0.0
    return float((spans[active] > 1).mean())
