"""Deterministic conflict-graph planner plane (DESIGN.md §10).

The wave former holds every transaction's declared read/write set on the
host before dispatch, so each wave's conflict graph is knowable *before*
execution.  This package partitions waves into conflict-free lanes
(BOHM/DGCC-style deterministic planning) and executes them through the
unchanged engine — the seventh scheduler, ``"planned"``, which commits
abort-free on planned lanes under any skew.

    graph.py   [T,O] op arrays -> WW/WR/RW conflict graph      (numpy)
    lanes.py   graph -> conflict-free lanes + spill             (numpy)
    sched.py   lanes -> one pow2 wave block -> engine.run_block (device)
    hybrid.py  optimistic <-> planned switch for the service
"""
from .graph import ConflictGraph, conflict_graph, op_masks
from .hybrid import HybridSwitch
from .lanes import SPILLED, Plan, color_lanes, plan_wave
from .sched import (ALL_SCHEDULERS, DEFAULT_MAX_LANES, PLANNED, PlanRunStats,
                    PlannedWave, PlannerError, build_planned_block,
                    run_wave_planned, run_workload_any, run_workload_planned)

__all__ = [
    "ConflictGraph", "conflict_graph", "op_masks",
    "Plan", "SPILLED", "color_lanes", "plan_wave",
    "ALL_SCHEDULERS", "DEFAULT_MAX_LANES", "PLANNED", "PlanRunStats",
    "PlannedWave", "PlannerError", "build_planned_block",
    "run_wave_planned", "run_workload_any", "run_workload_planned",
    "HybridSwitch",
]
