"""Deterministic lane partitioning of one wave's conflict graph (DESIGN.md
§10).

A *lane* is a conflict-free subset of the wave: no two transactions in the
same lane share a WW/WR/RW edge.  Lanes execute sequentially (lane 0 first)
and each lane runs as one ordinary wave through ``engine.run_wave_on`` —
inside a lane the engine finds an empty potential matrix and untouched read
snapshots, so every lane transaction commits (the zero-abort argument in
sched.py).

The coloring is *layered greedy* in transaction (row) order:

    lane(j) = 0                          if j conflicts with no earlier txn
            = 1 + max lane(i)            over conflicting predecessors i < j

This is deterministic (pure function of the graph), and it orients every
conflict edge forward: if i < j conflict then lane(i) < lane(j), so the
pair executes in row order.  Conflicting pairs therefore serialize exactly
as the row (tid) order and non-conflicting pairs commute — planned
execution is conflict-equivalent to the sequential oracle replay
(core/seq.py), which is the topological intra-wave order dependency chains
need: a RAW chain of depth d lands in d consecutive lanes and each link
reads its predecessor's committed write.

``max_lanes`` bounds the budget: a transaction whose layer would reach it
is *spilled* instead — left out of every lane and executed afterwards as a
single ordinary optimistic wave, where the engine's CC rules adjudicate it
(it may abort and re-enter the service's retry path).  Spilling trades the
program-order guarantee for a bounded lane count: a laned transaction may
then commit before a spilled predecessor, which is still serializable
(every committed txn passes the engine's rules) but no longer equivalent to
row order.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

from .graph import ConflictGraph, conflict_graph

SPILLED = -1


class Plan(NamedTuple):
    """One wave's execution plan."""
    lane_of: np.ndarray               # [T] int32 lane index, SPILLED = spill
    lanes: Tuple[np.ndarray, ...]     # row indices per lane, ascending
    spill: np.ndarray                 # row indices spilled past the budget
    conflicted: int                   # txns with >= 1 conflict edge
    n_edges: int                      # undirected conflict edges in the wave

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)

    @property
    def n_spilled(self) -> int:
        return len(self.spill)


def color_lanes(graph: ConflictGraph,
                max_lanes: Optional[int] = None) -> Plan:
    """Partition a wave into conflict-free lanes by layered greedy coloring.

    Deterministic in row order; every row lands in exactly one lane or the
    spill set.  ``max_lanes=None`` never spills (lane count = 1 + longest
    conflict chain)."""
    conflict = graph.conflict
    T = conflict.shape[0]
    lane_of = np.zeros(T, np.int32)
    for j in range(T):
        preds = np.flatnonzero(conflict[j, :j])
        preds = preds[lane_of[preds] != SPILLED]
        lane = int(lane_of[preds].max()) + 1 if len(preds) else 0
        if max_lanes is not None and lane >= max_lanes:
            lane = SPILLED
        lane_of[j] = lane
    n_lanes = int(lane_of.max()) + 1 if (lane_of != SPILLED).any() else 0
    lanes = tuple(np.flatnonzero(lane_of == l) for l in range(n_lanes))
    return Plan(lane_of=lane_of, lanes=lanes,
                spill=np.flatnonzero(lane_of == SPILLED),
                conflicted=int(conflict.any(axis=1).sum()),
                n_edges=int(np.triu(conflict, 1).sum()))


def plan_wave(op_kind: np.ndarray, op_key: np.ndarray,
              max_lanes: Optional[int] = None,
              method: str = "auto") -> Plan:
    """Graph + coloring in one call: the planner front half on a formed
    wave's host-side op arrays."""
    return color_lanes(conflict_graph(op_kind, op_key, method=method),
                       max_lanes=max_lanes)
