"""Hybrid optimistic/planned switch policy (DESIGN.md §10).

The service plane is optimistic by default — under low contention that is
strictly cheaper (one dispatch per wave, no host-side planning).  Under
zipfian skew aborts rise and the optimistic loop burns its throughput on
retries; that trailing abort rate is exactly the signal
``AdaptiveWaveSizer`` already regulates wave size with, so the hybrid
policy rides the same ceiling: when the trailing abort rate crosses
``enter_high`` (default 0.35 — the sizer's AIMD high-water mark), the
service switches wave execution to the planner.

Exiting is *not* symmetric: in planned mode lanes commit abort-free, so
the abort rate is ~0 by construction and says nothing about whether the
workload calmed down.  The planner instead observes what it uniquely
knows — the *conflict fraction* of each wave it plans (transactions with
at least one conflict edge, plus anything spilled past the lane budget).
When that trailing fraction drops below ``exit_low``, contention has
genuinely subsided and the service returns to the optimistic path.

Both windows reset on every switch so decisions are made on post-switch
evidence only (the sizer's discipline).  Degenerate thresholds pin the
policy: ``exit_low < 0`` never exits planned mode (``from_name("planned")``
— plan every wave), ``enter_high > 1`` never enters it.
"""
from __future__ import annotations

from typing import Optional

from .sched import DEFAULT_MAX_LANES


class HybridSwitch:
    """Trailing-window two-signal switch between optimistic and planned
    wave execution.  Mutable; one instance per service session."""

    def __init__(self, enter_high: float = 0.35, exit_low: float = 0.10,
                 window: int = 64, max_lanes: Optional[int] = DEFAULT_MAX_LANES,
                 start_planned: bool = False):
        if window < 1:
            raise ValueError(f"need window >= 1, got {window}")
        self.enter_high = enter_high
        self.exit_low = exit_low
        self.window = window
        self.max_lanes = max_lanes
        self.planned = start_planned
        self._exec = 0          # optimistic window: executions / aborts
        self._abort = 0
        self._seen = 0          # planned window: planned txns / conflicted
        self._conf = 0
        self.to_planned = 0
        self.to_optimistic = 0

    @classmethod
    def from_name(cls, name: str, **kw) -> "HybridSwitch":
        """``"hybrid"`` — adaptive switching (defaults); ``"planned"`` —
        pinned planned mode (plan every wave, never exit)."""
        if name == "hybrid":
            return cls(**kw)
        if name == "planned":
            kw.setdefault("exit_low", -1.0)
            return cls(start_planned=True, **kw)
        raise ValueError(f"unknown planner mode {name!r}; "
                         f"expected 'hybrid' or 'planned'")

    @property
    def switches(self) -> int:
        return self.to_planned + self.to_optimistic

    def observe_optimistic(self, executed: int, aborted: int) -> None:
        """Fold one optimistically-executed wave's counts in; enter planned
        mode at a window boundary when the trailing abort rate crosses the
        AIMD ceiling."""
        if self.planned:
            return
        self._exec += executed
        self._abort += aborted
        if self._exec < self.window:
            return
        if self._abort / self._exec > self.enter_high:
            self.planned = True
            self.to_planned += 1
            self._seen = self._conf = 0
        self._exec = self._abort = 0

    def observe_planned(self, planned: int, conflicted: int) -> None:
        """Fold one planned wave's conflict census in (``conflicted`` =
        txns with >= 1 conflict edge + spilled); exit planned mode when the
        trailing conflict fraction falls below ``exit_low``."""
        if not self.planned:
            return
        self._seen += planned
        self._conf += conflicted
        if self._seen < self.window:
            return
        if self._conf / self._seen < self.exit_low:
            self.planned = False
            self.to_optimistic += 1
            self._exec = self._abort = 0
        self._seen = self._conf = 0
