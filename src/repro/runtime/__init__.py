from .faults import Fault, FaultSchedule, InjectedCrash
from .runner import TrainRunner, FailureInjector
from .straggler import StragglerPolicy
