from .runner import TrainRunner, FailureInjector
from .straggler import StragglerPolicy
