"""Fault-tolerant training runner.

Restart loop around the train step: checkpoint every N steps through the
PostSI store, catch (injected or real) failures, restore the last *visible*
snapshot — atomicity comes from the paper's scheduler, not from a manifest
lock — and resume with an exactly-replayed data cursor.

On a real cluster each restart may come up with a different device count;
``TrainRunner.run`` takes the sharding tree per (re)start, so elastic
shrink/grow is a restore with new shardings (checkpoint/reshard_tree).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint import PostSICheckpointer
from repro.data import TokenStream
from .straggler import StragglerPolicy


class FailureInjector:
    """Deterministic fault injection: raise at the given global steps."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class TrainRunner:
    step_fn: Callable                  # (params, opt, batch) -> (params, opt, metrics)
    stream: TokenStream
    checkpointer: PostSICheckpointer
    ckpt_every: int = 10
    max_restarts: int = 8
    straggler: Optional[StragglerPolicy] = None

    def run(self, params, opt_state, n_steps: int,
            injector: Optional[FailureInjector] = None,
            shardings=None) -> Dict[str, Any]:
        state = {"params": params, "opt": opt_state}
        losses = []
        restarts = 0
        step = 0
        while step < n_steps:
            try:
                while step < n_steps:
                    t0 = time.perf_counter()
                    if injector:
                        injector.maybe_fail(step)
                    batch = self.stream.next()
                    state["params"], state["opt"], metrics = self.step_fn(
                        state["params"], state["opt"], batch)
                    dt = time.perf_counter() - t0
                    if self.straggler:
                        self.straggler.record(step, dt)
                    losses.append(float(metrics["loss"]))
                    step += 1
                    if step % self.ckpt_every == 0:
                        self._save(step, state)
            except RuntimeError as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                step, state = self._restore(state, shardings)
        return {"losses": losses, "restarts": restarts, "final_step": step,
                "state": state}

    # ------------------------------------------------------------------
    def _save(self, step: int, state) -> None:
        tree = {"params": state["params"], "opt": state["opt"],
                "data": {"step": jax.numpy.asarray(self.stream.state()["step"])}}
        assert self.checkpointer.save(step, tree)

    def _restore(self, state, shardings):
        tree_ex = {"params": state["params"], "opt": state["opt"],
                   "data": {"step": jax.numpy.asarray(0)}}
        sh = None
        if shardings is not None:
            sh = {"params": shardings[0], "opt": shardings[1], "data": {"step": None}}
        step, tree = self.checkpointer.restore(tree_ex, None)
        if step is None:           # no checkpoint yet: restart from scratch
            self.stream.restore({"step": 0, "seed": self.stream.seed,
                                 "host_id": self.stream.host_id,
                                 "host_count": self.stream.host_count})
            return 0, state
        self.stream.restore({"step": int(tree["data"]["step"]),
                             "seed": self.stream.seed,
                             "host_id": self.stream.host_id,
                             "host_count": self.stream.host_count})
        return step, {"params": tree["params"], "opt": tree["opt"]}
