"""Straggler mitigation policy.

On a 1000+-node job the slowest participant sets the step time.  The policy
tracks a robust (median/MAD) step-time model per worker; when a worker's
step exceeds ``threshold`` MADs it is flagged and the runner can act:

  "flag"    — report only (default; feeds the ops dashboard)
  "skip"    — drop the straggler's microbatch this step and rescale the
              gradient (bounded-staleness data parallelism); the scale
              factor keeps the update unbiased
  "rebalance" — shrink the straggler's assigned microbatch share

The wave-structured PostSI engine gets the same treatment for free: a wave
deadline simply truncates the wave, and unexecuted transactions carry to the
next wave (no partial effects exist before commit — paper §IV-C).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

import numpy as np


class StragglerPolicy:
    def __init__(self, window: int = 32, threshold: float = 4.0,
                 action: str = "flag"):
        assert action in ("flag", "skip", "rebalance")
        self.window = window
        self.threshold = threshold
        self.action = action
        self.times: Dict[int, collections.deque] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self.flags: List[Tuple[int, int, float]] = []   # (step, worker, dt)

    def record(self, step: int, dt: float, worker: int = 0) -> bool:
        """Returns True when (step, worker) is flagged as a straggler."""
        hist = self.times[worker]
        flagged = False
        if len(hist) >= 8:
            med = float(np.median(hist))
            mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
            if dt > med + self.threshold * mad * 1.4826:
                self.flags.append((step, worker, dt))
                flagged = True
        hist.append(dt)
        return flagged

    def grad_scale(self, n_workers: int, n_skipped: int) -> float:
        """Unbiased rescale when ``skip`` drops straggler microbatches."""
        live = max(n_workers - n_skipped, 1)
        return n_workers / live

    def share(self, worker: int, n_workers: int) -> float:
        """Microbatch share under ``rebalance``: inverse mean step time."""
        if not self.times:
            return 1.0 / n_workers
        means = {w: float(np.mean(h)) for w, h in self.times.items() if h}
        if worker not in means:
            return 1.0 / n_workers
        inv = {w: 1.0 / m for w, m in means.items()}
        z = sum(inv.values())
        return inv[worker] / z
