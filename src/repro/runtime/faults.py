"""Deterministic fault injection at the service/substrate seams
(DESIGN.md §9).

Generalizes the dormant straggler machinery (``runtime/straggler.py``
*detects* slow workers after the fact; this module *injects* the failures
it watches for) into seedable failure schedules the recovery conformance
suite replays exactly:

* ``kill`` — raise ``InjectedCrash`` at a seam: the process dies with
  dispatched-but-unretired blocks in flight and the group-commit buffer
  unsynced (``DurabilityManager.crash`` then models the page-cache loss);
* ``drop_node`` — the mesh flavor of ``kill``: the SPMD program dies with
  the node, recovery replays onto a *fresh* mesh of the same arity (the
  replacement-node story — per-node state is reconstructed from the log,
  never from the lost device);
* ``torn_tail`` — after the crash, tear ``arg`` bytes off the WAL's end
  (a partial final write); applied by ``mutilate_wal``, absorbed by
  ``wal.scan``;
* ``delay_retire`` — arm a budget of ``arg`` skipped tick-level
  retirements: the pipeline holds its oldest block ``arg`` extra ticks,
  the injection twin of the straggler the detector flags.  Consumed only
  at tick-level retires, never inside the dispatch loop's K-limit drain,
  so a delay can starve progress but never deadlock it.

Seams (counted independently, so ``Fault.at`` is "the n-th visit"):

* ``dispatch`` — after a block's device dispatch, before it is recorded
  in flight (kill here: work launched, nothing durable, replay-or-drop);
* ``retire``   — at the head of block retirement, before the host sync
  (kill here: outcomes computed, never logged nor acked);
* ``post_log`` — after the WAL append, before outcomes are acked to
  clients (kill here opens the durable-but-unacked window — recovery must
  treat "in recovered WAL" as committed and never re-execute it).
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence

import numpy as np


class InjectedCrash(RuntimeError):
    """A scheduled fault killed the process at a seam.  Harnesses catch
    this where a supervisor would observe the death, then run recovery."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled failure.

    ``kind``  — kill | drop_node | torn_tail | delay_retire.
    ``point`` — dispatch | retire | post_log (seam; torn_tail uses the
    pseudo-point "wal": it fires after death, not at a seam).
    ``at``    — fire on the ``at``-th visit of that seam (0-based).
    ``arg``   — torn bytes (torn_tail) or delay budget in ticks
    (delay_retire); unused otherwise.
    """
    kind: str
    point: str
    at: int
    arg: int = 0

    KINDS = ("kill", "drop_node", "torn_tail", "delay_retire")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultSchedule:
    """A deterministic list of faults, fired by seam-visit count.

    The service calls the seam hooks; each counts its visits and fires
    every fault scheduled for (point, count).  The same schedule against
    the same workload fails at exactly the same block every run — that is
    what makes crash-restart tests differential.
    """

    POINTS = ("dispatch", "retire", "post_log")

    def __init__(self, faults: Sequence[Fault] = (),
                 seed: Optional[int] = None):
        self.faults: List[Fault] = list(faults)
        self.seed = seed
        self.counts = dict.fromkeys(self.POINTS, 0)
        self.fired: List[Fault] = []
        self.crashed: Optional[Fault] = None
        self._delay_left = 0
        self.delays_taken = 0

    # ------------------------------------------------------------- seams
    def at_dispatch(self, svc=None) -> None:
        self._visit("dispatch")

    def at_retire(self, svc=None) -> None:
        self._visit("retire")

    def post_log(self, svc=None) -> None:
        self._visit("post_log")

    def _visit(self, point: str) -> None:
        n = self.counts[point]
        self.counts[point] += 1
        for f in self.faults:
            if f.point != point or f.at != n or f in self.fired:
                continue
            self.fired.append(f)
            if f.kind in ("kill", "drop_node"):
                self.crashed = f
                raise InjectedCrash(f"{f.kind} at {point}#{n}")
            if f.kind == "delay_retire":
                self._delay_left += max(0, f.arg)

    def delay_retire(self, svc=None) -> bool:
        """True while armed delay budget remains (the caller skips one
        tick-level retirement per True).  Finite by construction."""
        if self._delay_left > 0:
            self._delay_left -= 1
            self.delays_taken += 1
            return True
        return False

    # ----------------------------------------------------------- aftermath
    def mutilate_wal(self, path: str, synced_bytes: int = 0):
        """Apply every scheduled ``torn_tail`` to the dead process's WAL
        file — the partial final write a real crash leaves.  Call between
        the crash and recovery, passing the writer's fsync barrier
        (``DurabilityManager.crash_synced_bytes``): a tear may only eat
        the at-risk suffix written after the last fsync, never fsynced
        records — fsync is a durability barrier, and with ``fsync_every=1``
        nothing is ever at risk.  ``synced_bytes=0`` (standalone use)
        puts the whole file at risk.  Returns bytes actually torn."""
        from repro.durability import wal
        torn = 0
        for f in self.faults:
            if f.kind != "torn_tail":
                continue
            at_risk = max(0, (os.path.getsize(path) if os.path.exists(path)
                              else 0) - synced_bytes)
            torn += wal.torn_tail(path, min(f.arg, at_risk))
        return torn

    @property
    def pure_kill(self) -> bool:
        """True when no fault perturbs pre-crash execution timing (kills
        and torn tails only).  For pure-kill schedules the crashed run's
        WAL is a bit-identical *prefix* of the uninterrupted run's —
        delays reorder retry traffic, which is allowed but breaks the
        prefix property (not the conformance one)."""
        return all(f.kind in ("kill", "drop_node", "torn_tail")
                   for f in self.faults)

    # --------------------------------------------------------- generation
    @classmethod
    def random(cls, seed: int, horizon: int = 10,
               allow_delay: bool = True) -> "FaultSchedule":
        """A seed-deterministic schedule: one terminal kill at a random
        seam within ``horizon`` visits, optionally preceded by a retire
        delay, optionally followed by a torn WAL tail."""
        rng = np.random.RandomState(seed)
        faults: List[Fault] = []
        if allow_delay and rng.rand() < 0.4:
            faults.append(Fault("delay_retire", "retire",
                                int(rng.randint(0, max(1, horizon // 2))),
                                arg=int(rng.randint(1, 4))))
        point = cls.POINTS[int(rng.randint(len(cls.POINTS)))]
        faults.append(Fault("kill", point, int(rng.randint(1, horizon))))
        if rng.rand() < 0.5:
            faults.append(Fault("torn_tail", "wal", 0,
                                arg=int(rng.randint(1, 96))))
        return cls(faults, seed=seed)
