"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single-pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis crosses the
DCN boundary and carries only data-parallel gradient reduction.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under dryrun.py which "
            "sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:n])
    except TypeError:  # older signature without devices kwarg
        arr = np.array(devs[:n]).reshape(shape)
        return Mesh(arr, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CI-scale distribution tests (8 host devices)."""
    n = int(np.prod(shape))
    devs = jax.devices()[:n]
    arr = np.array(devs).reshape(shape)
    return Mesh(arr, axes)


def make_cc_node_mesh(n_nodes: int = 8) -> Mesh:
    """1-D ``("node",)`` mesh for the concurrency-control data plane — the
    launch-layer name for ``dist_engine.make_node_mesh`` (lazy import so
    this module keeps touching no jax device state at import time).  Pair
    with a ``PlacementMap(n_keys, n_nodes)`` for the elastic layout
    (DESIGN.md §11) or pass ``placement=None`` for the frozen blocks."""
    from repro.core.dist_engine import make_node_mesh
    return make_node_mesh(n_nodes)
