"""Divisibility-aware sharding rules for inputs, params and caches.

Baseline layout (see DESIGN.md §6):
  batch dims        -> ("pod", "data")     (pure DP across pods, FSDP inside)
  weight embed dim  -> "data"              (FSDP; gathered per layer in scan)
  heads/kv/mlp/vocab/experts/inner -> "model"  (TP / EP)
  KV-cache kv-head dim -> "model", batch dim -> ("pod","data")

Every assignment is guarded by divisibility: a dim that does not divide the
mesh axis stays unsharded (GSPMD handles the remainder) — this is what makes
all 40 (arch x shape) cells compile on the fixed production meshes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import param_pspecs, param_shardings  # re-export


def _size(mesh: Mesh, axes) -> int:
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= d[a]
        return n
    return d[axes]


def _fit(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """Return axes (possibly shrunk) if dim divides their product, else None."""
    if axes is None:
        return None
    cand = tuple(a for a in (axes if isinstance(axes, tuple) else (axes,))
                 if a in mesh.axis_names)
    while cand:
        if dim % _size(mesh, cand) == 0 and dim > 0:
            return cand if len(cand) > 1 else cand[0]
        cand = cand[1:]          # drop the leading ("pod") axis and retry
    return None


DP = ("pod", "data")

# serve-time parameter rules: no FSDP (there are no optimizer states to
# amortize per-layer gathers against) — weights shard over "model" only and
# replicate over "data", so decode/prefill steps have zero weight gathers
SERVE_RULES = {
    "embed": (),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "inner": ("model",),
    "state": (), "head_dim": (), "layers": (), "conv": (), "qkv": (),
}


def _leaf_pspec(name: str, shape: Tuple[int, ...], mesh: Mesh,
                seq_shard_kv: bool = False) -> P:
    """Input-tree leaf -> PartitionSpec, keyed by the leaf's dict name."""
    if name in ("tokens", "labels", "token"):
        return P(_fit(mesh, shape[0], DP), None)
    if name == "positions":
        return P(_fit(mesh, shape[0], DP), None, None)
    if name == "enc_embeds":
        return P(_fit(mesh, shape[0], DP), None, None)
    if name in ("k", "v", "ck", "cv"):           # [L, B, S, KH, Dh]
        head_fit = _fit(mesh, shape[3], "model")
        if seq_shard_kv or head_fit is None:      # flash-decoding layout:
            # shard the cache seq dim when kv heads don't divide the TP axis
            return P(None, _fit(mesh, shape[1], DP), _fit(mesh, shape[2], "model"),
                     None, None)
        return P(None, _fit(mesh, shape[1], DP), None, head_fit, None)
    if name == "ssm":                             # [L, B, H, P, N]
        return P(None, _fit(mesh, shape[1], DP), _fit(mesh, shape[2], "model"),
                 None, None)
    if name == "conv":                            # [L, B, K-1, C]
        return P(None, _fit(mesh, shape[1], DP), None,
                 _fit(mesh, shape[3], "model"))
    if name == "len":
        return P()
    # fallback: shard leading dim over DP when divisible
    return P(_fit(mesh, shape[0], DP), *([None] * (len(shape) - 1)))


def version_store_pspec() -> P:
    """PartitionSpec of every MVStore leaf on the CC node mesh: rows (one
    per physical key slot) shard over the 1-D ``"node"`` axis, trailing
    dims (the version ring) stay local.  ``dist_engine.shard_store`` pads
    the row count to a multiple of the mesh so this spec always divides —
    including elastic ``PlacementMap`` layouts, whose ``n_slots`` is
    ``capacity * n_nodes`` by construction."""
    return P("node")


def input_shardings(tree, mesh: Mesh, seq_shard_kv: bool = False):
    """Same-structure tree of NamedShardings for a batch/cache dict."""
    def walk(name, node):
        if isinstance(node, dict):
            return {k: walk(k, v) for k, v in node.items()}
        shape = node.shape
        return NamedSharding(mesh, _leaf_pspec(name, shape, mesh, seq_shard_kv))
    return {k: walk(k, v) for k, v in tree.items()}
