import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

import argparse
import json
import re
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import input_shardings
from repro.launch.train import (abstract_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models.module import (abstract, param_shardings, use_mesh_and_rules)
from repro.optim import adamw_init

# TPU v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\S+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<rest>[^\n]*)")
_ARR_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Sum per-device collective traffic from the post-SPMD HLO.

    Shapes in the SPMD module are per-device; traffic model per op:
      all-gather         -> result bytes           (each chip receives ~full)
      all-reduce         -> 2 x result bytes       (ring: reduce + broadcast)
      reduce-scatter     -> result bytes x group   (full operand traverses)
      all-to-all         -> result bytes
      collective-permute -> result bytes
    """
    per_type_bytes: Dict[str, int] = {}
    per_type_count: Dict[str, int] = {}
    top: list = []
    total = 0
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        b = _shape_bytes(m.group("shape"))
        if op == "all-reduce":
            traffic = 2 * b
        elif op == "reduce-scatter":
            traffic = b * _group_size(m.group("rest"), n_devices)
        else:
            traffic = b
        per_type_bytes[op] = per_type_bytes.get(op, 0) + traffic
        per_type_count[op] = per_type_count.get(op, 0) + 1
        total += traffic
        top.append((traffic, op, m.group("shape")[:80]))
    top.sort(reverse=True)
    return {
        "collective_bytes_per_device": total,
        "per_type_bytes": per_type_bytes,
        "per_type_count": per_type_count,
        "top_ops": [{"bytes": t, "op": o, "shape": s} for t, o, s in top[:12]],
    }


def _memory_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                out[f] = int(v)
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active params."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: 1 token/seq


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             seq_shard_kv: bool = False, remat: str | None = None,
             rules=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(cfg.family, shape_name):
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": f"long_500k not applicable to family={cfg.family} "
                           "(full attention; see DESIGN.md §5)"}
    if remat:
        cfg = cfg.replace(remat_policy=remat)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()

    with use_mesh_and_rules(mesh, rules):
        if shape.kind == "train":
            model, params, opt = abstract_train_state(cfg)
            _, step = make_train_step(cfg)
            p_sh = param_shardings(model.param_specs(), mesh, rules)
            o_sh = jax.eval_shape(adamw_init, params)
            o_sh = jax.tree_util.tree_map(lambda _: None, o_sh)
            from repro.optim.adamw import AdamWState
            o_sh = AdamWState(
                step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                m=p_sh, v=p_sh)
            batch = input_specs(cfg, shape)[0]
            b_sh = input_shardings(batch, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            from repro.launch.sharding import SERVE_RULES
            rules = rules or SERVE_RULES
            scfg = cfg.replace(param_dtype=jnp.bfloat16)
            model, pstep = make_prefill_step(scfg)
            params = abstract(model.param_specs())
            p_sh = param_shardings(model.param_specs(), mesh, rules)
            batch = input_specs(scfg, shape)[0]
            b_sh = input_shardings(batch, mesh)
            jitted = jax.jit(pstep, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            from repro.launch.sharding import SERVE_RULES
            rules = rules or SERVE_RULES
            model_axis = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
            kv_seq_sharded = seq_shard_kv or (
                cfg.n_kv_heads % model_axis != 0 and cfg.family != "ssm")
            scfg = cfg.replace(param_dtype=jnp.bfloat16,
                               decode_seq_shard=kv_seq_sharded)
            model, dstep = make_decode_step(scfg)
            params = abstract(model.param_specs())
            p_sh = param_shardings(model.param_specs(), mesh, rules)
            batch, cache = input_specs(scfg, shape)
            b_sh = input_shardings(batch, mesh)
            c_sh = input_shardings(cache, mesh, seq_shard_kv=seq_shard_kv)
            jitted = jax.jit(dstep, in_shardings=(p_sh, c_sh, b_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, cache, batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _memory_analysis(compiled)
    cost = _cost_analysis(compiled)
    txt = compiled.as_text()
    coll = parse_collectives(txt, n_dev)          # loop-body-once (for reference)
    from repro.launch.hlo_analysis import analyze
    hlo = analyze(txt, n_dev)                     # with loop trip multipliers
    del txt

    flops = hlo["flops"]
    bytes_acc = hlo["bytes"]
    mf = model_flops(cfg, shape)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = hlo["collective_bytes"] / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": cost, "collectives": coll,
        "hlo": hlo,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_dev,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "useful_flops_frac": (mf / n_dev) / flops if flops else None,
        },
        "options": {"seq_shard_kv": seq_shard_kv, "remat": remat},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--seq-shard-kv", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.outdir, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                name = f"{arch}__{shape}__{mesh_tag}{args.tag}"
                path = os.path.join(args.outdir, name + ".json")
                if os.path.exists(path):
                    print(f"[skip] {name} (exists)")
                    continue
                print(f"[cell] {name} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp,
                                   seq_shard_kv=args.seq_shard_kv,
                                   remat=args.remat)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                           "error": repr(e)[:2000]}
                    print(f"  ERROR: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if "roofline" in rec:
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s dominant={r['dominant']}"
                          f" c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s"
                          f" coll={r['collective_s']:.4f}s", flush=True)
                elif "skipped" in rec:
                    print(f"  skipped: {rec['skipped']}", flush=True)


if __name__ == "__main__":
    main()
