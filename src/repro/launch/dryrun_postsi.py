import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must be first (see dryrun.py).

"""Dry-run of the paper's OWN technique on the production mesh: one PostSI
wave (shard_map over 256 "node" shards, peer collectives only) lowered and
compiled for 256 devices, with the same roofline record as the LM cells.

  PYTHONPATH=src python -m repro.launch.dryrun_postsi [--nodes 256]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dist_engine import dist_wave_traceable, make_node_mesh, shard_store
from repro.core.workloads import micro_waves
from repro.core.store import make_store
from repro.launch.dryrun import (ICI_BW, PEAK_FLOPS, HBM_BW, _memory_analysis,
                                 parse_collectives)
from repro.launch.hlo_analysis import analyze


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=256)
    ap.add_argument("--keys-per-node", type=int, default=65536)
    ap.add_argument("--txns", type=int, default=2048)
    ap.add_argument("--ops", type=int, default=8)
    ap.add_argument("--out", default="experiments/dryrun_final/postsi-db__wave__16x16.json")
    args = ap.parse_args()

    mesh = make_node_mesh(args.nodes)
    rng = np.random.RandomState(0)
    wave = micro_waves(rng, 1, args.txns, args.nodes, args.keys_per_node,
                       n_ops=args.ops, read_ratio=0.6, dist_frac=0.3)[0]

    store_abs = jax.eval_shape(lambda: make_store(args.nodes * args.keys_per_node, 8))
    t0 = time.time()

    wave_fn = dist_wave_traceable(mesh, sched="postsi")

    def step(val, tid, cid, sid, head, wv, ok, okey, oval, host, tids):
        from repro.core.store import MVStore
        st = MVStore(val, tid, cid, sid, head, wv)
        from repro.core.engine import Wave
        w = Wave(ok, okey, oval, host, tids)
        st2, out, _ = wave_fn(st, w, jnp.int32(1), jnp.int32(1), args.nodes)
        return st2.val, st2.cid, out.status, out.s, out.c

    from jax.sharding import NamedSharding, PartitionSpec as P
    sh_store = NamedSharding(mesh, P("node"))
    sh_rep = NamedSharding(mesh, P())
    abs_in = [jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh_store)
              for a in store_abs]
    wave_abs = [jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh_rep)
                for a in wave]
    lowered = jax.jit(step).lower(*abs_in, *wave_abs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    txt = compiled.as_text()
    hlo = analyze(txt, args.nodes)
    coll = parse_collectives(txt, args.nodes)
    mem = _memory_analysis(compiled)

    rec = {
        "arch": "postsi-db", "shape": f"wave_T{args.txns}_O{args.ops}",
        "mesh": "16x16(node)", "n_devices": args.nodes,
        "kind": "txn-wave",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "hlo": hlo, "collectives": coll,
        "roofline": {
            "compute_s": hlo["flops"] / PEAK_FLOPS,
            "memory_s": hlo["bytes"] / HBM_BW,
            "collective_s": hlo["collective_bytes"] / ICI_BW,
            "dominant": max(
                (("compute", hlo["flops"] / PEAK_FLOPS),
                 ("memory", hlo["bytes"] / HBM_BW),
                 ("collective", hlo["collective_bytes"] / ICI_BW)),
                key=lambda kv: kv[1])[0],
            "useful_flops_frac": None,
        },
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec["roofline"]
    print(f"postsi-db wave on {args.nodes} nodes: compile={t_compile:.1f}s "
          f"dominant={r['dominant']} c={r['compute_s']:.4f}s "
          f"m={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
          f"({args.txns} txns x {args.ops} ops, "
          f"{args.nodes * args.keys_per_node / 1e6:.0f}M keys)")


if __name__ == "__main__":
    main()
