"""Train / prefill / decode step builders.

``make_*_step`` return plain functions over (params, opt_state, batch) pytrees
— jit/lower/compile is the caller's business (see dryrun.py and
examples/train_lm.py), so the same step serves the 1-device smoke path and the
512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import build
from repro.optim import adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, lr: float = 3e-4, weight_decay: float = 0.1):
    model = build(cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params2, opt2, gnorm = adamw_update(params, grads, opt_state, lr,
                                            weight_decay=weight_decay)
        out = {"loss": loss, "gnorm": gnorm, **metrics}
        return params2, opt2, out

    return model, train_step


def make_prefill_step(cfg: ModelConfig):
    model = build(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return model, prefill_step


def make_decode_step(cfg: ModelConfig):
    model = build(cfg)

    def decode_step(params, cache, batch):
        logits, cache2 = model.decode(params, cache, batch)
        # greedy next token (serving harness feeds it back)
        nxt = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return nxt, cache2

    return model, decode_step


def abstract_train_state(cfg: ModelConfig):
    """(params, opt_state) as ShapeDtypeStructs — for .lower() without
    allocating 33B parameters on the host."""
    model = build(cfg)
    specs = model.param_specs()
    from repro.models.module import abstract
    params = abstract(specs)
    opt = jax.eval_shape(adamw_init, params)
    return model, params, opt
