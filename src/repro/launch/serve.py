"""Batched serving driver with PostSI-versioned live weight publishing.

A light continuous-batching server: requests are grouped into fixed-size
batches, prefilled once and decoded step-by-step. Weight versions live in a
PostSI store (one key per parameter leaf); every batch is a reader
transaction, every publish a writer transaction — Consistent Visibility
guarantees a batch never mixes two weight versions (torn weights), with no
version counter or lock (DESIGN.md §3.2).

This is the single-host driver; on a pod the same step functions are jitted
with the serve-time shardings (launch/sharding.SERVE_RULES), as exercised by
the decode/prefill dry-run cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.seq import SeqScheduler
from repro.models.config import ModelConfig
from repro.models.model import build

from .train import make_decode_step, make_prefill_step


@dataclasses.dataclass
class ServeStats:
    batches: int = 0
    tokens: int = 0
    publishes: int = 0
    versions_served: List[int] = dataclasses.field(default_factory=list)


class Server:
    def __init__(self, cfg: ModelConfig, params, batch_size: int = 4,
                 cache_margin: int = 128):
        self.cfg = cfg
        self.batch_size = batch_size
        self.cache_margin = cache_margin
        self.model, prefill = make_prefill_step(cfg)
        _, decode = make_decode_step(cfg)
        self.prefill = jax.jit(prefill)
        self.decode = jax.jit(decode, donate_argnums=(1,))
        # versioned weight store: one key per leaf
        self._versions = [params]
        n_leaves = len(jax.tree_util.tree_leaves(params))
        self._sched = SeqScheduler(n_leaves, mode="postsi")
        self._n_leaves = n_leaves
        t = self._sched.begin()
        for k in range(n_leaves):
            self._sched.write(t, k, 0)
        assert self._sched.commit(t)
        self.stats = ServeStats()

    # ------------------------------------------------------------- weights
    def publish(self, params) -> bool:
        """Writer transaction: install a new weight version atomically."""
        self._versions.append(params)
        vid = len(self._versions) - 1
        t = self._sched.begin()
        for k in range(self._n_leaves):
            self._sched.write(t, k, vid)
        ok = self._sched.commit(t)
        if ok:
            self.stats.publishes += 1
        return ok

    def _snapshot(self):
        """Reader transaction: an atomic weight version for one batch."""
        t = self._sched.begin()
        vids = {self._sched.read(t, k) for k in range(self._n_leaves)}
        assert self._sched.commit(t)
        assert len(vids) == 1, f"torn weight versions: {vids}"
        vid = vids.pop()
        return vid, self._versions[vid]

    # ------------------------------------------------------------- serving
    def serve_batch(self, tokens: np.ndarray, max_new_tokens: int = 8,
                    enc_embeds: Optional[np.ndarray] = None) -> Dict:
        """tokens: [B, S] int32 prompt batch -> dict with generated ids."""
        B, S = tokens.shape
        assert B == self.batch_size
        vid, params = self._snapshot()
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.cfg.mrope:
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3))
            batch["positions"] = jnp.asarray(pos.astype(np.int32))
        if self.cfg.family == "encdec":
            batch["enc_embeds"] = jnp.asarray(enc_embeds, jnp.float32)
        logits, cache = self.prefill(params, batch)
        # room for the new tokens
        for kk in ("k", "v"):
            if kk in cache:
                pad = jnp.zeros(cache[kk].shape[:2] + (self.cache_margin,)
                                + cache[kk].shape[3:], cache[kk].dtype)
                cache[kk] = jnp.concatenate([cache[kk], pad], axis=2)
        tok = jnp.argmax(logits[..., : self.cfg.vocab_size], -1).astype(jnp.int32)
        out = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            tok, cache = self.decode(params, cache, {"token": tok})
            out.append(np.asarray(tok))
        gen = np.concatenate(out, axis=1)
        self.stats.batches += 1
        self.stats.tokens += int(gen.size)
        self.stats.versions_served.append(vid)
        return {"generated": gen, "weight_version": vid}
