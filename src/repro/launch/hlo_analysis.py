"""Post-SPMD HLO text analyzer with loop trip-count multipliers.

``compiled.cost_analysis()`` on the CPU backend counts a ``while`` body
*once*, so a 62-layer scanned transformer reports ~1 layer of FLOPs.  This
module walks the HLO computation graph bottom-up instead:

  flops        — 2 * prod(result) * prod(lhs contracting dims) per dot,
  bytes        — operand + result bytes of every top-level op in each
                 computation (fusion internals excluded: a fusion's operands/
                 result approximate its HBM traffic on TPU),
  collectives  — per-type traffic with ring/group factors (see dryrun),

each multiplied by the enclosing while-loop trip counts (parsed from the
loop-condition computations).  This is the per-device roofline numerator.
"""
from __future__ import annotations

import re
from typing import Dict, List, NamedTuple, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ARR_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*\S.*\{", re.M)
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$")
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "after-all", "iota", "reshape"}


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(shape_str: str) -> List[int]:
    m = _ARR_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Op(NamedTuple):
    name: str
    result: str      # result type string
    kind: str        # opcode
    rest: str        # operands + attributes (rest of line)


class Totals(NamedTuple):
    flops: float
    bytes: float
    coll_bytes: float
    coll_by_type: Dict[str, float]
    coll_count: Dict[str, int]


def parse_computations(txt: str) -> Tuple[Dict[str, List[Op]], Dict[str, Dict[str, str]]]:
    """Returns (ops per computation, result-type table per computation)."""
    comps: Dict[str, List[Op]] = {}
    types: Dict[str, Dict[str, str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in txt.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                types[cur] = {}
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            comps[cur].append(op)
            types[cur][op.name] = op.result
    comps["__entry__"] = comps.get(entry, [])
    types["__entry__"] = types.get(entry, {})
    return comps, types


def _dot_flops(op: Op, typemap: Dict[str, str]) -> float:
    out = _dims(op.result)
    # lhs type: inline if present, else look up the defining op's result type
    head = op.rest.split(")")[0]
    mo = _ARR_RE.search(head)
    if mo:
        lhs = [int(d) for d in mo.group(2).split(",") if d]
    else:
        names = re.findall(r"%([\w\.\-]+)", head)
        lhs = _dims(typemap.get(names[0], "")) if names else []
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    k = 1
    if mc and lhs:
        for i in mc.group(1).split(","):
            if i:
                k *= lhs[int(i)]
    n = 1
    for d in out:
        n *= d
    return 2.0 * n * k


def _conv_flops(op: Op) -> float:
    # rough: 2 * prod(result) * prod(kernel dims beyond batch)
    out = _dims(op.result)
    ops_shapes = _ARR_RE.findall(op.rest)
    if len(ops_shapes) < 2:
        return 0.0
    kdims = [int(d) for d in ops_shapes[1][1].split(",") if d]
    n = 1
    for d in out:
        n *= d
    k = 1
    for d in kdims[:-1]:
        k *= d
    return 2.0 * n * k


def _coll_traffic(op: Op, n_devices: int) -> float:
    b = _shape_bytes(op.result)
    if op.kind == "all-reduce":
        return 2.0 * b
    if op.kind == "reduce-scatter":
        m = _GROUPS_IOTA.search(op.rest)
        if m:
            return float(b) * int(m.group(2))
        m = _GROUPS_EXPL.search(op.rest)
        if m:
            return float(b) * len(m.group(1).split(","))
        return float(b) * n_devices
    return float(b)


def _trip_count(comps: Dict[str, List[Op]], cond: str) -> int:
    best = 1
    for op in comps.get(cond, []):
        if op.kind == "constant":
            m = re.match(r"s32\[\]", op.result)
            if m:
                mm = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
                if mm:
                    best = max(best, int(mm.group(1)))
        # constants may also be spelled inline in compare operands
    # also scan raw constant lines of the computation
    return best


def analyze(txt: str, n_devices: int = 1) -> Dict:
    comps, types = parse_computations(txt)

    # trip counts need raw constant values: rebuild from op rest strings
    def cond_trip(cond_name: str) -> int:
        best = 1
        for op in comps.get(cond_name, []):
            joined = f"{op.result} {op.kind}({op.rest}"
            for m in _CONST_S32.finditer(joined):
                best = max(best, int(m.group(1)))
            if op.kind == "constant" and op.result.strip() == "s32[]":
                m = re.match(r"(\d+)", op.rest)
                if m:
                    best = max(best, int(m.group(1)))
            # fused compare: constant feeding a fusion
        return best

    memo: Dict[str, Totals] = {}

    def total(comp: str, stack=()) -> Totals:
        if comp in memo:
            return memo[comp]
        if comp in stack:                      # recursion guard
            return Totals(0, 0, 0, {}, {})
        fl = by = cb = 0.0
        cbt: Dict[str, float] = {}
        cbc: Dict[str, int] = {}
        for op in comps.get(comp, []):
            if op.kind == "while":
                m = _CALL_ATTR.findall(op.rest)
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trip = cond_trip(cond) if cond else 1
                if body:
                    t = total(body, stack + (comp,))
                    fl += trip * t.flops
                    by += trip * t.bytes
                    cb += trip * t.coll_bytes
                    for k, v in t.coll_by_type.items():
                        cbt[k] = cbt.get(k, 0.0) + trip * v
                    for k, v in t.coll_count.items():
                        cbc[k] = cbc.get(k, 0) + trip * v
                continue
            if op.kind == "dot":
                fl += _dot_flops(op, types.get(comp, {}))
            elif op.kind == "convolution":
                fl += _conv_flops(op)
            elif op.kind in COLLECTIVES:
                t = _coll_traffic(op, n_devices)
                cb += t
                cbt[op.kind] = cbt.get(op.kind, 0.0) + t
                cbc[op.kind] = cbc.get(op.kind, 0) + 1
            elif op.kind in ("fusion", "call", "conditional", "custom-call",
                             "async-start", "map", "sort", "reduce",
                             "reduce-window", "scatter", "select-and-scatter"):
                for sub in re.findall(
                        r"(?:calls|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-]+)", op.rest):
                    for name in re.split(r",\s*%?", sub):
                        t = total(name, stack + (comp,))
                        fl += t.flops           # inner dots (rare) count once
                        cb += t.coll_bytes
                        for k, v in t.coll_by_type.items():
                            cbt[k] = cbt.get(k, 0.0) + v
                        for k, v in t.coll_count.items():
                            cbc[k] = cbc.get(k, 0) + v
            if op.kind not in _SKIP_BYTES:
                by += _shape_bytes(op.result) + _shape_bytes(op.rest.split(
                    "metadata=")[0])
        out = Totals(fl, by, cb, cbt, cbc)
        memo[comp] = out
        return out

    t = total("__entry__")
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "collective_bytes": t.coll_bytes,
        "collective_by_type": t.coll_by_type,
        "collective_count": t.coll_count,
    }
