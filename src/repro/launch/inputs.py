"""Input construction for every (architecture × shape × mode) cell.

``input_specs`` returns ``ShapeDtypeStruct`` stand-ins (weak-type-correct,
shardable, **no device allocation**) — the dry-run lowers against these.
``make_batch`` materializes small real batches for smoke tests / examples.

Modality frontends are stubs per the assignment: the VLM cell feeds token ids
plus precomputed 3D M-RoPE position ids; the audio cell feeds precomputed
frame embeddings to the encoder.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import build

CACHE_PAD = 128          # decode cells: room after the prefilled cache
ENCDEC_DECODE_SRC = 4096  # encoder memory length for enc-dec decode cells


def _tok(shape, abstract):
    if abstract:
        return jax.ShapeDtypeStruct(shape, jnp.int32)
    return jnp.zeros(shape, jnp.int32)


def _f32(shape, abstract, dtype=jnp.float32):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def _train_batch(cfg: ModelConfig, B: int, S: int, abstract: bool) -> Dict[str, Any]:
    batch = {"tokens": _tok((B, S), abstract), "labels": _tok((B, S), abstract)}
    if cfg.mrope:
        batch["positions"] = _tok((B, S, 3), abstract)
    if cfg.family == "encdec":
        batch["enc_embeds"] = _f32((B, S, cfg.d_model), abstract)
    return batch


def _prefill_batch(cfg: ModelConfig, B: int, S: int, abstract: bool) -> Dict[str, Any]:
    return _train_batch(cfg, B, S, abstract)


def _abstract_cache(cfg: ModelConfig, B: int, S: int):
    model = build(cfg)
    if cfg.family == "encdec":
        fn = lambda: model.init_cache(B, S + CACHE_PAD, min(S, ENCDEC_DECODE_SRC))
    elif cfg.family == "ssm":
        fn = lambda: model.init_cache(B)
    else:
        fn = lambda: model.init_cache(B, S + CACHE_PAD)
    cache = jax.eval_shape(fn)
    # decode starts with a full cache of S tokens
    cache = dict(cache)
    cache["len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return cache


def _real_cache(cfg: ModelConfig, B: int, S: int):
    model = build(cfg)
    if cfg.family == "encdec":
        cache = model.init_cache(B, S + CACHE_PAD, min(S, ENCDEC_DECODE_SRC))
    elif cfg.family == "ssm":
        cache = model.init_cache(B)
    else:
        cache = model.init_cache(B, S + CACHE_PAD)
    cache["len"] = jnp.int32(S)
    return cache


def _decode_batch(cfg: ModelConfig, B: int, S: int, abstract: bool):
    batch = {"token": _tok((B, 1), abstract)}
    cache = _abstract_cache(cfg, B, S) if abstract else _real_cache(cfg, B, S)
    return batch, cache


def input_specs(cfg: ModelConfig, shape, mode: str | None = None):
    """Abstract inputs for one shape cell. Returns (batch,) or (batch, cache)."""
    mode = mode or shape.kind
    B, S = shape.global_batch, shape.seq_len
    if mode == "train":
        return (_train_batch(cfg, B, S, True),)
    if mode == "prefill":
        return (_prefill_batch(cfg, B, S, True),)
    if mode == "decode":
        batch, cache = _decode_batch(cfg, B, S, True)
        return (batch, cache)
    raise ValueError(mode)


def make_batch(cfg: ModelConfig, B: int, S: int, mode: str = "train",
               rng: np.random.RandomState | None = None):
    """Small real batches for smoke tests and examples."""
    rng = rng or np.random.RandomState(0)
    if mode in ("train", "prefill"):
        batch = {
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3))
            batch["positions"] = jnp.asarray(pos, jnp.int32)
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.asarray(rng.randn(B, S, cfg.d_model) * 0.05,
                                              jnp.float32)
        return batch
    if mode == "decode":
        batch = {"token": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 1)), jnp.int32)}
        cache = _real_cache(cfg, B, S)
        return batch, cache
    raise ValueError(mode)
