"""Minimal functional module system.

Models are described as nested dicts of :class:`ParamSpec`.  A spec tree can be

* ``materialize``d into real arrays (smoke tests, examples),
* ``abstract``ed into ``ShapeDtypeStruct``s (multi-pod dry-run — no allocation),
* ``partition_specs``'d into ``PartitionSpec``s via divisibility-aware logical
  axis rules (the distribution layer).

Forward functions are plain JAX functions over the materialized pytree, so the
same model code serves smoke tests (1 CPU device), the 512-device dry-run and a
real TPU pod.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[Optional[str], ...]   # one logical axis name (or None) per dim
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 1.0


def spec(shape, axes, dtype=jnp.float32, init="normal", scale=None) -> ParamSpec:
    shape = tuple(int(s) for s in shape)
    assert len(shape) == len(axes), (shape, axes)
    if scale is None:
        # fan-in scaled normal by default
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
    return ParamSpec(shape, dtype, tuple(axes), init, float(scale))


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(f: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# materialization / abstraction
# ---------------------------------------------------------------------------

def materialize(specs, rng: jax.Array):
    """Instantiate real parameter arrays (used by smoke tests and examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            out.append((jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(s.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract(specs, sharding_fn: Optional[Callable[[ParamSpec], Any]] = None):
    """ShapeDtypeStruct tree — shape-only stand-ins for .lower()."""
    def mk(s: ParamSpec):
        sh = sharding_fn(s) if sharding_fn is not None else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return tree_map_specs(mk, specs)


# ---------------------------------------------------------------------------
# logical axis rules → PartitionSpec
# ---------------------------------------------------------------------------

# Baseline parameter-sharding rules.  Each logical axis maps to an ordered list
# of candidate mesh axes; the first unused mesh axis whose size divides the dim
# is taken.  ``embed`` rides the FSDP ("data") axis; TP-ish dims ride "model".
DEFAULT_PARAM_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("data",),        # FSDP: weights gathered per-layer under scan
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),     # expert parallelism
    "expert_mlp": (),
    "inner": ("model",),       # ssm inner channels
    "state": (),
    "head_dim": (),
    "layers": (),
    "conv": (),
    "qkv": (),
}

_local = threading.local()


def set_param_rules(rules: Optional[Dict[str, Tuple[str, ...]]]) -> None:
    _local.rules = rules


def get_param_rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_local, "rules", None) or DEFAULT_PARAM_RULES


def set_current_mesh(mesh: Optional[Mesh]) -> None:
    _local.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_local, "mesh", None)


class use_mesh_and_rules:
    """Context manager installing (mesh, param rules) for spec resolution and
    activation sharding constraints."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        self._pm, self._pr = current_mesh(), getattr(_local, "rules", None)
        set_current_mesh(self.mesh)
        set_param_rules(self.rules)
        return self.mesh

    def __exit__(self, *exc):
        set_current_mesh(self._pm)
        set_param_rules(self._pr)
        return False


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        n = 1
        for a in name:
            n *= _axis_size(mesh, a)
        return n
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def partition_spec(s: ParamSpec, mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> P:
    """Divisibility-aware PartitionSpec for one parameter."""
    rules = rules or get_param_rules()
    used: set = set()
    out = []
    for dim, ax in zip(s.shape, s.axes):
        assigned = None
        for cand in rules.get(ax, ()) if ax else ():
            if cand in used or cand not in mesh.axis_names:
                continue
            if dim % _axis_size(mesh, cand) == 0 and dim > 0:
                assigned = cand
                used.add(cand)
                break
        out.append(assigned)
    return P(*out)


def param_shardings(specs, mesh: Mesh, rules=None):
    return tree_map_specs(lambda s: NamedSharding(mesh, partition_spec(s, mesh, rules)), specs)


def param_pspecs(specs, mesh: Mesh, rules=None):
    return tree_map_specs(lambda s: partition_spec(s, mesh, rules), specs)


# ---------------------------------------------------------------------------
# activation sharding constraints
# ---------------------------------------------------------------------------

def shard_activation(x: jax.Array, axes: Tuple[Any, ...]) -> jax.Array:
    """``with_sharding_constraint`` with divisibility checking.

    ``axes`` gives, per dim, a mesh axis name, a tuple of mesh axis names, or
    None.  No-op when no mesh is installed (pure-CPU tests).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            resolved.append(None)
            continue
        cand = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,)) if a in mesh.axis_names)
        if cand and dim % _axis_size(mesh, cand) == 0:
            resolved.append(cand if len(cand) > 1 else cand[0])
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))


FSDP_AXES = ("data", "pod")


def gather_pspec(s: ParamSpec, mesh: Mesh, rules=None) -> P:
    """PartitionSpec of a weight at *use* time: FSDP axes gathered, TP axes
    kept.  Constraining a weight to this spec inside the scanned layer body
    makes GSPMD all-gather the (small) weight shard per layer instead of
    all-reducing (large) partial activations — classic FSDP/ZeRO-3."""
    rules = rules or get_param_rules()
    used: set = set()
    out = []
    for dim, ax in zip(s.shape, s.axes):
        assigned = None
        for cand in rules.get(ax, ()) if ax else ():
            if cand in used or cand not in mesh.axis_names or cand in FSDP_AXES:
                continue
            if dim % _axis_size(mesh, cand) == 0 and dim > 0:
                assigned = cand
                used.add(cand)
                break
        out.append(assigned)
    return P(*out)


def fsdp_gather(params, specs):
    """Apply gathered-layout constraints to a (sub)tree of weights at use.

    ``specs`` is the per-layer ParamSpec tree (no stacked "layers" dim);
    no-op without an installed mesh (pure-CPU tests)."""
    mesh = current_mesh()
    if mesh is None:
        return params
    rules = get_param_rules()

    def one(s, w):
        if w.ndim != len(s.axes):
            return w             # stacked/grouped variant — caller handles
        return jax.lax.with_sharding_constraint(
            w, NamedSharding(mesh, gather_pspec(s, mesh, rules)))

    # map over the spec tree (ParamSpec is itself a pytree, so is_leaf must
    # fire on the spec side)
    return jax.tree_util.tree_map(one, specs, params, is_leaf=_is_spec)


def count_params(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))
