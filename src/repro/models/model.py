"""Composable model assembly for all supported families.

Families:
  dense / vlm  — decoder-only transformer (GQA, RoPE or M-RoPE, optional
                 qk-norm / qkv-bias), SwiGLU MLP.
  moe          — same backbone with token-choice top-k MoE FFN (+ shared experts).
  ssm          — Mamba2 (SSD) stack, attention-free.
  hybrid       — Zamba2-style: Mamba2 backbone with a *shared-weight*
                 attention+MLP block applied every ``attn_every`` layers.
  encdec       — encoder-decoder (Seamless text path); encoder input is
                 precomputed frame embeddings (modality frontend stubbed per
                 the assignment).

Every family exposes:
  param_specs() / init(rng)          — ParamSpec tree / materialized params
  loss(params, batch)                — scalar loss + metrics (train_step body)
  prefill(params, batch)             — full-sequence forward -> (logits_last, cache)
  decode(params, cache, batch)       — one-token step -> (logits, cache)
Layers are stacked and scanned (lax.scan) so deep configs compile fast; remat
policy comes from the config.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (attention, attn_out, attn_qkv, attn_specs, cross_entropy,
                     decode_attention, embed, embed_specs, mlp, mlp_specs,
                     moe_ffn, moe_specs, rmsnorm, unembed)
from .module import fsdp_gather, materialize, shard_activation, spec
from .ssm import (mamba2_decode_step, mamba2_forward, mamba2_specs)

AUX_COEF = 0.01


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def default_positions(B: int, S: int, offset=0) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(S) + offset, (B, S))


def _shard_cache(k):
    return shard_activation(k, (None, ("pod", "data"), None, "model", None))


# ===========================================================================
# decoder-only LM (dense / moe / vlm)
# ===========================================================================

class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- params ----------------------------------------------------------
    def param_specs(self):
        cfg = self.cfg
        L, d = cfg.n_layers, cfg.d_model
        blocks = {
            "ln1": spec((L, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
            "ln2": spec((L, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
            "attn": attn_specs(cfg, layers=L),
        }
        if cfg.moe:
            blocks["moe"] = moe_specs(cfg, layers=L)
        else:
            blocks["mlp"] = mlp_specs(d, cfg.d_ff, layers=L, dtype=cfg.param_dtype)
        return {
            "embed": embed_specs(cfg),
            "blocks": blocks,
            "final_norm": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
        }

    def init(self, rng):
        return materialize(self.param_specs(), rng)

    def layer_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        ls = {
            "ln1": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
            "ln2": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
            "attn": attn_specs(cfg),
        }
        if cfg.moe:
            ls["moe"] = moe_specs(cfg)
        else:
            ls["mlp"] = mlp_specs(d, cfg.d_ff)
        return ls

    # ---- forward ----------------------------------------------------------
    def _positions(self, batch, B, S):
        if self.cfg.mrope:
            return batch["positions"]                               # [B,S,3]
        return batch.get("positions", default_positions(B, S))

    def hidden(self, params, tokens, positions):
        cfg = self.cfg
        x = embed(params["embed"], tokens, cfg)
        # sequence-parallel residual stream (context-parallel archs): h stays
        # seq-sharded over "model"; the MLP all-gathers its bf16 input and
        # reduce-scatters its output (GSPMD folds AR+slice -> RS)
        sp = cfg.attn_seq_shard and x.shape[1] > 1
        if sp:
            x = shard_activation(x, (("pod", "data"), "model", None))

        lspecs = self.layer_specs()

        def body(h, lp):
            lp = fsdp_gather(lp, lspecs)
            a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          seq_shard=cfg.attn_seq_shard)
            h = h + attn_out(lp["attn"], o, cfg)
            f_in = rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.moe:
                y, aux = moe_ffn(lp["moe"], f_in, cfg)
                return h + y, aux
            y = mlp(lp["mlp"], f_in, cfg)
            if sp:
                y = shard_activation(y, (("pod", "data"), "model", None))
            return h + y, jnp.zeros((), jnp.float32)

        h, aux = lax.scan(_remat(body, cfg), x, params["blocks"])
        return rmsnorm(h, params["final_norm"], cfg.norm_eps), aux.mean()

    def loss(self, params, batch):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        h, aux = self.hidden(params, tokens, self._positions(batch, B, S))
        logits = unembed(params["embed"], h, cfg)
        ce = cross_entropy(logits, labels, cfg.padded_vocab)
        return ce + AUX_COEF * aux, {"ce": ce, "aux": aux}

    # ---- serving ----------------------------------------------------------
    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = self._positions(batch, B, S)
        x = embed(params["embed"], tokens, cfg)
        sp = cfg.attn_seq_shard and S > 1
        if sp:
            x = shard_activation(x, (("pod", "data"), "model", None))

        lspecs = self.layer_specs()

        def body(h, lp):
            lp = fsdp_gather(lp, lspecs)
            a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          seq_shard=cfg.attn_seq_shard)
            h = h + attn_out(lp["attn"], o, cfg)
            f_in = rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.moe:
                y, _ = moe_ffn(lp["moe"], f_in, cfg)
                h = h + y
            else:
                y = mlp(lp["mlp"], f_in, cfg)
                if sp:
                    y = shard_activation(y, (("pod", "data"), "model", None))
                h = h + y
            return h, (k.astype(cfg.compute_dtype), v.astype(cfg.compute_dtype))

        h, (ks, vs) = lax.scan(_remat(body, cfg), x, params["blocks"])
        h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        cache = {"k": _shard_cache(ks), "v": _shard_cache(vs),
                 "len": jnp.int32(S)}
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        token = batch["token"]                                      # [B,1]
        B = token.shape[0]
        pos = cache["len"]
        if cfg.mrope:
            positions = jnp.broadcast_to(pos, (B, 1, 3)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
        x = embed(params["embed"], token, cfg)
        kv_len = jnp.broadcast_to(pos, (B,))      # cache entries < pos are live

        lspecs = self.layer_specs()

        def body(h, xs):
            lp, ck, cv = xs                        # cache consumed READ-ONLY
            lp = fsdp_gather(lp, lspecs)
            a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = decode_attention(q, ck, cv, k.astype(ck.dtype),
                                 v.astype(cv.dtype), kv_len,
                                 seq_shard=cfg.decode_seq_shard)
            h = h + attn_out(lp["attn"], o, cfg)
            f_in = rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.moe:
                y, _ = moe_ffn(lp["moe"], f_in, cfg)
                h = h + y
            else:
                h = h + mlp(lp["mlp"], f_in, cfg)
            return h, (k.astype(ck.dtype), v.astype(cv.dtype))

        h, (kn, vn) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        # one tiny in-place write per step (aliases under donation)
        ks = lax.dynamic_update_slice(cache["k"], kn, (0, 0, pos, 0, 0))
        vs = lax.dynamic_update_slice(cache["v"], vn, (0, 0, pos, 0, 0))
        return logits, {"k": ks, "v": vs, "len": pos + 1}

    def init_cache(self, B: int, max_len: int):
        cfg = self.cfg
        shape = (cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, cfg.compute_dtype),
                "v": jnp.zeros(shape, cfg.compute_dtype),
                "len": jnp.int32(0)}


# ===========================================================================
# Mamba2 (ssm)
# ===========================================================================

class SSMModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        L, d = cfg.n_layers, cfg.d_model
        return {
            "embed": embed_specs(cfg),
            "blocks": {
                "ln": spec((L, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "mix": mamba2_specs(cfg, layers=L),
            },
            "final_norm": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
        }

    def init(self, rng):
        return materialize(self.param_specs(), rng)

    def layer_specs(self):
        cfg = self.cfg
        return {"ln": spec((cfg.d_model,), ("embed",), init="ones"),
                "mix": mamba2_specs(cfg)}

    def hidden(self, params, tokens):
        cfg = self.cfg
        x = embed(params["embed"], tokens, cfg)
        lspecs = self.layer_specs()

        def body(h, lp):
            lp = fsdp_gather(lp, lspecs)
            y, _, _ = mamba2_forward(lp["mix"], rmsnorm(h, lp["ln"], cfg.norm_eps), cfg)
            return h + y, None

        h, _ = lax.scan(_remat(body, cfg), x, params["blocks"])
        return rmsnorm(h, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        cfg = self.cfg
        h = self.hidden(params, batch["tokens"])
        logits = unembed(params["embed"], h, cfg)
        ce = cross_entropy(logits, batch["labels"], cfg.padded_vocab)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, cfg)

        lspecs = self.layer_specs()

        def body(h, lp):
            lp = fsdp_gather(lp, lspecs)
            y, st, tail = mamba2_forward(lp["mix"], rmsnorm(h, lp["ln"], cfg.norm_eps), cfg)
            return h + y, (st, tail)

        h, (states, tails) = lax.scan(_remat(body, cfg), x, params["blocks"])
        h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        B = tokens.shape[0]
        cache = {"ssm": states.astype(jnp.float32),
                 "conv": tails,
                 "len": jnp.int32(tokens.shape[1])}
        return logits, cache

    def _zero_conv(self, B):
        cfg = self.cfg
        convc = cfg.d_inner + 2 * cfg.d_state
        return jnp.zeros((cfg.n_layers, B, cfg.d_conv - 1, convc), cfg.compute_dtype)

    def decode(self, params, cache, batch):
        cfg = self.cfg
        token = batch["token"]
        x = embed(params["embed"], token, cfg)

        lspecs = self.layer_specs()

        def body(h, xs):
            lp, st, cv = xs
            lp = fsdp_gather(lp, lspecs)
            y, st2, cv2 = mamba2_decode_step(
                lp["mix"], rmsnorm(h, lp["ln"], cfg.norm_eps), cfg, st, cv)
            return h + y, (st2, cv2)

        h, (ssm, conv) = lax.scan(body, x, (params["blocks"], cache["ssm"], cache["conv"]))
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        return logits, {"ssm": ssm, "conv": conv, "len": cache["len"] + 1}

    def init_cache(self, B: int, max_len: int = 0):
        cfg = self.cfg
        ssm = jnp.zeros((cfg.n_layers, B, cfg.ssm_heads, cfg.headdim, cfg.d_state),
                        jnp.float32)
        return {"ssm": ssm, "conv": self._zero_conv(B), "len": jnp.int32(0)}


# ===========================================================================
# hybrid (zamba2): mamba backbone + shared attention block per group
# ===========================================================================

class HybridModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.n_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.n_groups = cfg.n_layers // cfg.attn_every

    def param_specs(self):
        cfg = self.cfg
        L, d = cfg.n_layers, cfg.d_model
        return {
            "embed": embed_specs(cfg),
            "mamba": {
                "ln": spec((L, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "mix": mamba2_specs(cfg, layers=L),
            },
            "shared": {
                "ln1": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "attn": attn_specs(cfg),
                "ln2": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "mlp": mlp_specs(d, cfg.d_ff, dtype=cfg.param_dtype),
            },
            "final_norm": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
        }

    def init(self, rng):
        return materialize(self.param_specs(), rng)

    def _grouped(self, params):
        G, E = self.n_groups, self.cfg.attn_every
        return jax.tree_util.tree_map(
            lambda a: a.reshape((G, E) + a.shape[1:]), params["mamba"])

    def layer_specs(self):
        cfg = self.cfg
        return {"ln": spec((cfg.d_model,), ("embed",), init="ones"),
                "mix": mamba2_specs(cfg)}

    def shared_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {"ln1": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "attn": attn_specs(cfg),
                "ln2": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "mlp": mlp_specs(d, cfg.d_ff, dtype=cfg.param_dtype)}

    def hidden(self, params, tokens, positions):
        cfg = self.cfg
        x = embed(params["embed"], tokens, cfg)
        sp = fsdp_gather(params["shared"], self.shared_specs())
        lspecs = self.layer_specs()

        def group(h, gp):
            a_in = rmsnorm(h, sp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(sp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          seq_shard=cfg.attn_seq_shard)
            h = h + attn_out(sp["attn"], o, cfg)
            h = h + mlp(sp["mlp"], rmsnorm(h, sp["ln2"], cfg.norm_eps), cfg)

            def mblock(hh, lp):
                lp = fsdp_gather(lp, lspecs)
                y, _, _ = mamba2_forward(lp["mix"], rmsnorm(hh, lp["ln"], cfg.norm_eps), cfg)
                return hh + y, None

            h, _ = lax.scan(mblock, h, gp)
            return h, None

        h, _ = lax.scan(_remat(group, cfg), x, self._grouped(params))
        return rmsnorm(h, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = self.hidden(params, tokens, default_positions(B, S))
        logits = unembed(params["embed"], h, cfg)
        ce = cross_entropy(logits, batch["labels"], cfg.padded_vocab)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = default_positions(B, S)
        x = embed(params["embed"], tokens, cfg)
        sp = fsdp_gather(params["shared"], self.shared_specs())
        lspecs = self.layer_specs()

        def group(h, gp):
            a_in = rmsnorm(h, sp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(sp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          seq_shard=cfg.attn_seq_shard)
            h = h + attn_out(sp["attn"], o, cfg)
            h = h + mlp(sp["mlp"], rmsnorm(h, sp["ln2"], cfg.norm_eps), cfg)

            def mblock(hh, lp):
                lp = fsdp_gather(lp, lspecs)
                y, st, tail = mamba2_forward(lp["mix"], rmsnorm(hh, lp["ln"], cfg.norm_eps), cfg)
                return hh + y, (st, tail)

            h, (sts, tls) = lax.scan(mblock, h, gp)
            return h, (k.astype(cfg.compute_dtype), v.astype(cfg.compute_dtype), sts, tls)

        h, (ks, vs, ssm, tails) = lax.scan(_remat(group, cfg), x, self._grouped(params))
        h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        G, E = self.n_groups, cfg.attn_every
        convc = cfg.d_inner + 2 * cfg.d_state
        cache = {
            "k": _shard_cache(ks), "v": _shard_cache(vs),
            "ssm": ssm.reshape((G * E,) + ssm.shape[2:]).astype(jnp.float32),
            "conv": tails.reshape((G * E,) + tails.shape[2:]),
            "len": jnp.int32(S),
        }
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        token = batch["token"]
        B = token.shape[0]
        pos = cache["len"]
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
        kv_len = jnp.broadcast_to(pos, (B,))      # cache entries < pos are live
        x = embed(params["embed"], token, cfg)
        sp = params["shared"]
        G, E = self.n_groups, cfg.attn_every
        ssm = cache["ssm"].reshape((G, E) + cache["ssm"].shape[1:])
        conv = cache["conv"].reshape((G, E) + cache["conv"].shape[1:])
        sp = fsdp_gather(sp, self.shared_specs())
        lspecs = self.layer_specs()

        def group(h, xs):
            gp, ck, cv, st, cvs = xs               # kv caches READ-ONLY
            a_in = rmsnorm(h, sp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(sp["attn"], a_in, cfg, positions)
            o = decode_attention(q, ck, cv, k.astype(ck.dtype),
                                 v.astype(cv.dtype), kv_len,
                                 seq_shard=cfg.decode_seq_shard)
            h = h + attn_out(sp["attn"], o, cfg)
            h = h + mlp(sp["mlp"], rmsnorm(h, sp["ln2"], cfg.norm_eps), cfg)

            def mblock(hh, ys):
                lp, s1, c1 = ys
                lp = fsdp_gather(lp, lspecs)
                y, s2, c2 = mamba2_decode_step(
                    lp["mix"], rmsnorm(hh, lp["ln"], cfg.norm_eps), cfg, s1, c1)
                return hh + y, (s2, c2)

            h, (st2, cvs2) = lax.scan(mblock, h, (gp, st, cvs))
            return h, (k.astype(ck.dtype), v.astype(cv.dtype), st2, cvs2)

        h, (kn, vn, ssm2, conv2) = lax.scan(
            group, x, (self._grouped(params), cache["k"], cache["v"], ssm, conv))
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        ks = lax.dynamic_update_slice(cache["k"], kn, (0, 0, pos, 0, 0))
        vs = lax.dynamic_update_slice(cache["v"], vn, (0, 0, pos, 0, 0))
        return logits, {
            "k": ks, "v": vs,
            "ssm": ssm2.reshape((G * E,) + ssm2.shape[2:]),
            "conv": conv2.reshape((G * E,) + conv2.shape[2:]),
            "len": pos + 1,
        }

    def init_cache(self, B: int, max_len: int):
        cfg = self.cfg
        G = self.n_groups
        convc = cfg.d_inner + 2 * cfg.d_state
        return {
            "k": jnp.zeros((G, B, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.compute_dtype),
            "v": jnp.zeros((G, B, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.compute_dtype),
            "ssm": jnp.zeros((cfg.n_layers, B, cfg.ssm_heads, cfg.headdim, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, B, cfg.d_conv - 1, convc), cfg.compute_dtype),
            "len": jnp.int32(0),
        }


# ===========================================================================
# encoder-decoder (seamless text path)
# ===========================================================================

class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        Le, Ld = cfg.n_enc_layers, cfg.n_layers
        return {
            "embed": embed_specs(cfg),
            "enc": {
                "ln1": spec((Le, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "attn": attn_specs(cfg, layers=Le),
                "ln2": spec((Le, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "mlp": mlp_specs(d, cfg.d_ff, layers=Le, dtype=cfg.param_dtype),
            },
            "enc_norm": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
            "dec": {
                "ln1": spec((Ld, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "attn": attn_specs(cfg, layers=Ld),
                "ln2": spec((Ld, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "xattn": attn_specs(cfg, layers=Ld),
                "ln3": spec((Ld, d), ("layers", "embed"), dtype=cfg.param_dtype, init="ones"),
                "mlp": mlp_specs(d, cfg.d_ff, layers=Ld, dtype=cfg.param_dtype),
            },
            "final_norm": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
        }

    def init(self, rng):
        return materialize(self.param_specs(), rng)

    def enc_layer_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {"ln1": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "attn": attn_specs(cfg),
                "ln2": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "mlp": mlp_specs(d, cfg.d_ff, dtype=cfg.param_dtype)}

    def dec_layer_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {"ln1": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "attn": attn_specs(cfg),
                "ln2": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "xattn": attn_specs(cfg),
                "ln3": spec((d,), ("embed",), dtype=cfg.param_dtype, init="ones"),
                "mlp": mlp_specs(d, cfg.d_ff, dtype=cfg.param_dtype)}

    def encode(self, params, enc_embeds):
        cfg = self.cfg
        B, S, _ = enc_embeds.shape
        positions = default_positions(B, S)
        h = enc_embeds.astype(cfg.compute_dtype)
        especs = self.enc_layer_specs()

        def body(hh, lp):
            lp = fsdp_gather(lp, especs)
            a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
            hh = hh + attn_out(lp["attn"], o, cfg)
            hh = hh + mlp(lp["mlp"], rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg)
            return hh, None

        h, _ = lax.scan(_remat(body, cfg), h, params["enc"])
        return rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, lp, enc_out):
        cfg = self.cfg
        cd = cfg.compute_dtype
        B, S, _ = enc_out.shape
        k = jnp.einsum("bsd,dh->bsh", enc_out, lp["wk"].astype(cd))
        v = jnp.einsum("bsd,dh->bsh", enc_out, lp["wv"].astype(cd))
        return (k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
                v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim))

    def _cross_q(self, lp, x):
        cfg = self.cfg
        B, S, _ = x.shape
        q = jnp.einsum("bsd,dh->bsh", x, lp["wq"].astype(cfg.compute_dtype))
        return q.reshape(B, S, cfg.n_heads, cfg.head_dim)

    def decode_hidden(self, params, tokens, enc_out, positions):
        cfg = self.cfg
        x = embed(params["embed"], tokens, cfg)

        lspecs = self.dec_layer_specs()

        def body(h, lp):
            lp = fsdp_gather(lp, lspecs)
            a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          seq_shard=cfg.attn_seq_shard)
            h = h + attn_out(lp["attn"], o, cfg)
            xq = self._cross_q(lp["xattn"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            ck, cv = self._cross_kv(lp["xattn"], enc_out)
            xo = attention(xq, ck, cv, causal=False, chunk=cfg.attn_chunk)
            h = h + attn_out(lp["xattn"], xo, cfg)
            h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps), cfg)
            return h, None

        h, _ = lax.scan(_remat(body, cfg), x, params["dec"])
        return rmsnorm(h, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        enc_out = self.encode(params, batch["enc_embeds"])
        h = self.decode_hidden(params, tokens, enc_out, default_positions(B, S))
        logits = unembed(params["embed"], h, cfg)
        ce = cross_entropy(logits, labels, cfg.padded_vocab)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = default_positions(B, S)
        enc_out = self.encode(params, batch["enc_embeds"])
        x = embed(params["embed"], tokens, cfg)

        lspecs = self.dec_layer_specs()

        def body(h, lp):
            lp = fsdp_gather(lp, lspecs)
            a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          seq_shard=cfg.attn_seq_shard)
            h = h + attn_out(lp["attn"], o, cfg)
            xq = self._cross_q(lp["xattn"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            ck, cv = self._cross_kv(lp["xattn"], enc_out)
            xo = attention(xq, ck, cv, causal=False, chunk=cfg.attn_chunk)
            h = h + attn_out(lp["xattn"], xo, cfg)
            h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps), cfg)
            return h, (k.astype(cfg.compute_dtype), v.astype(cfg.compute_dtype),
                       ck.astype(cfg.compute_dtype), cv.astype(cfg.compute_dtype))

        h, (ks, vs, cks, cvs) = lax.scan(_remat(body, cfg), x, params["dec"])
        h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        cache = {"k": _shard_cache(ks), "v": _shard_cache(vs),
                 "ck": _shard_cache(cks), "cv": _shard_cache(cvs),
                 "len": jnp.int32(S)}
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        token = batch["token"]
        B = token.shape[0]
        pos = cache["len"]
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
        kv_len = jnp.broadcast_to(pos, (B,))
        x = embed(params["embed"], token, cfg)

        dspecs = self.dec_layer_specs()

        def body(h, xs):
            lp, ck, cv, xk, xv = xs                # caches READ-ONLY
            lp = fsdp_gather(lp, dspecs)
            a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], a_in, cfg, positions)
            o = decode_attention(q, ck, cv, k.astype(ck.dtype),
                                 v.astype(cv.dtype), kv_len,
                                 seq_shard=cfg.decode_seq_shard)
            h = h + attn_out(lp["attn"], o, cfg)
            xq = self._cross_q(lp["xattn"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            xo = attention(xq, xk, xv, causal=False)
            h = h + attn_out(lp["xattn"], xo, cfg)
            h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps), cfg)
            return h, (k.astype(ck.dtype), v.astype(cv.dtype))

        h, (kn, vn) = lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"]))
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        ks = lax.dynamic_update_slice(cache["k"], kn, (0, 0, pos, 0, 0))
        vs = lax.dynamic_update_slice(cache["v"], vn, (0, 0, pos, 0, 0))
        return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                        "len": pos + 1}

    def init_cache(self, B: int, max_len: int, src_len: int):
        cfg = self.cfg
        kd = (cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.head_dim)
        xd = (cfg.n_layers, B, src_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kd, cfg.compute_dtype),
                "v": jnp.zeros(kd, cfg.compute_dtype),
                "ck": jnp.zeros(xd, cfg.compute_dtype),
                "cv": jnp.zeros(xd, cfg.compute_dtype),
                "len": jnp.int32(0)}


# ===========================================================================
# registry
# ===========================================================================

def build(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return SSMModel(cfg)
    if cfg.family == "hybrid":
        return HybridModel(cfg)
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")
