"""Core NN layers: norms, RoPE / M-RoPE, GQA attention (dense + chunked
online-softmax), SwiGLU MLP and sort-based top-k MoE.

Layouts: activations ``[B, S, D]``; attention tensors ``[B, S, H, Dh]``.
All matmuls run in ``compute_dtype`` (bf16 by default); softmax/statistics in
fp32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .module import shard_activation, spec

NEG_INF = -1.0e30


@jax.custom_vjp
def cast_grad_bf16(x):
    """Identity forward; casts the cotangent to bf16 on the way back.

    The CE loss emits f32 dlogits; without this boundary the f32 cotangent
    flows down the whole residual stream and every backward TP all-reduce
    moves f32 — 2x the bytes.  Placed at the unembed input."""
    return x


def _cg_fwd(x):
    return x, None


def _cg_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype) if g.dtype == jnp.float32
            else g,)


# real implementation: actually return bf16 cotangent (dtype must match the
# primal, so we cast through bf16 to drop mantissa bits AND mark the boundary
# by casting the primal input to bf16 in the caller instead)
def _cg_bwd2(_, g):
    return (g.astype(jnp.bfloat16),)


cast_grad_bf16.defvjp(_cg_fwd, _cg_bwd)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def _rope_freqs(half: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(half, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs          # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions3: [B, S, 3] (t, h, w) ids.

    Frequency slots are partitioned into (t, h, w) sections of ``sections``
    half-dims; each slot uses the position id of its section.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(half, theta)
    sec_id = jnp.concatenate([
        jnp.full((sections[0],), 0, jnp.int32),
        jnp.full((sections[1],), 1, jnp.int32),
        jnp.full((sections[2],), 2, jnp.int32),
    ])                                                              # [half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1)                                                    # [B,S,half]
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _dense_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                     kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference O(S^2)-memory attention. q: [B,Sq,H,D]; k,v: [B,Sk,KH,D]."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(k.shape[1])[None] < kv_len[:, None]       # [B,Sk]
        s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, H, D)


def _chunked_attention(q, k, v, *, causal: bool, chunk: int,
                       seq_shard: bool = False) -> jax.Array:
    """Online-softmax chunked attention (flash-style in pure XLA).

    Memory is O(chunk^2) per (head, q-chunk); causal masking is applied per
    block.  Fully-masked blocks are still *computed* (masked) — the Pallas
    flash kernel (kernels/flash_attention.py) skips them on real TPUs; see
    EXPERIMENTS.md §Perf for the block-skipping XLA variant.
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    cq = ck = min(chunk, S)
    assert S % cq == 0 and S % ck == 0, (S, chunk)
    nq, nk = S // cq, S // ck
    scale = 1.0 / math.sqrt(D)

    qc = q.reshape(B, nq, cq, KH, G, D)
    kc = k.reshape(B, nk, ck, KH, D)
    vc = v.reshape(B, nk, ck, KH, D)
    if seq_shard:
        # context parallelism: intra-chunk q rows over "model"; kv replicated.
        # Stats are scan carries with a constant layout, which GSPMD
        # partitions cleanly (unlike indexed updates).
        qc = shard_activation(qc, (("pod", "data"), None, "model", None, None, None))
        kc = shard_activation(kc, (("pod", "data"), None, None, None, None))
        vc = shard_activation(vc, (("pod", "data"), None, None, None, None))

    def q_block(_, qx):
        qi, qb = qx                                                 # qb [B,cq,KH,G,D]
        m0 = jnp.full((B, KH, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, cq, D), jnp.float32)
        if seq_shard:
            m0 = shard_activation(m0, (("pod", "data"), None, None, "model"))
            l0 = shard_activation(l0, (("pod", "data"), None, None, "model"))
            a0 = shard_activation(a0, (("pod", "data"), None, None, "model", None))

        def kv_block(carry, kx):
            m, l, acc = carry
            kj, kb, vb = kx
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * cq + jnp.arange(cq)
                kpos = kj * ck + jnp.arange(ck)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(kv_block, (m0, l0, a0),
                                  (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
        o = acc / jnp.maximum(l, 1e-37)[..., None]                  # [B,KH,G,cq,D]
        return None, o.transpose(0, 3, 1, 2, 4)                     # [B,cq,KH,G,D]

    _, ob = lax.scan(q_block, None, (jnp.arange(nq), qc.swapaxes(0, 1)))
    o = ob.swapaxes(0, 1).reshape(B, S, KH, G, D)                   # [B,nq*cq,...]
    o = o.reshape(B, S, H, D).astype(q.dtype)
    if seq_shard:
        # stay seq-sharded for the (replicated-weight) output projection
        o = shard_activation(o, (("pod", "data"), "model", None, None))
    return o


def _tri_chunked_attention(q, k, v, *, chunk: int, seq_shard: bool = False) -> jax.Array:
    """Causal chunked attention over the LOWER-TRIANGLE block pairs only.

    A flat scan walks the n(n+1)/2 valid (q-chunk, kv-chunk) pairs in
    (i, j<=i) order, maintaining online-softmax stats per q chunk — exactly
    half the FLOPs/temporaries of the masked full grid (the XLA analogue of
    the Pallas kernel's pl.when block skip).

    ``seq_shard``: shard the intra-chunk q dim over "model" — context
    parallelism for architectures whose head count does not divide the TP
    axis (scores/temps shard 16x; kv stays replicated).
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    c = min(chunk, S)
    assert S % c == 0
    n = S // c
    scale = 1.0 / math.sqrt(D)

    qc = q.reshape(B, n, c, KH, G, D)
    kc = k.reshape(B, n, c, KH, D)
    vc = v.reshape(B, n, c, KH, D)
    if seq_shard:
        qc = shard_activation(qc, (("pod", "data"), None, "model", None, None, None))
        # every q chunk needs the full kv: gather once before the pair scan
        kc = shard_activation(kc, (("pod", "data"), None, None, None, None))
        vc = shard_activation(vc, (("pod", "data"), None, None, None, None))

    pairs_i, pairs_j = [], []
    for i in range(n):
        for j in range(i + 1):
            pairs_i.append(i)
            pairs_j.append(j)
    ii = jnp.asarray(pairs_i, jnp.int32)
    jj = jnp.asarray(pairs_j, jnp.int32)

    m0 = jnp.full((B, n, KH, G, c), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, n, KH, G, c), jnp.float32)
    a0 = jnp.zeros((B, n, KH, G, c, D), jnp.float32)
    if seq_shard:
        sa5 = (("pod", "data"), None, None, None, "model")
        m0 = shard_activation(m0, sa5)
        l0 = shard_activation(l0, sa5)
        a0 = shard_activation(a0, sa5 + (None,))

    def pair(carry, idx):
        m, l, acc = carry
        i, j = idx
        qb = lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)   # [B,c,KH,G,D]
        kb = lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vb = lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        qpos = i * c + jnp.arange(c)
        kpos = j * c + jnp.arange(c)
        s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)

        mi = lax.dynamic_index_in_dim(m, i, 1, keepdims=False)    # [B,KH,G,c]
        li = lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        ai = lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        m = lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        acc = lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        return (m, l, acc), None

    (m, l, acc), _ = lax.scan(pair, (m0, l0, a0), (ii, jj))
    o = acc / jnp.maximum(l, 1e-37)[..., None]                    # [B,n,KH,G,c,D]
    o = o.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, KH, G, D)
    o = o.reshape(B, S, H, D).astype(q.dtype)
    if seq_shard:
        # stay seq-sharded for the (replicated-weight) output projection
        o = shard_activation(o, (("pod", "data"), "model", None, None))
    return o


def attention(q, k, v, *, causal: bool = True, chunk: int = 512,
              q_offset: int = 0, kv_len: Optional[jax.Array] = None,
              seq_shard: bool = False, impl: str = "masked") -> jax.Array:
    """Dispatch: dense for short/decode, chunked online-softmax for long.

    impl="tri" (triangular pair scan) halves causal FLOPs but its indexed
    carry updates cost more XLA memory traffic than they save (measured:
    yi-9b prefill m 5.9->11.9 s) — the Pallas flash kernel implements the
    same skip in VMEM scratch where it is free, so "masked" is the XLA
    default and "tri" stays opt-in."""
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq == 1 or Sk <= chunk or Sq != Sk or kv_len is not None:
        return _dense_attention(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)
    if seq_shard:
        return _chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                  seq_shard=True)
    if causal and impl == "tri":
        return _tri_chunked_attention(q, k, v, chunk=chunk)
    return _chunked_attention(q, k, v, causal=causal, chunk=chunk)


def decode_attention(q, K, V, k_new, v_new, kv_len,
                     seq_shard: bool = False) -> jax.Array:
    """One-token attention against a READ-ONLY cache plus the new token.

    Avoids writing the new KV into the (multi-GB) cache before attending:
    the scan body never copies the cache (it is consumed as read-only xs);
    the single new-token slice is written once after the layer scan, which
    XLA aliases in place under buffer donation.

    q: [B,1,H,D]; K/V: [B,S,KH,D] (entries >= kv_len are stale);
    k_new/v_new: [B,1,KH,D]; kv_len: [B].

    ``seq_shard``: the cache is seq-sharded over "model" (flash-decoding) —
    anchor the score partition on the seq dim so GSPMD keeps the cache
    sharded and replicates the (tiny) q instead of gathering the cache.
    """
    B, _, H, D = q.shape
    KH = K.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, D)
    if seq_shard:
        qg = shard_activation(qg, (("pod", "data"), None, None, None))
    s_old = jnp.einsum("bkgd,bskd->bkgs", qg, K,
                       preferred_element_type=jnp.float32) * scale    # [B,KH,G,S]
    if seq_shard:
        s_old = shard_activation(s_old, (("pod", "data"), None, None, "model"))
    valid = jnp.arange(K.shape[1])[None] < kv_len[:, None]            # [B,S]
    s_old = jnp.where(valid[:, None, None], s_old, NEG_INF)
    s_new = jnp.einsum("bkgd,bkd->bkg", qg, k_new[:, 0],
                       preferred_element_type=jnp.float32)[..., None] * scale
    s = jnp.concatenate([s_old, s_new], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p[..., :-1].astype(V.dtype), V)
    o = o + p[..., -1:].astype(V.dtype) * v_new[:, 0][:, :, None, :]
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (params + forward)
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, layers: Optional[int] = None):
    d, hd = cfg.d_model, cfg.head_dim
    H, KH = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.param_dtype
    L = (layers,) if layers else ()
    La = ("layers",) if layers else ()
    # context-parallel archs replicate attention weights over "model" (their
    # head counts don't divide the TP axis; sharding the flat H*hd dim would
    # force an all-gather at the [B,S,H,hd] reshape)
    hx = None if cfg.attn_seq_shard else "heads"
    kx = None if cfg.attn_seq_shard else "kv_heads"
    p = {
        "wq": spec(L + (d, H * hd), La + ("embed", hx), dtype=dt),
        "wk": spec(L + (d, KH * hd), La + ("embed", kx), dtype=dt),
        "wv": spec(L + (d, KH * hd), La + ("embed", kx), dtype=dt),
        "wo": spec(L + (H * hd, d), La + (hx, "embed"), dtype=dt),
    }
    if cfg.qkv_bias:
        p["bq"] = spec(L + (H * hd,), La + ("heads",), dtype=dt, init="zeros")
        p["bk"] = spec(L + (KH * hd,), La + ("kv_heads",), dtype=dt, init="zeros")
        p["bv"] = spec(L + (KH * hd,), La + ("kv_heads",), dtype=dt, init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = spec(L + (hd,), La + ("head_dim",), dtype=dt, init="ones")
        p["k_norm"] = spec(L + (hd,), La + ("head_dim",), dtype=dt, init="ones")
    return p


def attn_qkv(p, x, cfg: ModelConfig, positions=None):
    """Project to (q, k, v) with RoPE / M-RoPE / qk-norm applied.

    attn_seq_shard: the whole attention region (projections included) is
    context-parallel — input sliced over the seq dim on "model" (free),
    projections run on replicated weights at 1/TP cost each."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    cd = cfg.compute_dtype
    if cfg.attn_seq_shard and S > 1:
        x = shard_activation(x, (("pod", "data"), "model", None))
    xq = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cd))
    xk = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(cd))
    xv = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        xq = xq + p["bq"].astype(cd)
        xk = xk + p["bk"].astype(cd)
        xv = xv + p["bv"].astype(cd)
    q = xq.reshape(B, S, cfg.n_heads, hd)
    k = xk.reshape(B, S, cfg.n_kv_heads, hd)
    v = xv.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    if not cfg.attn_seq_shard:
        q = shard_activation(q, (("pod", "data"), None, "model", None))
        k = shard_activation(k, (("pod", "data"), None, "model", None))
        v = shard_activation(v, (("pod", "data"), None, "model", None))
    return q, k, v


def attn_out(p, o, cfg: ModelConfig):
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(cfg.compute_dtype))


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_specs(d: int, ff: int, layers: Optional[int] = None, dtype=None):
    import jax.numpy as _jnp
    dt = dtype if dtype is not None else _jnp.float32
    L = (layers,) if layers else ()
    La = ("layers",) if layers else ()
    return {
        "w1": spec(L + (d, ff), La + ("embed", "mlp"), dtype=dt),
        "w3": spec(L + (d, ff), La + ("embed", "mlp"), dtype=dt),
        "w2": spec(L + (ff, d), La + ("mlp", "embed"), dtype=dt),
    }


def mlp(p, x, cfg: ModelConfig):
    cd = cfg.compute_dtype
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(cd))) \
        * jnp.einsum("bsd,df->bsf", x, p["w3"].astype(cd))
    h = shard_activation(h, (("pod", "data"), None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(cd))


def moe_specs(cfg: ModelConfig, layers: Optional[int] = None):
    d, fe, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    L = (layers,) if layers else ()
    La = ("layers",) if layers else ()
    dt = cfg.param_dtype
    p = {
        "router": spec(L + (d, E), La + ("embed", None), dtype=dt,
                       scale=1.0 / math.sqrt(d)),
        "we1": spec(L + (E, d, fe), La + ("experts", "embed", "expert_mlp"), dtype=dt),
        "we3": spec(L + (E, d, fe), La + ("experts", "embed", "expert_mlp"), dtype=dt),
        "we2": spec(L + (E, fe, d), La + ("experts", "expert_mlp", "embed"), dtype=dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_specs(d, cfg.n_shared_experts * fe, layers, dtype=dt)
    return p


def moe_ffn(p, x, cfg: ModelConfig):
    """Token-choice top-k MoE with *per-batch-row* sort-based dispatch.

    The sort/pack runs independently per batch row (vmap-style batched ops),
    so the data-parallel sharding of ``B`` is preserved end-to-end and GSPMD
    never has to sort across shards; the only cross-shard movement is the
    token buffer crossing from the data axis to the EP ("model") axis, which
    lowers to an all-to-all.  Capacity overflow drops (static shapes).
    """
    B, S, D = x.shape
    cd = cfg.compute_dtype
    E, K = cfg.n_experts, cfg.top_k
    T = S * K                                                        # per row

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, K)                                 # [B,S,K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style), per row then averaged
    me = probs.mean(axis=1)                                          # [B,E]
    hot = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(axis=(1, 2)) / T
    aux = (E * (me * hot).sum(axis=-1)).mean()

    C = int(math.ceil(T / E * cfg.capacity_factor))
    C = max(4, ((C + 3) // 4) * 4)

    dp2 = (("pod", "data"), None)
    dp3 = (("pod", "data"), None, None)
    flat_e = topi.reshape(B, T)                                      # [B,T]
    order = shard_activation(jnp.argsort(flat_e, axis=-1), dp2)
    sorted_e = shard_activation(jnp.take_along_axis(flat_e, order, axis=-1), dp2)
    rank = jnp.arange(T)[None, :] - jax.vmap(
        lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
    rank = shard_activation(rank, dp2)
    keep = rank < C
    dest = shard_activation(jnp.where(keep, sorted_e * C + rank, E * C), dp2)
    src_tok = shard_activation(order // K, dp2)                      # [B,T]

    # vmap'd per-row gather/scatter: index tensors stay [T, 1] per row instead
    # of the [B, T, D] broadcast take_along_axis would build (which GSPMD
    # replicates into multi-GB u32 all-gathers)
    gather_row = jax.vmap(lambda xb, ib: xb[ib])
    scatter_row = jax.vmap(
        lambda db, xb: jnp.zeros((E * C, D), cd).at[db].set(xb, mode="drop"))
    xs = gather_row(x.astype(cd), src_tok)                           # [B,T,D]
    xs = shard_activation(xs, dp3)
    bidx = jnp.arange(B)[:, None]
    buf = scatter_row(dest, xs)
    buf = shard_activation(buf, dp3)
    buf = buf.reshape(B, E, C, D)
    buf = shard_activation(buf, (("pod", "data"), "model", None, None))  # EP

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["we1"].astype(cd))) \
        * jnp.einsum("becd,edf->becf", buf, p["we3"].astype(cd))
    y = jnp.einsum("becf,efd->becd", h, p["we2"].astype(cd))
    y = shard_activation(y, (("pod", "data"), "model", None, None))
    y = shard_activation(y.reshape(B, E * C, D), dp3)

    safe = jnp.minimum(dest, E * C - 1)
    contrib = jnp.where(keep[..., None], gather_row(y, safe), 0).astype(jnp.float32)
    contrib = shard_activation(contrib, dp3)
    w = jnp.take_along_axis(topv.reshape(B, T), order, axis=-1)
    scatter_add_row = jax.vmap(
        lambda ib, cb: jnp.zeros((S, D), jnp.float32).at[ib].add(cb))
    out = scatter_add_row(src_tok, contrib * w[..., None])
    out = shard_activation(out, dp3)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg).astype(jnp.float32)
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig):
    dt = cfg.param_dtype
    p = {"tok": spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                     dtype=dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                         dtype=dt)
    return p


def _gathered_table(w):
    """Embedding/head table at use: vocab stays TP-sharded, FSDP dim gathered."""
    from .module import fsdp_gather, spec as _spec
    return fsdp_gather(w, _spec(w.shape, ("vocab", "embed")))


def embed(p, tokens, cfg: ModelConfig):
    x = _gathered_table(p["tok"]).astype(cfg.compute_dtype)[tokens]
    return shard_activation(x, (("pod", "data"), None, None))


def unembed(p, x, cfg: ModelConfig):
    head = _gathered_table(p.get("head", p["tok"]))
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(cfg.compute_dtype),
                        preferred_element_type=jnp.float32)
    return shard_activation(logits, (("pod", "data"), None, "model"))


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Masked token-mean CE; labels < 0 are ignored. logits fp32 [B,S,V]."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
