"""Model configuration for every supported architecture family.

A single dataclass covers dense / MoE / SSM / hybrid / encoder-decoder
backbones.  Modality frontends (vision patches, speech frames) are stubs per
the assignment: ``input_specs`` feeds precomputed embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None     # default: d_model // n_heads

    # ---- attention options -------------------------------------------------
    rope_theta: float = 1.0e4
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k
    qkv_bias: bool = False           # qwen2-style bias on qkv projections
    mrope: bool = False              # qwen2-vl multimodal 3D RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)   # t/h/w half-dims

    # ---- MoE ---------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # ---- SSM (Mamba2 / SSD) ------------------------------------------------
    ssm: bool = False
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ssd_chunk: int = 64

    # ---- hybrid (zamba2): shared attention block every k ssm layers --------
    attn_every: int = 0

    # ---- encoder-decoder (seamless) -----------------------------------------
    encdec: bool = False
    n_enc_layers: int = 0

    # ---- misc ---------------------------------------------------------------
    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat_policy: str = "full"       # full | dots | none
    attn_chunk: int = 512            # online-softmax chunk for long sequences
    attn_seq_shard: bool = False     # context-parallel attention (heads don't
                                     # divide the TP axis): replicate attn
                                     # weights over "model", shard intra-chunk
                                     # seq instead
    decode_seq_shard: bool = False   # decode KV cache is seq-sharded (set by
                                     # the launcher when kv-heads don't divide
                                     # the TP axis): anchor decode scores on
                                     # the seq partition

    # ------------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter-count estimate (for MODEL_FLOPS = 6 N D) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, v = self.d_model, self.padded_vocab
        hd = self.head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d

        def attn_params() -> int:
            return d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff

        if self.family in ("dense", "vlm"):
            per = attn_params() + mlp_params(self.d_ff) + 2 * d
            n += self.n_layers * per
        elif self.family == "moe":
            routed = self.n_experts if not active_only else self.top_k
            per = attn_params() + 2 * d + d * self.n_experts  # router
            per += (routed + self.n_shared_experts) * mlp_params(self.d_ff_expert)
            n += self.n_layers * per
        elif self.family == "ssm":
            di, ds, nh = self.d_inner, self.d_state, self.ssm_heads
            per = d * (2 * di + 2 * ds + nh) + di * d + di + 2 * nh + 2 * d
            n += self.n_layers * per
        elif self.family == "hybrid":
            di, ds, nh = self.d_inner, self.d_state, self.ssm_heads
            per = d * (2 * di + 2 * ds + nh) + di * d + di + 2 * nh + 2 * d
            n += self.n_layers * per
            n += attn_params() + mlp_params(self.d_ff) + 2 * d  # one shared block
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff) + 3 * d)
            n += enc + dec
        return n
