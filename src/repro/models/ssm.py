"""Mamba2 (state-space duality) mixer: chunked SSD scan for train/prefill and
a single-step recurrence for decode.

Follows the SSD block decomposition (Dao & Gu, 2024): exact quadratic
attention within chunks + linear inter-chunk state recurrence (lax.scan).
Shapes: x [B, S, D]; heads H = d_inner/headdim; groups G share B/C tensors.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import rmsnorm
from .module import shard_activation, spec

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def mamba2_specs(cfg: ModelConfig, layers: int | None = None) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = 1, cfg.d_state, cfg.ssm_heads
    convc = di + 2 * G * N
    dt = cfg.param_dtype
    L = (layers,) if layers else ()
    La = ("layers",) if layers else ()
    return {
        "in_proj": spec(L + (d, 2 * di + 2 * G * N + H), La + ("embed", "inner"), dtype=dt),
        "conv_w": spec(L + (cfg.d_conv, convc), La + ("conv", "inner"), dtype=dt, scale=0.5),
        "conv_b": spec(L + (convc,), La + ("inner",), dtype=dt, init="zeros"),
        "A_log": spec(L + (H,), La + (None,), init="zeros"),       # A = -exp(A_log), f32
        "D": spec(L + (H,), La + (None,), init="ones"),
        "dt_bias": spec(L + (H,), La + (None,), init="zeros"),
        "norm_w": spec(L + (di,), La + ("inner",), dtype=dt, init="ones"),
        "out_proj": spec(L + (di, d), La + ("inner", "embed"), dtype=dt),
    }


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d via K shifted adds. x: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        y = y + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] log-decays -> [..., Q, Q] cumulative segment sums,
    -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]   # sum_{k=j+1..i} a_k
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, seg, NEG_INF)


def ssd_chunked(x: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, init_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  [B, S, H, P]  (already multiplied by dt)
    dA: [B, S, H]     (log decay = dt * A, negative)
    Bm, Cm: [B, S, G, N]
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HG = H // G
    Q = min(chunk, S)
    S0 = S
    if S % Q:
        # zero-pad to a chunk multiple: padded x=0 contributes nothing to the
        # states and padded dA=0 (decay 1) leaves the recurrence unchanged.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q

    xc = x.reshape(B, nc, Q, H, P)
    ac = dA.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)               # [B,H,nc,Q]
    bc = Bm.reshape(B, nc, Q, G, N)
    cc = Cm.reshape(B, nc, Q, G, N)

    a_cum = jnp.cumsum(ac, axis=-1)                                  # [B,H,nc,Q]

    # (1) intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(ac))                                      # [B,H,nc,Q,Q]
    Lmat = Lmat.reshape(B, G, HG, nc, Q, Q)
    xg = xc.reshape(B, nc, Q, G, HG, P)
    scores = jnp.einsum("bcqgn,bcsgn->bgcqs", cc, bc,
                        preferred_element_type=jnp.float32)          # [B,G,nc,Q,Q]
    y_diag = jnp.einsum("bgcqs,bghcqs,bcsghp->bcqghp",
                        scores, Lmat, xg.astype(jnp.float32))

    # (2) per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)                  # [B,H,nc,Q]
    ds = decay_states.reshape(B, G, HG, nc, Q)
    states = jnp.einsum("bcqgn,bghcq,bcqghp->bcghpn", bc, ds,
                        xg.astype(jnp.float32))                      # [B,nc,G,HG,P,N]

    # (3) inter-chunk recurrence (linear scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1]).reshape(B, G, HG, nc)      # [B,G,HG,nc]
    h0 = (jnp.zeros((B, G, HG, P, N), jnp.float32) if init_state is None
          else init_state.reshape(B, G, HG, P, N).astype(jnp.float32))

    def step(h, xs):
        st, dec = xs                                                 # st [B,G,HG,P,N]
        h_in = h
        h_next = h * dec[..., None, None] + st
        return h_next, h_in

    (h_final, h_prevs) = lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4, 5),
                   chunk_decay.transpose(3, 0, 1, 2)))
    h_prev = h_prevs.transpose(1, 0, 2, 3, 4, 5)                     # [B,nc,G,HG,P,N]

    # (4) off-diagonal contribution
    sd_out = jnp.exp(a_cum).reshape(B, G, HG, nc, Q)
    y_off = jnp.einsum("bcqgn,bcghpn,bghcq->bcqghp", cc, h_prev, sd_out)

    y = (y_diag + y_off).reshape(B, nc, Q, H, P).reshape(B, S, H, P)
    return y[:, :S0].astype(x.dtype), h_final.reshape(B, H, P, N)


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------

def mamba2_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                   init_state: jax.Array | None = None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence Mamba2 mixer.

    x: [B, S, D] -> (y, final_ssm_state, conv_tail) where conv_tail holds the
    last (K-1) *pre-conv* xBC inputs — the conv cache handed to decode.
    """
    B, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.ssm_heads, cfg.headdim
    G = 1
    cd = cfg.compute_dtype

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(cd))
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    conv_tail = xBC[:, -(cfg.d_conv - 1):, :]
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                     # [H]
    dA = dt * A                                                      # [B,S,H]

    xh = xs.reshape(B, S, H, P)
    xh = shard_activation(xh, (("pod", "data"), None, "model", None))
    xd = (xh.astype(jnp.float32) * dt[..., None]).astype(cd)

    y, h_final = ssd_chunked(xd, dA, Bm.astype(cd), Cm.astype(cd),
                             cfg.ssd_chunk, init_state)
    y = y.astype(jnp.float32) + p["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd))
    return out, h_final, conv_tail.astype(cfg.compute_dtype)


def mamba2_decode_step(p: Dict, x: jax.Array, cfg: ModelConfig,
                       ssm_state: jax.Array, conv_state: jax.Array
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token recurrent step.

    x: [B, 1, D]; ssm_state: [B, H, P, N]; conv_state: [B, K-1, convc].
    Returns (y [B,1,D], ssm_state', conv_state').
    """
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.ssm_heads, cfg.headdim
    G, K = 1, cfg.d_conv
    cd = cfg.compute_dtype

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(cd))[:, 0]
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)

    # conv over (state ++ new input)
    full = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)    # [B,K,convc]
    w = p["conv_w"].astype(jnp.float32)
    conv_out = (full.astype(jnp.float32) * w[None]).sum(axis=1) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(cd)
    conv_state_new = full[:, 1:]

    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    Bm = Bm.reshape(B, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, G, N).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                             # [B,H]

    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm, H // G, axis=1)                              # [B,H,N]
    Ch = jnp.repeat(Cm, H // G, axis=1)
    upd = (dt[..., None] * xh)[..., None] * Bh[:, :, None, :]        # [B,H,P,N]
    st = ssm_state.astype(jnp.float32) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", st, Ch)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di)

    y = (y * jax.nn.silu(z.astype(jnp.float32))[:, None, :]).astype(cd)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd))
    return out, st.astype(ssm_state.dtype), conv_state_new
