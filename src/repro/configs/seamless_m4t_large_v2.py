"""seamless-m4t-large-v2 — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — encoder-decoder, multimodal. [arXiv:2308.11596; hf]

Interpreted as 24 encoder + 24 decoder layers (text path).  The speech
frontend is a stub per the assignment: ``input_specs`` feeds precomputed frame
embeddings to the encoder.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    encdec=True,
    n_layers=24,           # decoder layers
    n_enc_layers=24,       # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="seamless-reduced", n_layers=2, n_enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=192, vocab_size=512, d_head=16)
