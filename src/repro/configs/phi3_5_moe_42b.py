"""phi3.5-moe-42b-a6.6b — 32L d_model=4096 32H (GQA kv=8) d_ff=6400 per
expert, vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    moe=True,
    n_experts=16,
    top_k=2,
    d_ff_expert=6400,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="phi3.5-moe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, d_ff_expert=128, n_experts=4, top_k=2,
        vocab_size=512, d_head=16)
