"""mamba2-130m — 24L d_model=768, attention-free SSD, ssm_state=128,
vocab=50280.  State-space duality. [arXiv:2405.21060]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,            # unused (attention-free)
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50_280,
    ssm=True,
    d_state=128,
    headdim=64,            # d_inner = 1536 -> 24 ssd heads
    expand=2,
    ssd_chunk=128,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="mamba2-130m-reduced", n_layers=2, d_model=64, d_state=16,
        headdim=16, ssd_chunk=16, vocab_size=512)
