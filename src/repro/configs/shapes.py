"""Assigned input-shape cells for the LM-family architectures.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of ``seq_len``); ``train_4k`` lowers ``train_step``;
``prefill_32k`` lowers ``prefill_step``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence handling: run for SSM/hybrid only
# (per assignment); pure full-attention archs skip it (see DESIGN.md §5).
LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(family: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return family in LONG_OK_FAMILIES
    return True
