"""qwen2-0.5b — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.

GQA, QKV bias, tied embeddings. [arXiv:2407.10671; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1.0e6,
    tie_embeddings=True,
    attn_seq_shard=True,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="qwen2-0.5b-reduced", n_layers=2, d_model=112, n_heads=7,
        n_kv_heads=1, d_ff=256, vocab_size=512, d_head=16)
