"""deepseek-moe-16b — 28L d_model=2048 16H (kv=16) d_ff=1408 per expert,
vocab=102400, MoE 2 shared + 64 routed top-6 (fine-grained experts).
[arXiv:2401.06066; hf]

Deviation (DESIGN.md §5): the HF checkpoint's dense layer 0 is made MoE for
scan homogeneity.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="deepseek-moe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, d_ff_expert=96, n_experts=8,
        n_shared_experts=1, top_k=2, vocab_size=512, d_head=16)
