"""zamba2-2.7b — 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  Mamba2 backbone + shared-weight attention blocks applied every
6 layers (9 applications, separate KV per application). [arXiv:2411.15242; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    ssm=True,
    d_state=64,
    headdim=64,            # d_inner = 5120 -> 80 ssd heads
    expand=2,
    ssd_chunk=128,
    attn_every=6,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="zamba2-2.7b-reduced", n_layers=4, attn_every=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=192, d_state=16, headdim=16,
        ssd_chunk=16, vocab_size=512)
