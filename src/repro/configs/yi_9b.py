"""yi-9b — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

llama-arch GQA. [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    rope_theta=5.0e6,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="yi-9b-reduced", n_layers=3, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=384, vocab_size=512, d_head=16)
