"""deepseek-coder-33b — 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.

llama-arch. [arXiv:2401.14196; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32_256,
    rope_theta=1.0e5,
    attn_seq_shard=True,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="deepseek-coder-33b-reduced", n_layers=3, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=320, vocab_size=512, d_head=16)
