"""qwen3-14b — 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk-norm (per-head RMSNorm on q/k), GQA. [hf:Qwen/Qwen3-8B family; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1.0e6,
    attn_seq_shard=True,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="qwen3-14b-reduced", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=320, vocab_size=512, d_head=32)
