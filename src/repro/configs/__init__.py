"""Architecture registry: ``--arch <id>`` resolution.

10 assigned architectures + the paper's own transaction-engine config
(``postsi-db``, see repro/core).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeCell, applicable  # re-export

_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen3-14b": "qwen3_14b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-9b": "yi_9b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-2.7b": "zamba2_2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_IDS: List[str] = list(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).FULL


def get_reduced(arch: str) -> ModelConfig:
    return _mod(arch).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
