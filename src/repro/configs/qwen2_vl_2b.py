"""qwen2-vl-2b — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

M-RoPE (3D rotary over temporal/height/width ids), dynamic resolution.
[arXiv:2409.12191; hf].  The vision frontend is a stub per the assignment:
``input_specs`` feeds merged token ids plus precomputed 3D position ids.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # head_dim 128 -> half 64
    rope_theta=1.0e6,
    tie_embeddings=True,
    attn_seq_shard=True,
)


def reduced() -> ModelConfig:
    return FULL.replace(
        name="qwen2-vl-2b-reduced", n_layers=2, d_model=96, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512, d_head=24,
        mrope_sections=(4, 4, 4))
