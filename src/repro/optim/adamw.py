"""AdamW with decoupled weight decay and global-norm clipping.

Moments live in fp32 and inherit the parameter sharding (FSDP x TP), so the
optimizer adds zero resharding traffic.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_norm: float = 1.0):
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params2 = tdef.unflatten([o[0] for o in out])
    m2 = tdef.unflatten([o[1] for o in out])
    v2 = tdef.unflatten([o[2] for o in out])
    return params2, AdamWState(step, m2, v2), gnorm
