from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .compress import compress_int8, decompress_int8, compressed_psum
