"""int8 gradient compression with error feedback, for the cross-pod (DCN)
data-parallel all-reduce.

The pod axis has the lowest bandwidth in a multi-pod mesh; quantizing the
gradient exchange 4x (fp32 -> int8 + per-tensor scale) with an error-feedback
residual keeps convergence while cutting DCN bytes ~4x.  Used by
``train_step(..., compress_pod_grads=True)``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array, residual: jax.Array | None = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q, scale, new_residual). Error feedback: x' = x + residual."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    scale = jnp.maximum(jnp.abs(xf).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    return q, scale, err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    residual: jax.Array | None = None):
    """Quantized all-reduce over ``axis_name`` (inside shard_map): each shard
    contributes an int8 tensor + scale; the sum is exact in the dequantized
    domain because scales are psum-maxed first."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    scale = jax.lax.pmax(jnp.maximum(jnp.abs(xf).max(), 1e-12), axis_name) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, err
