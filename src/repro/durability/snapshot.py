"""Periodic snapshots of the version store (DESIGN.md §9).

A snapshot is one PostSI writer transaction over the version rings + SID
state plus a small meta vector — taken through ``PostSICheckpointer``
(checkpoint/postsi_store.py), so CID-based visibility guarantees a restore
observes one atomic snapshot, never a torn mix of two, with no manifest
lock (DESIGN.md §3.1).  The meta vector pins the snapshot to the WAL:

    [clock, wave_idx, wal_seq, gc_clock, next_tid]

``wal_seq`` is the number of retired blocks already folded into the
snapshot — recovery restores the snapshot and replays only WAL records
with ``seq >= wal_seq``.  Snapshots are only taken at **pipeline-empty
retire boundaries** (no dispatched-but-unretired block, no open buffer):
that is the only point where the device store is exactly the state of the
retired prefix, so snapshot + WAL-suffix replay reconstructs the same
state as a full replay, bit for bit.

A corrupt snapshot directory degrades, never kills: the checkpointer
tolerates a damaged meta file (``meta_corrupt``) and ``restore_latest``
then returns ``None`` — recovery falls back to replaying the whole WAL.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import numpy as np

from repro.checkpoint import PostSICheckpointer

_META_LEN = 5        # clock, wave_idx, wal_seq, gc_clock, next_tid


@dataclasses.dataclass
class SnapshotState:
    """One restored snapshot: numpy store leaves + the WAL anchor."""
    store: dict                  # field name -> np.ndarray (MVStore leaves)
    clock: int
    wave_idx: int
    wal_seq: int                 # retired blocks already inside the store
    gc_clock: int
    next_tid: int
    snap_id: int                 # the checkpointer step that produced it


def _tree_example(n_keys: int, n_versions: int) -> dict:
    """The fixed pytree shape every snapshot of this store uses — dict
    leaves (not the MVStore NamedTuple) so the checkpointer's leaf paths
    are stable strings independent of core-engine refactors."""
    kv = (n_keys, n_versions)
    return {
        "store": {
            "val": np.zeros(kv, np.int32), "tid": np.zeros(kv, np.int32),
            "cid": np.zeros(kv, np.int32), "sid": np.zeros(kv, np.int32),
            "head": np.zeros((n_keys,), np.int32),
            "wave": np.zeros((n_keys,), np.int32),
        },
        "meta": np.zeros((_META_LEN,), np.int64),
    }


class SnapshotStore:
    """Snapshot save/restore for one durable service directory."""

    SUBDIR = "snaps"

    def __init__(self, directory: str, n_keys: int, n_versions: int,
                 keep_latest: int = 2):
        self.dir = os.path.join(directory, self.SUBDIR)
        self.keep_latest = keep_latest
        self.example = _tree_example(n_keys, n_versions)
        self.ckpt = PostSICheckpointer(self.dir, self.example)
        self._next_id = 1

    # ---------------------------------------------------------------- save
    def save(self, store, clock: int, wave_idx: int, wal_seq: int,
             gc_clock: int, next_tid: int) -> int:
        """Snapshot the (host-synced) store; returns the snapshot id.
        ``store`` is an MVStore whose leaves may be device arrays or
        sharded — ``np.asarray`` gathers either."""
        tree = {
            "store": {f: np.asarray(getattr(store, f))
                      for f in self.example["store"]},
            "meta": np.array([clock, wave_idx, wal_seq, gc_clock, next_tid],
                             np.int64),
        }
        snap_id = self._next_id
        self._next_id += 1
        ok = self.ckpt.save(snap_id, tree)
        if ok:
            self.ckpt.gc(keep_latest=self.keep_latest)
        return snap_id

    # ------------------------------------------------------------- restore
    def restore_latest(self) -> Optional[SnapshotState]:
        """Latest committed snapshot, or ``None`` (no snapshot yet, or the
        snapshot store is damaged — recovery then replays the full WAL)."""
        try:
            snap_id, tree = self.ckpt.restore(self.example)
        except (OSError, ValueError):
            return None                   # damaged leaf files: full replay
        if snap_id is None:
            return None
        meta = [int(x) for x in np.asarray(tree["meta"])]
        self._next_id = max(self._next_id, snap_id + 1)
        return SnapshotState(
            store={f: np.asarray(a) for f, a in tree["store"].items()},
            clock=meta[0], wave_idx=meta[1], wal_seq=meta[2],
            gc_clock=meta[3], next_tid=meta[4], snap_id=snap_id)
