"""Crash recovery: snapshot restore + WAL replay (DESIGN.md §9).

``recover()`` rebuilds a durable service directory into the exact state of
an uninterrupted run over the retired prefix:

1. restore the latest committed snapshot (or start from the bootstrap
   store) — version rings, SID state, clock, wave index, GC clock, TID
   counter;
2. replay every WAL block with ``seq >= snapshot.wal_seq`` through
   ``engine.run_block`` (or ``dist_engine.run_block_dist`` on a mesh) with
   the logged wave-index origin and dispatch-time watermark;
3. cross-check each replayed wave's (status, s, c) against the outcomes
   logged at retirement — replay is deterministic, so any divergence means
   corruption or a config mismatch and raises ``RecoveryError`` instead of
   silently serving a forked history.

The store, version rings and GC watermark come back **bit-identical** for
all six schedulers on both substrates (tests/test_recovery.py), because
the WAL records everything ``run_block`` consumed: the stacked wave
(op_kind/op_key/op_val/host/tid), the wave-index origin, and the watermark
the service computed at dispatch.  External GC pins are *not* durable —
a pinned reader that matters across restarts must re-pin after recovery
(its floor only lowers the watermark, so forgetting it is conservative
for correctness of recovery itself, wasteful for the reader).

``DurabilityManager`` is the service-side hook: ``TxnService(...,
durability=mgr)`` attaches it — an existing log auto-recovers into the
fresh service (store/clock/wave_idx/GC/TID counter/history), an empty
directory gets a CONFIG head record; thereafter every retired block is
appended durable-before-ack and snapshots are taken at pipeline-empty
retire boundaries every ``snapshot_every`` blocks.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MVStore, Wave, WaveOut, make_store, run_block

from . import wal
from .snapshot import SnapshotStore

_WAL_NAME = "wal.log"
_FORMAT = 1
# config fields that must match for replay to be meaningful; T is absent on
# purpose (the adaptive sizer already varies it block to block)
_REPLAY_FIELDS = ("sched", "n_nodes", "n_keys", "n_versions", "O",
                  "gc_block", "n_slots", "placement")


class RecoveryError(RuntimeError):
    """Replay diverged from the logged outcomes (corruption or config
    drift) — recovery refuses to serve a forked history."""


@dataclasses.dataclass
class RecoveredState:
    """Everything a service needs to resume exactly after the retired
    prefix."""
    store: MVStore               # device-resident (sharded when mesh given)
    clock: int
    wave_idx: int                # last executed wave index
    gc_clock: int                # watermark tracker clock (= recovered wm)
    next_tid: int
    evicted_visible: int
    history: List[Tuple[np.ndarray, WaveOut]]   # per-wave, service format
    # when a snapshot was used the history is a SUFFIX; this is the
    # snapshot's numpy store (field -> array) whose version rings seed the
    # verifiers' pre-boundary version lists (core/verify.py); None under
    # full replay (history is complete)
    base_store: Optional[Dict[str, np.ndarray]]
    n_blocks: int                # durable blocks total
    n_replayed: int              # blocks replayed (rest came from snapshot)
    snapshot_seq: Optional[int]  # snapshot id used, or None
    torn_bytes: int              # damaged tail bytes the scan absorbed
    config: Dict[str, Any]
    # elastic placement plane (DESIGN.md §11)
    placement_map: Optional[Any] = None  # PlacementMap after the prefix
    n_records: int = 0           # durable records total (next WAL seq —
                                 # blocks AND moves share one seq space)
    folded_requests: int = 0     # member requests that rode folded RMW rows
                                 # in the replayed suffix (DESIGN.md §12.2);
                                 # 0 for pre-fold logs or snapshot-covered
                                 # blocks


def wal_path(directory: str) -> str:
    return os.path.join(directory, _WAL_NAME)


def service_config(svc) -> Dict[str, Any]:
    """The replay-relevant configuration of a ``TxnService`` — the WAL's
    head record, written once and checked on every reattach."""
    hs = svc.host_skew
    pm = getattr(svc, "placement", None)
    return {
        "format": _FORMAT, "sched": svc.sched, "n_nodes": svc.n_nodes,
        "n_keys": svc.n_keys, "n_versions": svc.store.n_versions,
        "T": svc.T, "O": svc.O, "gc_block": svc.gc.block,
        "host_skew": None if hs is None else np.asarray(hs, np.int32),
        "backend": svc.kernels.backend,
        # elastic placement (DESIGN.md §11): the INITIAL layout identity;
        # moves replay from explicit REC_MOVE records on top of it
        "n_slots": int(svc.store.head.shape[0]),
        "placement": None if pm is None else pm.to_config(),
    }


def check_config(logged: Dict[str, Any], current: Dict[str, Any]) -> None:
    """Reject a reattach whose service would replay under different rules."""
    logged = dict(logged)            # logs from before the elastic plane
    logged.setdefault("n_slots", logged.get("n_keys"))
    logged.setdefault("placement", None)
    for f in _REPLAY_FIELDS:
        if logged.get(f) != current.get(f):
            raise wal.WalError(
                f"durable log was written by a different service config: "
                f"{f}={logged.get(f)!r} logged vs {current.get(f)!r} now")
    a, b = logged.get("host_skew"), current.get("host_skew")
    if (a is None) != (b is None) or \
            (a is not None and not np.array_equal(a, b)):
        raise wal.WalError(
            f"durable log was written under host_skew={a!r}, "
            f"service now has {b!r}")


def _block_record(seq: int, stacked, wave_idx0: int, wm: Optional[int],
                  outs_np: WaveOut, clock: int, gc_clock: int,
                  fold: Optional[np.ndarray] = None) -> Dict:
    """One retired block as a WAL payload: the full ``run_block`` input
    (replay) + the outcome digest (determinism cross-check) + the GC
    watermark after retirement (monotonicity audit).  ``fold`` ([B, T]
    request multiplicities, DESIGN.md §12.2) is pure accounting: the
    folded row IS the executed input, so replay is bit-identical with or
    without it, and logs from pre-fold services simply lack the key."""
    rec = {
        "seq": seq, "wave_idx0": int(wave_idx0),
        "wm": None if wm is None else int(wm),
        "op_kind": np.asarray(stacked.op_kind, np.int32),
        "op_key": np.asarray(stacked.op_key, np.int32),
        "op_val": np.asarray(stacked.op_val, np.int32),
        "host": np.asarray(stacked.host, np.int32),
        "tid": np.asarray(stacked.tid, np.int32),
        "status": np.asarray(outs_np.status, np.int32),
        "s": np.asarray(outs_np.s, np.int32),
        "c": np.asarray(outs_np.c, np.int32),
        "clock": int(clock), "gc_clock": int(gc_clock),
    }
    if fold is not None:
        rec["fold"] = np.asarray(fold, np.int32)
    return rec


def _replay_block(store, rec: Dict, cfg: Dict, clock, mesh, kernels,
                  placement=None):
    """Re-execute one logged block on the chosen substrate."""
    stacked = Wave(op_kind=rec["op_kind"], op_key=rec["op_key"],
                   op_val=rec["op_val"], host=rec["host"], tid=rec["tid"])
    kw = dict(sched=cfg["sched"], n_nodes=cfg["n_nodes"],
              host_skew=cfg["host_skew"], watermark=rec["wm"],
              gc_block=cfg["gc_block"], kernels=kernels,
              placement=placement)
    if mesh is None:
        return run_block(store, stacked, rec["wave_idx0"], clock, **kw)
    from repro.core.dist_engine import run_block_dist
    return run_block_dist(store, stacked, rec["wave_idx0"], clock, mesh,
                          **kw)


def recover(directory: str, mesh=None, kernels=None,
            verify_outcomes: bool = True, use_snapshot: bool = True,
            snaps: Optional[SnapshotStore] = None
            ) -> Optional[RecoveredState]:
    """Rebuild the durable state of ``directory``; ``None`` when it holds
    no log.  ``mesh`` selects the substrate the recovered store lives on
    (and replays through); ``kernels`` the kernel backend — both are free
    choices, the result is bit-identical (tests/test_recovery.py).
    ``use_snapshot=False`` forces a full-WAL replay (differential path)."""
    scan = wal.scan(wal_path(directory))
    if scan.config is None:
        return None
    cfg = scan.config
    n_keys, n_versions = cfg["n_keys"], cfg["n_versions"]
    n_slots = cfg.get("n_slots") or n_keys
    pm = None
    if cfg.get("placement") is not None:
        from repro.placement import PlacementMap
        pm = PlacementMap.from_config(cfg["placement"])

    snap = None
    if use_snapshot:
        if snaps is None:
            snaps = SnapshotStore(directory, n_slots, n_versions)
        snap = snaps.restore_latest()
    if snap is not None and snap.wal_seq > len(scan.records):
        # a snapshot may only lag the durable log (the writer syncs before
        # every save); running ahead of it means the directory was tampered
        raise RecoveryError(
            f"snapshot claims wal_seq={snap.wal_seq} but only "
            f"{len(scan.records)} durable record(s) exist")

    if snap is None:
        store = make_store(n_keys, n_versions)
        if pm is not None:
            from repro.placement import physical_store
            store = physical_store(store, pm)
        clock = jnp.int32(1)
        wave_idx, gc_clock, next_tid, start = 0, 0, 1, 0
    else:
        store = MVStore(*(jnp.asarray(snap.store[f])
                          for f in MVStore._fields))
        clock = jnp.int32(snap.clock)
        wave_idx, gc_clock = snap.wave_idx, snap.gc_clock
        next_tid, start = snap.next_tid, snap.wal_seq
        if pm is not None:
            # fold pre-snapshot moves into the map ONLY — the snapshot
            # store already holds the rings at their moved slots
            from repro.placement import record_from_payload
            for rt, rec in scan.records[:start]:
                if rt == wal.REC_MOVE:
                    pm.apply_record(record_from_payload(rec))
    if mesh is not None:
        from repro.core.dist_engine import shard_store
        store = shard_store(store, mesh)
    # the snapshot's rings are in PHYSICAL slot order; the verifiers speak
    # logical keys — capture the snapshot-time permutation before suffix
    # moves mutate the map
    snap_perm = None if pm is None else np.asarray(pm.slot).copy()

    history: List[Tuple[np.ndarray, WaveOut]] = []
    evicted = 0
    n_replayed = 0
    folded = 0
    for rt, rec in scan.records[start:]:
        if rt == wal.REC_MOVE:
            from repro.placement import apply_move, record_from_payload
            mrec = record_from_payload(rec)
            store = apply_move(store, mrec, mesh=mesh)
            pm.apply_record(mrec)
            continue
        store, outs, clock = _replay_block(
            store, rec, cfg, clock, mesh, kernels,
            placement=None if pm is None else pm.device_arrays())
        n_replayed += 1
        outs = jax.tree_util.tree_map(np.asarray, outs)
        if verify_outcomes:
            for name in ("status", "s", "c"):
                if not np.array_equal(getattr(outs, name), rec[name]):
                    raise RecoveryError(
                        f"replay of block seq={rec['seq']} diverged from "
                        f"the logged outcomes on '{name}' — refusing to "
                        f"serve a forked history")
        B = rec["op_kind"].shape[0]
        for j in range(B):
            history.append((rec["tid"][j], WaveOut(*(f[j] for f in outs))))
        evicted += int(outs.evicted_visible.sum())
        wave_idx = rec["wave_idx0"] + B - 1
        gc_clock = rec["gc_clock"]
        next_tid = max(next_tid, int(rec["tid"].max()) + 1)
        if rec.get("fold") is not None:
            # members beyond the leader per row (rows with multiplicity 0
            # are NOP padding, clip keeps them out of the count)
            folded += int(np.clip(rec["fold"] - 1, 0, None).sum())

    base_store = None if snap is None else snap.store
    if base_store is not None and snap_perm is not None:
        base_store = {f: np.asarray(a)[snap_perm]
                      for f, a in snap.store.items()}
    return RecoveredState(
        store=store, clock=int(jnp.asarray(clock)), wave_idx=wave_idx,
        gc_clock=gc_clock, next_tid=next_tid, evicted_visible=evicted,
        history=history,
        base_store=base_store,
        n_blocks=len(scan.blocks),
        n_replayed=n_replayed,
        snapshot_seq=None if snap is None else snap.snap_id,
        torn_bytes=scan.torn_bytes, config=cfg,
        placement_map=pm, n_records=len(scan.records),
        folded_requests=folded)


class DurabilityManager:
    """WAL + snapshot lifecycle for one ``TxnService`` (DESIGN.md §9).

    Knobs: ``fsync_every`` — group-commit batch (1 = durable before every
    ack); ``snapshot_every`` — snapshot cadence in retired blocks taken at
    pipeline-empty boundaries (``None`` disables snapshots: recovery
    replays the whole WAL); ``keep_snapshots`` — retained snapshot count.
    """

    def __init__(self, directory: str, fsync_every: int = 1,
                 snapshot_every: Optional[int] = None,
                 keep_snapshots: int = 2):
        self.dir = directory
        self.wal_path = wal_path(directory)
        self.fsync_every = fsync_every
        self.snapshot_every = snapshot_every
        self.keep_snapshots = keep_snapshots
        self.writer: Optional[wal.WalWriter] = None
        self.snaps: Optional[SnapshotStore] = None
        self.seq = 0                      # next block sequence number
        self._since_snap = 0
        self.last_recovery: Optional[RecoveredState] = None
        self.snapshots_taken = 0
        self.crash_synced_bytes = 0   # fsync barrier at the last crash()

    # ------------------------------------------------------------- attach
    def attach(self, svc) -> None:
        """Bind to a service: recover an existing log into it, or write
        the CONFIG head record of a fresh one.  Called by
        ``TxnService.__init__`` — after this, the service's store, clock,
        wave index, GC clock, TID counter and history are the durable
        prefix's."""
        os.makedirs(self.dir, exist_ok=True)
        cfg = service_config(svc)
        scan = wal.scan(self.wal_path)
        if self.snaps is None:
            # snapshots hold PHYSICAL rows: size them by n_slots (== n_keys
            # under the static identity placement)
            self.snaps = SnapshotStore(self.dir,
                                       cfg.get("n_slots") or cfg["n_keys"],
                                       cfg["n_versions"],
                                       keep_latest=self.keep_snapshots)
        if scan.config is not None:
            check_config(scan.config, cfg)
            state = recover(self.dir, mesh=svc.mesh, kernels=svc.kernels,
                            snaps=self.snaps)
            svc.store = state.store
            svc.clock = jnp.int32(state.clock)
            svc.wave_idx = state.wave_idx
            svc.gc.clock = state.gc_clock
            svc.gc.evicted_visible += state.evicted_visible
            svc.former.next_tid = state.next_tid
            svc.history = list(state.history)
            svc.base_store = state.base_store
            if state.placement_map is not None:
                # adopt the replayed map (same initial layout + all logged
                # moves) so routing resumes exactly where the crash left it
                svc.placement = state.placement_map
            self.seq = state.n_records
            self.last_recovery = state
        self.writer = wal.WalWriter(self.wal_path, self.fsync_every,
                                    valid_bytes=scan.valid_bytes)
        if scan.config is None:
            self.writer.append(wal.REC_CONFIG, cfg)
            self.writer.sync()            # the head record is never batched

    # ---------------------------------------------------------------- log
    def log_block(self, stacked, wave_idx0: int, wm: Optional[int],
                  outs_np: WaveOut, clock: int, gc_clock: int,
                  fold: Optional[np.ndarray] = None) -> None:
        """Append one retired block — called after the host sync, BEFORE
        outcomes are routed (acked) to clients.  ``fold`` carries the
        per-row request multiplicities when the former batched same-key
        RMWs into this block (DESIGN.md §12.2)."""
        rec = _block_record(self.seq, stacked, wave_idx0, wm, outs_np,
                            clock, gc_clock, fold=fold)
        self.writer.append(wal.REC_BLOCK, rec)
        self.seq += 1
        self._since_snap += 1

    def log_move(self, rec, clock: int = 0) -> None:
        """Append one executed placement range move (DESIGN.md §11) with
        its explicit slot arrays — replay applies the arrays verbatim and
        never re-runs the allocator.  Moves share the block seq space and
        are synced immediately: a move is a placement commit point, and
        every block logged after it replays under the moved layout."""
        from repro.placement import move_payload
        self.writer.append(wal.REC_MOVE, move_payload(rec, self.seq, clock))
        self.writer.sync()
        self.seq += 1

    def maybe_snapshot(self, svc, pipeline_empty: bool) -> bool:
        """Snapshot when the cadence is due AND the device store is exactly
        the retired prefix (no block in flight, no open buffer) — the only
        point where snapshot + WAL-suffix replay equals full replay."""
        if (self.snapshot_every is None or not pipeline_empty
                or self._since_snap < self.snapshot_every):
            return False
        self.writer.sync()        # a snapshot may lag the log, never lead it
        self.snaps.save(svc.store, int(jnp.asarray(svc.clock)), svc.wave_idx,
                        self.seq, svc.gc.clock, svc.former.next_tid)
        self.snapshots_taken += 1
        self._since_snap = 0
        return True

    # -------------------------------------------------------------- close
    def crash(self) -> int:
        """Simulated kill honoring fsync semantics: pending group-commit
        frames reach the OS unsynced (at risk of tearing), everything
        behind the last fsync barrier survives.  Records the barrier in
        ``crash_synced_bytes`` — pass it to
        ``FaultSchedule.mutilate_wal(path, synced_bytes=...)`` so injected
        tears respect it.  Returns the number of at-risk records."""
        if self.writer is None:
            return 0
        self.crash_synced_bytes = self.writer.synced_bytes
        return self.writer.simulate_crash()

    def close(self) -> None:
        """Clean shutdown: flush + fsync everything."""
        if self.writer is not None:
            self.writer.close()
