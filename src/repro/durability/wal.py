"""Write-ahead log of retired blocks (DESIGN.md §9).

The streaming plane's **block-retire point is the durability boundary**: a
retired block is a committed, ordered unit — its outcomes have been synced
to host and are about to be acknowledged to clients — so it is logged ONCE,
as one record, and replay is deterministic (``engine.run_block`` over the
logged inputs reproduces the logged outcomes bit for bit; recovery checks
exactly that).  Nothing upstream of retirement is ever durable: a block
that was dispatched but not retired when the process died is simply absent
from the log, so after recovery it either replays (the client re-submits)
or drops — it can never double-commit.

Record framing, designed to survive a torn tail::

    MAGIC(4) | type(1) | payload_len(4, LE) | crc32(payload)(4, LE) | payload

``scan`` walks frames until the file ends cleanly or a frame is damaged —
incomplete header, truncated payload, CRC mismatch, bad magic — and
reports the prefix of intact records plus how many trailing bytes were
torn.  A writer re-opening the file truncates to the intact prefix, so a
crash mid-append costs at most the unflushed suffix, never the log.

Fsync batching (group commit): ``append`` buffers frames in host memory
and only writes + ``fsync``\\ s every ``fsync_every`` records (or on an
explicit ``sync``/``close``).  ``fsync_every=1`` is the durable-before-ack
configuration the conformance suite runs; larger values trade a bounded
window of acked-but-lost commits for append throughput, exactly the group
commit trade-off in Larson et al. (PAPERS.md).  A simulated crash
(``drop_unsynced``) discards the buffered frames without writing them —
the honest model of losing the page cache.

Payloads are pickled dicts of numpy arrays + scalars; the CRC is computed
over the payload bytes, so bit-rot anywhere in a record is detected at
scan time, not deep inside replay.  Dict payloads make record schemas
forward-extensible: a block record logged by a folding former
(DESIGN.md §12.2) carries an extra ``fold`` array of per-row request
multiplicities, which old readers ignore and new readers ``.get`` —
replay itself never consults it, because the delta-summed folded row IS
the executed input and replays bit-identically.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

MAGIC = b"VWAL"
_HDR = struct.Struct("<4sBII")        # magic, rtype, payload_len, crc32
REC_CONFIG = 1
REC_BLOCK = 2
REC_MOVE = 3      # placement range move (DESIGN.md §11): explicit slot
                  # arrays, shares the block seq space so replay interleaves
                  # moves and blocks in the exact retire order


class WalError(RuntimeError):
    """Structural WAL failure that is NOT a tolerable torn tail (e.g. a
    config mismatch or a corrupt record in the *middle* of the log)."""


@dataclasses.dataclass
class WalScan:
    """Result of scanning a WAL file up to the first damaged frame."""
    config: Optional[Dict[str, Any]]      # the head CONFIG record, if intact
    blocks: List[Dict[str, Any]]          # intact BLOCK records, in order
    valid_bytes: int                      # offset of the intact prefix
    torn_bytes: int                       # damaged/incomplete trailing bytes
    # elastic placement plane (DESIGN.md §11): MOVE records, and the merged
    # (rtype, record) stream in file order — blocks and moves share ONE seq
    # space, so replay walks ``records`` to interleave them exactly
    moves: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    records: List[Tuple[int, Dict[str, Any]]] = \
        dataclasses.field(default_factory=list)


def _frame(rtype: int, payload: Dict[str, Any]) -> bytes:
    buf = pickle.dumps(payload, protocol=4)
    return _HDR.pack(MAGIC, rtype, len(buf), zlib.crc32(buf)) + buf


def scan(path: str) -> WalScan:
    """Read every intact record; tolerate (and measure) a torn tail.

    The first damaged frame ends the scan: everything before it is the
    durable prefix, everything after is counted as torn.  A missing file
    scans as empty.
    """
    if not os.path.exists(path):
        return WalScan(None, [], 0, 0)
    with open(path, "rb") as f:
        data = f.read()
    config: Optional[Dict[str, Any]] = None
    blocks: List[Dict[str, Any]] = []
    moves: List[Dict[str, Any]] = []
    records: List[Tuple[int, Dict[str, Any]]] = []
    off = 0
    while off + _HDR.size <= len(data):
        magic, rtype, ln, crc = _HDR.unpack_from(data, off)
        end = off + _HDR.size + ln
        if magic != MAGIC or end > len(data):
            break                                  # torn/garbage tail
        payload = data[off + _HDR.size:end]
        if zlib.crc32(payload) != crc:
            break                                  # bit-rot or partial write
        rec = pickle.loads(payload)
        if rtype == REC_CONFIG:
            if config is not None or records:
                raise WalError(f"{path}: CONFIG record not at log head "
                               f"(offset {off})")
            config = rec
        elif rtype == REC_BLOCK:
            blocks.append(rec)
            records.append((REC_BLOCK, rec))
        elif rtype == REC_MOVE:
            moves.append(rec)
            records.append((REC_MOVE, rec))
        else:
            raise WalError(f"{path}: unknown record type {rtype} at "
                           f"offset {off}")
        off = end
    # one seq space over blocks AND moves: position in the file IS the seq
    for i, (_, rec) in enumerate(records):
        if rec["seq"] != i:
            raise WalError(f"{path}: record seq {rec['seq']} at position {i} "
                           f"— the log is not a contiguous retire order")
    return WalScan(config, blocks, off, len(data) - off,
                   moves=moves, records=records)


class WalWriter:
    """Append-only writer over the intact prefix of a WAL file."""

    def __init__(self, path: str, fsync_every: int = 1,
                 valid_bytes: Optional[int] = None):
        if fsync_every < 1:
            raise ValueError(f"fsync_every must be >= 1, got {fsync_every}")
        self.path = path
        self.fsync_every = fsync_every
        self._pending: List[bytes] = []           # frames not yet in the OS
        self.synced_records = 0                   # frames made durable
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if valid_bytes is not None and os.path.exists(path):
            with open(path, "rb+") as f:
                f.truncate(valid_bytes)           # drop any torn tail
        self._f = open(path, "ab")
        # the fsync barrier: bytes at or before this offset survive any
        # crash; only the suffix beyond it is ever at risk of tearing
        self.synced_bytes = (os.path.getsize(path)
                             if os.path.exists(path) else 0)

    # ------------------------------------------------------------- append
    def append(self, rtype: int, payload: Dict[str, Any]) -> None:
        self._pending.append(_frame(rtype, payload))
        if len(self._pending) >= self.fsync_every:
            self.sync()

    def sync(self) -> None:
        """Write buffered frames and fsync — the group-commit point."""
        if not self._pending:
            return
        self._f.write(b"".join(self._pending))
        self._f.flush()
        os.fsync(self._f.fileno())
        self.synced_records += len(self._pending)
        self.synced_bytes = self._f.tell()
        self._pending.clear()

    @property
    def unsynced_records(self) -> int:
        return len(self._pending)

    # -------------------------------------------------------------- close
    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def drop_unsynced(self) -> int:
        """Simulated crash, page-cache-lost extreme: discard frames never
        handed to the OS.  Returns how many records were lost."""
        lost = len(self._pending)
        self._pending.clear()
        if not self._f.closed:
            self._f.close()
        return lost

    def simulate_crash(self) -> int:
        """Simulated kill honoring fsync semantics: pending group-commit
        frames are handed to the OS (written, flushed) but never fsynced —
        they are AT RISK, and a fault schedule's torn tail may destroy any
        suffix of them; everything at or before ``synced_bytes`` is behind
        the last fsync barrier and survives unconditionally.  Returns the
        number of at-risk records.  With ``fsync_every=1`` the pending
        buffer is empty at every service seam, so nothing is ever at risk
        — the durable-before-ack configuration."""
        at_risk = len(self._pending)
        if not self._f.closed:
            if self._pending:
                self._f.write(b"".join(self._pending))
                self._f.flush()
            self._f.close()
        self._pending.clear()
        return at_risk


def torn_tail(path: str, n_bytes: int) -> int:
    """Fault injection: tear ``n_bytes`` off the end of the WAL file (a
    partial final write).  Clamped to the file size; returns bytes torn.
    ``scan`` must absorb this by construction — the conformance suite and
    the chaos schedules call this between crash and recovery."""
    if n_bytes <= 0 or not os.path.exists(path):
        return 0
    size = os.path.getsize(path)
    n = min(n_bytes, size)
    with open(path, "rb+") as f:
        f.truncate(size - n)
    return n
