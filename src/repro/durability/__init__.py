"""Durability & recovery plane (DESIGN.md §9): block-retire WAL,
PostSI-committed snapshots, snapshot+replay crash recovery."""
from . import wal
from .recovery import (DurabilityManager, RecoveredState, RecoveryError,
                       recover, service_config, wal_path)
from .snapshot import SnapshotState, SnapshotStore
from .wal import WalError, WalScan, WalWriter, torn_tail

__all__ = [
    "wal", "wal_path", "WalError", "WalScan", "WalWriter", "torn_tail",
    "SnapshotState", "SnapshotStore",
    "DurabilityManager", "RecoveredState", "RecoveryError", "recover",
    "service_config",
]
