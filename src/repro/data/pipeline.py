"""Deterministic, checkpointable synthetic token pipeline.

Counter-based (Philox) generation: batch ``i`` is a pure function of
(seed, i, host_id), so

* restart/resume is exact — restoring ``state()`` replays from the same step,
* each host of a multi-host job draws a disjoint shard of the global batch
  (``host_id`` / ``host_count``) with no coordination,

which is what checkpoint/restart fault tolerance needs from the data layer.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class TokenStream:
    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, host_count: int = 1, host_id: int = 0):
        assert global_batch % host_count == 0
        self.cfg = cfg
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.seq_len = seq_len
        self.seed = seed
        self.host_count = host_count
        self.host_id = host_id
        self.step = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.seed, counter=step * self.host_count + self.host_id))

    def next(self) -> Dict[str, jnp.ndarray]:
        rng = self._rng(self.step)
        self.step += 1
        B, S = self.local_batch, self.seq_len
        # structured synthetic text: a noisy integer-sequence language so the
        # model has something learnable (next token ~ current + delta mod V)
        V = self.cfg.vocab_size
        start = rng.integers(0, V, (B, 1))
        delta = rng.integers(1, 7, (B, 1))
        base = (start + delta * np.arange(S + 1)[None, :]) % V
        noise = rng.integers(0, V, (B, S + 1))
        mask = rng.random((B, S + 1)) < 0.05
        seq = np.where(mask, noise, base).astype(np.int32)
        batch = {"tokens": jnp.asarray(seq[:, :-1]),
                 "labels": jnp.asarray(seq[:, 1:])}
        if self.cfg.mrope:
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3))
            batch["positions"] = jnp.asarray(pos.astype(np.int32))
        if self.cfg.family == "encdec":
            emb = rng.standard_normal((B, S, self.cfg.d_model)) * 0.05
            batch["enc_embeds"] = jnp.asarray(emb.astype(np.float32))
        return batch

    # ---- checkpointable cursor -------------------------------------------
    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed,
                "host_id": self.host_id, "host_count": self.host_count}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.seed
        self.step = int(state["step"])
