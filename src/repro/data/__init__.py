from .pipeline import TokenStream
