"""Closed-loop transaction service over the fused wave engine (DESIGN.md §8).

The replay drivers in ``repro.core.engine`` execute *static* wave lists:
aborted transactions die silently and nothing ever arrives.  ``TxnService``
closes the loop into the open system the paper describes serving:

    arrivals ──> WaveFormer ──> engine.step_wave ──> outcomes
                   ^  (admission, packing)   │
                   └── RetryPolicy (backoff) ┴──> committed / dropped

Each scheduler *tick* forms at most one ``[T, O]`` wave from due retries
plus fresh arrivals, executes it on-device through ``engine.step_wave``
(any of the six schedulers), and routes per-transaction outcomes: commits
record end-to-end latency (admission tick → commit tick); aborts re-enter
through the retry calendar with a fresh TID and exponential backoff until
the retry budget drops them.  The ``VisibilityGC`` tracker supplies the
version-reclamation watermark to the engine's install path and accumulates
the ``evicted_visible`` accounting.

The full history (including aborted attempts) is kept in the engine's
``(tids, WaveOut)`` format, so the standard verifiers run unchanged on
served traffic: ``service.verify()`` checks SI/CV validity and that the
final store matches a serial replay of the committed history.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, Iterable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import ABORTED, COMMITTED, NOP, Wave, WaveOut, make_store, \
    run_block, step_wave
from repro.core.verify import final_values_ok, verify_cv, verify_si
from repro.core.workloads import SMALLBANK_O, smallbank_txn, ycsb_txn
from repro.placement import (HotKeyReplicas, LoadBalancer, apply_move,
                             logical_store, physical_store)

from .former import TxnRequest, WaveFormer, fold_counts
from .gc import VisibilityGC
from .retry import RetryPolicy


def _pct(xs: List[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclasses.dataclass
class ServiceReport:
    """End-of-run metrics for one closed-loop session."""
    sched: str
    offered: int           # requests presented to admission
    admitted: int
    rejected: int          # shed at admission (queue full)
    committed: int
    dropped: int           # retry budget exhausted
    retries: int           # re-executions scheduled
    executions: int        # total txn slots executed (incl. retries)
    waves: int
    idle_ticks: int
    wall_s: float
    txns_per_sec: float    # sustained executed txns/sec (wall)
    goodput_tps: float     # committed txns/sec (wall)
    retry_rate: float      # retries / admitted
    latency_p50: float     # ticks, admission -> commit
    latency_p95: float
    latency_p99: float
    evicted_visible: int   # GC watermark violations observed
    gc: Dict[str, int]
    # streaming plane (DESIGN.md §8): 0 under the per-wave step loop
    blocks: int = 0        # fused block dispatches (>= waves / B)
    # planner plane (DESIGN.md §10): all 0 without a planner knob
    planned_waves: int = 0       # waves served through conflict-free lanes
    planned_lane_waves: int = 0  # lane + spill waves they expanded to
    planned_spilled: int = 0     # txns spilled past the lane budget
    planner_switches: int = 0    # hybrid mode flips (either direction)
    # elastic placement plane (DESIGN.md §11): all 0/empty when static
    replica_commits: int = 0     # read-only txns answered from replicas
    replica_refreshes: int = 0   # replica snapshot refreshes
    placement_moves: int = 0     # executed live range moves
    moved_keys: int = 0          # keys relocated across all moves
    imbalance: float = 0.0       # max/mean per-node committed-txn occupancy
    occupancy: List[int] = dataclasses.field(default_factory=list)
    # tenancy + write-hot mitigation plane (DESIGN.md §12)
    tenants: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    fold_groups: int = 0         # wave rows that carried a same-key RMW fold
    folded_requests: int = 0     # member requests that rode those rows free

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        return d


class TxnService:
    """Closed-loop transaction service: open stream in, commits out.

    ``mesh`` switches the data plane: ``None`` serves from the single-device
    engine (``engine.step_wave``); a 1-D ``("node",)`` mesh (from
    ``dist_engine.make_node_mesh``) shards the version store over the mesh
    and serves every wave through ``dist_engine.step_wave_dist`` — the same
    commit loop over peer collectives, any scheduler, with the GC watermark
    merged from per-node reader floors by ``lax.pmin`` instead of a host-side
    min.  Outcomes are bit-identical between the two placements.
    """

    def __init__(self, n_keys: int, n_versions: int = 8, T: int = 64,
                 O: int = SMALLBANK_O, sched: str = "postsi",
                 n_nodes: int = 8, retry: Optional[RetryPolicy] = None,
                 gc_block: bool = False, max_queue: Optional[int] = None,
                 host_skew: Optional[np.ndarray] = None, seed: int = 0,
                 mesh=None, kernels=None, durability=None, faults=None,
                 planner=None, placement=None, replicas=None, balancer=None,
                 replica_refresh: int = 1,
                 tenants: Optional[Dict[int, float]] = None,
                 fold_rmw: bool = False, fold_max: int = 256):
        from repro.core.substrate import mesh_kernels
        from repro.kernels import resolve
        from repro.planner import HybridSwitch
        self.sched = sched
        self.n_nodes = n_nodes
        self.host_skew = host_skew
        self.T, self.O = T, O
        self.mesh = mesh
        # kernel-backend plane knob (DESIGN.md §7): resolved once, threaded
        # into every engine step; on the mesh placement it is normalized
        # through the shard_map degrade so it reports what actually runs
        self.kernels = resolve(kernels) if mesh is None else \
            mesh_kernels(kernels)
        # elastic placement plane (DESIGN.md §11): when a PlacementMap is
        # given, rings live at physical rows ``placement.slot[key]`` and
        # every engine dispatch translates logical keys through it; the
        # default (None) is the frozen identity layout
        self.placement = placement
        if placement is not None:
            if placement.n_keys != n_keys:
                raise ValueError(f"placement covers {placement.n_keys} keys, "
                                 f"service has {n_keys}")
            if mesh is not None and placement.n_nodes != mesh.devices.size:
                raise ValueError(f"placement is laid out for "
                                 f"{placement.n_nodes} nodes, mesh has "
                                 f"{mesh.devices.size}")
        base = make_store(n_keys, n_versions)
        if placement is not None:
            base = physical_store(base, placement)
        if mesh is None:
            self.store = base
        else:
            from repro.core.dist_engine import shard_store
            self.store = shard_store(base, mesh)
        self.n_keys = n_keys
        if replicas is not None and not isinstance(replicas, HotKeyReplicas):
            replicas = HotKeyReplicas(replicas)
        self.replicas = replicas
        self.replica_refresh = max(1, int(replica_refresh))
        self.replica_commits = 0
        if balancer is True:
            if placement is None:
                raise ValueError("balancer=True needs an elastic placement")
            balancer = LoadBalancer(n_keys, placement.n_nodes)
        if balancer is not None and placement is None:
            raise ValueError("a balancer needs an elastic placement to move")
        self.balancer = balancer
        self.placement_moves = 0
        self.moved_keys = 0
        self._occupancy = (np.zeros(placement.n_nodes, np.int64)
                          if placement is not None else None)
        self.clock = jnp.int32(1)
        # tenancy + write-hot mitigation plane (DESIGN.md §12): weighted
        # per-tenant admission queues with DRR wave packing, and optional
        # same-key commutative-RMW folding at form time
        self.former = WaveFormer(T, O, max_queue=max_queue, tenants=tenants,
                                 fold_rmw=fold_rmw, fold_max=fold_max)
        self._tenant_stats: Dict[int, Dict] = {}
        self.retry = retry or RetryPolicy()
        self.gc = VisibilityGC(
            block=gc_block,
            n_nodes=None if mesh is None else mesh.devices.size)
        self.rng = np.random.RandomState(seed)       # backoff jitter only
        self.tick = 0
        self.wave_idx = 0
        self.blocks = 0                              # streaming plane only
        self.history: List = []                      # (tids, WaveOut) numpy
        self.requests: List[TxnRequest] = []         # every offered request
        self.committed = 0
        self.dropped = 0
        self.retries = 0
        self.executions = 0
        self.idle_ticks = 0
        self.latencies: List[int] = []
        self._req_ids = itertools.count(1)
        self._wall_s = 0.0
        self.stream = None                   # StreamingDriver, when serving
        self._last_dispatch = (0, None)      # (wave_idx0, wm) of last block
        self.base_store = None    # snapshot rings when history is a suffix
        # durability & fault-injection planes (DESIGN.md §9): the manager
        # WAL-logs every retired block durable-before-ack and auto-recovers
        # an existing log into this fresh service; the schedule fires at
        # the dispatch/retire/post-log seams
        self.faults = faults
        # planner plane (DESIGN.md §10): ``None`` — always optimistic;
        # ``"hybrid"`` — switch to planned lanes when the trailing abort
        # rate crosses the AIMD ceiling and back when contention drops;
        # ``"planned"`` — plan every wave; or a configured HybridSwitch
        self.planner = (HybridSwitch.from_name(planner)
                        if isinstance(planner, str) else planner)
        self.planned_waves = 0        # waves served through the planner
        self.planned_lane_waves = 0   # lane + spill waves they expanded to
        self.planned_spilled = 0      # txns spilled past the lane budget
        self.durability = durability
        if durability is not None:
            durability.attach(self)
        if self.replicas is not None:
            # bootstrap snapshot at floor 0 so pre-first-tick submits can
            # already be answered (every ring starts with the cid-0 version)
            self._refresh_replicas()

    # ------------------------------------------------------------ intake
    def _tstat(self, tenant: int) -> Dict:
        st = self._tenant_stats.get(tenant)
        if st is None:
            st = {"offered": 0, "committed": 0, "dropped": 0, "retries": 0,
                  "replica_commits": 0, "latencies": []}
            self._tenant_stats[tenant] = st
        return st

    def submit(self, op_kind: np.ndarray, op_key: np.ndarray,
               op_val: np.ndarray, host: int, tenant: int = 0) -> TxnRequest:
        """Offer one transaction to admission control; the returned request
        carries its fate (``rejected`` immediately, else async).  ``tenant``
        selects the admission/fairness class (DESIGN.md §12) — untagged
        submits share the default tenant 0."""
        req = TxnRequest(next(self._req_ids), np.asarray(op_kind, np.int32),
                         np.asarray(op_key, np.int32),
                         np.asarray(op_val, np.int32), int(host),
                         tenant=int(tenant))
        self.requests.append(req)
        self._tstat(req.tenant)["offered"] += 1
        if (self.replicas is not None
                and self.replicas.can_serve(req.op_kind, req.op_key)):
            # visibility-cheap replica read (DESIGN.md §11.3): a read-only
            # txn over replicated keys commits AT SUBMIT TIME with
            # s = c = the replica's visibility floor — zero coordination,
            # never enters the engine; validity is the watermark-freeze
            # invariant (versions visible at the floor are immutable)
            _, floor = self.replicas.serve(req.op_kind, req.op_key)
            req.status = "committed"
            req.replica = True
            req.arrive_tick = self.tick
            req.commit_tick = self.tick
            req.s = req.c = int(floor)
            req.attempts = 1
            self.committed += 1
            self.replica_commits += 1
            self.latencies.append(req.latency)
            st = self._tstat(req.tenant)
            st["committed"] += 1
            st["replica_commits"] += 1
            st["latencies"].append(req.latency)
            self.gc.observe_replica(
                floor, n_reads=int((req.op_kind != NOP).sum()))
            return req
        self.former.offer(req, self.tick + 1)     # eligible from next tick
        return req

    # ------------------------------------------------------------- loop
    def step(self):
        """One scheduler tick: form a wave, execute it, route outcomes.
        Returns the numpy ``WaveOut`` or ``None`` for an idle tick."""
        self.tick += 1
        t0 = time.perf_counter()
        if (self.replicas is not None
                and self.tick % self.replica_refresh == 0):
            self._refresh_replicas()
        formed = self.former.form(self.tick)
        if formed is None:
            self.idle_ticks += 1
            return None
        wave, slots = formed
        if self.planner is not None and self.planner.planned:
            out = self._step_planned(wave, slots)
            self._wall_s += time.perf_counter() - t0
            return out
        self.wave_idx += 1
        wm = self._watermark()
        if self.faults is not None:
            self.faults.at_dispatch(self)
        self.store, out, self.clock = self._step_wave(wave, wm)
        if self.faults is not None:
            self.faults.at_retire(self)
        self.gc.observe(out, int(self.clock))
        self.history.append((np.asarray(wave.tid), out))
        if self.durability is not None:
            # the step loop retires every wave synchronously: log it as a
            # B=1 block, durable BEFORE its outcomes are acked below
            self.durability.log_block(
                Wave(*(np.asarray(getattr(wave, f))[None]
                       for f in Wave._fields)),
                self.wave_idx, wm, WaveOut(*(np.asarray(x)[None]
                                             for x in out)),
                int(self.clock), self.gc.clock,
                fold=fold_counts(slots,
                                 np.asarray(wave.op_kind).shape[0])[None])
            if self.faults is not None:
                self.faults.post_log(self)
        self._route(out, slots)
        self._observe_placement(wave, out, slots)
        if self.planner is not None:
            self.planner.observe_optimistic(
                len(slots), int((out.status[:len(slots)] == ABORTED).sum()))
        if self.durability is not None:
            self.durability.maybe_snapshot(self, pipeline_empty=True)
        self._wall_s += time.perf_counter() - t0
        return out

    def _step_planned(self, wave, slots):
        """Planned-mode tick half (DESIGN.md §10): plan the formed wave
        into conflict-free lanes and execute them as ONE pow2 wave block
        through the configured data plane (local or mesh — same engine
        rules per lane), then route the merged per-row outcomes exactly
        like an optimistic wave.  Lane rows commit abort-free; only spilled
        rows can re-enter the retry calendar."""
        from repro.planner.sched import run_wave_planned
        wave_idx0 = self.wave_idx + 1
        wm = self._watermark()
        if self.faults is not None:
            self.faults.at_dispatch(self)
        self.store, self.clock, pw = run_wave_planned(
            self.store, wave, self.clock, wave_idx0=wave_idx0,
            next_tid=self.former.next_tid, sched=self.sched,
            n_nodes=self.n_nodes, mesh=self.mesh, kernels=self.kernels,
            watermark=wm, host_skew=self.host_skew, gc_block=self.gc.block,
            max_lanes=self.planner.max_lanes,
            placement=self._placement_arrays())
        if self.faults is not None:
            self.faults.at_retire(self)
        # the planner relabeled every row with fresh contiguous tids (lane
        # waves need their own [tid0, tid0+T) ranges); advance the former's
        # counter past them and point each request at the tid it ran under,
        # so history rows, requests and store versions all agree
        self.wave_idx += pw.waves_consumed
        self.former.next_tid += pw.tids_consumed
        out = pw.merged
        self.gc.observe(out, int(self.clock))
        self.history.append((pw.exec_tid, out))
        self.planned_waves += 1
        self.planned_lane_waves += pw.lane_waves + pw.spill_waves
        self.planned_spilled += pw.plan.n_spilled
        if self.durability is not None:
            # the dispatched block IS an ordinary wave block: logged as-is,
            # recovery replays it through run_block under the base sched.
            # Fold multiplicities ride along at each request's EXECUTED
            # row (the planner relabeled rows into lanes; exec_tid maps a
            # slot to its contiguous position in the stacked block), so
            # RecoveredState.folded_requests accounts planned runs exactly
            # like the step and streaming paths
            fold = np.zeros(pw.stacked.tid.shape, np.int32)
            tid0 = int(pw.stacked.tid[0, 0])
            T_pad = pw.stacked.tid.shape[1]
            for i, req in enumerate(slots):
                off = int(pw.exec_tid[i]) - tid0
                fold[off // T_pad, off % T_pad] = 1 + len(req.folded)
            self.durability.log_block(pw.stacked, wave_idx0, wm, pw.outs,
                                      int(self.clock), self.gc.clock,
                                      fold=fold)
            if self.faults is not None:
                self.faults.post_log(self)
        for i, req in enumerate(slots):
            for r in (req, *req.folded):
                r.tid = int(pw.exec_tid[i])
                r.tids[-1] = r.tid
        self._route(out, slots)
        self._observe_placement(wave, out, slots)
        self.planner.observe_planned(
            len(slots), pw.plan.conflicted + pw.plan.n_spilled)
        if self.durability is not None:
            self.durability.maybe_snapshot(self, pipeline_empty=True)
        return out

    def _route(self, out, slots):
        """Route one synced wave's per-txn outcomes: commits record latency,
        aborts re-enter the retry calendar or drop.  Shared by the per-wave
        step loop and the streaming driver's block retirement (which calls
        it once per wave of a retired block).

        A folded row (DESIGN.md §12.2) fans its outcome out to every member
        request exactly once: on commit all members commit with the row's
        (s, c) — the summed delta IS their serial net effect — and on abort
        each member re-enters the retry calendar individually (it may fold
        into a different group next wave)."""
        for i, req in enumerate(slots):
            group = (req, *req.folded)
            req.folded = []
            self.executions += len(group)
            if out.status[i] == COMMITTED:
                for r in group:
                    r.status = "committed"
                    r.commit_tick = self.tick
                    r.s, r.c = int(out.s[i]), int(out.c[i])
                    self.committed += 1
                    self.latencies.append(r.latency)
                    st = self._tstat(r.tenant)
                    st["committed"] += 1
                    st["latencies"].append(r.latency)
            else:
                for r in group:
                    delay = self.retry.next_delay(r.attempts, self.rng)
                    if delay is None:
                        r.status = "dropped"
                        self.dropped += 1
                        self._tstat(r.tenant)["dropped"] += 1
                    else:
                        self.retries += 1
                        self._tstat(r.tenant)["retries"] += 1
                        self.former.requeue(r, self.tick + delay)

    def _watermark(self):
        """The GC watermark for the next dispatch.  Single-device: the
        tracker's min over pins (or None for the engine's wave-boundary
        collapse).  Mesh: per-node live-reader floors merged by a pmin
        collective — never a host-side reduction; with no pins the engine's
        own collapse applies (None).  Under pipelined streaming the
        tracker's clock is the clock of the *retired* prefix, which can only
        under-estimate the true floor — a lower watermark is conservative,
        never unsafe."""
        if self.mesh is None:
            return self.gc.watermark()
        if not self.gc.pinned:
            return None
        from repro.core.dist_engine import mesh_watermark
        return mesh_watermark(self.mesh,
                              self.gc.node_floors(self.mesh.devices.size))

    def _step_wave(self, wave, wm):
        """Dispatch one formed wave to the configured data plane under the
        given GC watermark (``_watermark()`` at dispatch time — the caller
        computes it once so the WAL can log exactly what ran)."""
        if self.mesh is None:
            return step_wave(
                self.store, wave, self.wave_idx, self.clock, sched=self.sched,
                n_nodes=self.n_nodes, host_skew=self.host_skew,
                watermark=wm, gc_block=self.gc.block,
                kernels=self.kernels, placement=self._placement_arrays())
        from repro.core.dist_engine import step_wave_dist
        return step_wave_dist(
            self.store, wave, self.wave_idx, self.clock, self.mesh,
            sched=self.sched, n_nodes=self.n_nodes, host_skew=self.host_skew,
            watermark=wm, gc_block=self.gc.block,
            kernels=self.kernels, placement=self._placement_arrays())

    def _run_block(self, stacked):
        """Dispatch a [B]-stacked wave block to the configured data plane
        WITHOUT syncing the host (the streaming driver's dispatch half:
        store/clock advance as device futures, outcomes are materialized
        only when the driver retires the block).  Returns (outs, clock);
        ``_last_dispatch`` records the (wave_idx0, watermark) this dispatch
        consumed, so the retirement path can WAL-log a replayable record."""
        B = stacked.op_kind.shape[0]
        wave_idx0 = self.wave_idx + 1
        self.wave_idx += B
        wm = self._watermark()
        self._last_dispatch = (wave_idx0, wm)
        if self.mesh is None:
            self.store, outs, self.clock = run_block(
                self.store, stacked, wave_idx0, self.clock, sched=self.sched,
                n_nodes=self.n_nodes, host_skew=self.host_skew,
                watermark=wm, gc_block=self.gc.block,
                kernels=self.kernels, placement=self._placement_arrays())
        else:
            from repro.core.dist_engine import run_block_dist
            self.store, outs, self.clock = run_block_dist(
                self.store, stacked, wave_idx0, self.clock, self.mesh,
                sched=self.sched, n_nodes=self.n_nodes,
                host_skew=self.host_skew, watermark=wm,
                gc_block=self.gc.block, kernels=self.kernels,
                placement=self._placement_arrays())
        return outs, self.clock

    # ------------------------------------------------- elastic placement
    def _placement_arrays(self):
        """Device-side (owner, slot) arrays of the current placement, or
        ``None`` when static (cached by the PlacementMap until a move)."""
        return (None if self.placement is None
                else self.placement.device_arrays())

    def _refresh_replicas(self):
        """Re-snapshot the hot-key replicas at the current visibility floor
        (the merged GC watermark; the engine's boundary-collapse clock when
        no pins exist).  The floor only moves forward, so no invalidation
        traffic exists — one batched gather IS the replication protocol."""
        wm = self._watermark()
        floor = int(self.gc.clock) if wm is None else int(wm)
        slot_of = None if self.placement is None else self.placement.slot
        self.replicas.refresh(self.store, floor, slot_of=slot_of)

    def _observe_placement(self, wave, out, slots):
        """Fold one retired wave into placement-plane accounting (per-node
        committed-txn occupancy under the CURRENT placement) and let the
        balancer trigger live range moves at its block boundary."""
        if self.placement is None:
            return
        T = len(slots)
        kinds = np.asarray(wave.op_kind)[:T]
        keys = np.asarray(wave.op_key)[:T]
        status = np.asarray(out.status)[:T]
        owner = self.placement.owner
        active = kinds != NOP
        committed = status == COMMITTED
        sel = committed & active.any(axis=1)
        if sel.any():
            first = np.argmax(active, axis=1)
            np.add.at(self._occupancy,
                      owner[keys[np.arange(T), first][sel]], 1)
        if self.balancer is None:
            return
        self.balancer.observe(keys, active, committed, owner)
        if self.balancer.end_block():
            for lo, hi, dst in self.balancer.plan(self.placement):
                self.move_range(lo, hi, dst)

    def move_range(self, lo: int, hi: int, dst: int):
        """Live-repartition logical keys ``[lo, hi)`` onto node ``dst`` at a
        wave boundary: plan slot assignments on the PlacementMap, relocate
        the version rings in one device program (psum gather + owner-masked
        scatter on the mesh), commit the map mutation, and WAL-log the
        explicit record so recovery replays the move bit-identically.
        Between waves no transaction is in flight, every retired outcome is
        durable, and the engine's outcomes are placement-invariant — so the
        move needs no quiescence protocol beyond the boundary itself.
        Returns the applied ``MoveRecord`` (``None`` if nothing moved)."""
        if self.placement is None:
            raise ValueError("move_range needs an elastic placement")
        if self.stream is not None:
            self.stream.flush()          # no dispatched block may be in flight
        rec = self.placement.move(lo, hi, dst)
        if rec.keys.size == 0:
            return None
        self.store = apply_move(self.store, rec, mesh=self.mesh)
        self.placement.apply_record(rec)
        self.placement_moves += 1
        self.moved_keys += int(rec.keys.size)
        if self.durability is not None:
            self.durability.log_move(rec, int(self.clock))
        return rec

    def drain(self, max_ticks: Optional[int] = None) -> int:
        """Run ticks until no request is pending (or the safety cap).
        Returns the number of ticks consumed."""
        if max_ticks is None:
            max_ticks = (self.retry.worst_case_ticks()
                         + self.former.pending() // max(self.T, 1) + 8)
        n = 0
        while self.former.pending() and n < max_ticks:
            self.step()
            n += 1
        return n

    def _submit_tick(self, n_arr, txn_gen):
        """Submit one tick's arrivals.  Scalar ``n_arr``: that many calls of
        ``txn_gen()`` (4-tuples, default tenant).  1-D ``n_arr`` of length
        n_tenants: per-tenant counts, each from ``txn_gen(tenant)`` which
        must return a 5-tuple ending in the tenant tag (see
        ``tenant_txn_gen``)."""
        arr = np.asarray(n_arr)
        if arr.ndim == 0:
            for _ in range(int(arr)):
                self.submit(*txn_gen())
        else:
            for tenant, cnt in enumerate(arr):
                for _ in range(int(cnt)):
                    self.submit(*txn_gen(tenant))

    def run_stream(self, arrivals: Iterable,
                   txn_gen: Callable, drain: bool = True):
        """Feed ``arrivals[t]`` fresh requests per tick (from ``txn_gen``,
        which returns ``(op_kind, op_key, op_val, host)``), stepping once
        per tick; optionally drain the backlog afterwards.  A 2-D arrivals
        array ``[n_ticks, n_tenants]`` feeds a multi-tenant stream: column
        ``t`` arrives via ``txn_gen(t)`` (see ``tenant_txn_gen``)."""
        for n_arr in arrivals:
            self._submit_tick(n_arr, txn_gen)
            self.step()
        if drain:
            self.drain()
        return self.report()

    def run_streaming(self, arrivals: Iterable[int],
                      txn_gen: Callable[[], tuple], B: int = 4, K: int = 2,
                      sizer=None, drain: bool = True):
        """Serve the same open stream through the pipelined streaming plane
        (DESIGN.md §8): waves are batched into blocks of ``B`` and executed
        as ONE fused device program each (``engine.run_block``), with up to
        ``K`` dispatched blocks in flight — the host forms the next block(s)
        while the device runs, and a block's outcomes are synced (and its
        aborts routed to retry) only when it retires.

        ``B=1, K=1`` degenerates to the synchronous ``run_stream`` loop and
        is bit-identical to it; larger B/K trade retry-routing latency for
        dispatch amortization.  ``sizer`` — an
        ``stream.AdaptiveWaveSizer`` (or ``"auto"``) — additionally
        regulates the wave size T (and optionally B) from the trailing
        abort rate, the paper's §V-D contention regulation in open-stream
        form.  Returns the end-of-run ``ServiceReport``."""
        from .stream import AdaptiveWaveSizer, StreamingDriver
        if sizer == "auto":
            sizer = AdaptiveWaveSizer(T0=self.T, B0=B,
                                      t_min=min(8, self.T), adapt_B=True)
        driver = StreamingDriver(self, B=B, K=K, sizer=sizer)
        self.stream = driver                 # expose pipeline state/stats
        for n_arr in arrivals:
            self._submit_tick(n_arr, txn_gen)
            driver.tick()
        if drain:
            driver.drain()
        else:
            driver.flush()
        return self.report()

    # ------------------------------------------------------------ output
    def report(self) -> ServiceReport:
        wall = max(self._wall_s, 1e-9)
        admitted = self.former.admitted
        return ServiceReport(
            sched=self.sched,
            offered=len(self.requests),
            admitted=admitted,
            rejected=self.former.rejected,
            committed=self.committed,
            dropped=self.dropped,
            retries=self.retries,
            executions=self.executions,
            waves=self.wave_idx,
            idle_ticks=self.idle_ticks,
            wall_s=round(wall, 6),
            txns_per_sec=round(self.executions / wall, 1),
            goodput_tps=round(self.committed / wall, 1),
            retry_rate=round(self.retries / max(admitted, 1), 4),
            latency_p50=_pct(self.latencies, 50),
            latency_p95=_pct(self.latencies, 95),
            latency_p99=_pct(self.latencies, 99),
            evicted_visible=self.gc.evicted_visible,
            gc=self.gc.report(),
            blocks=self.blocks,
            planned_waves=self.planned_waves,
            planned_lane_waves=self.planned_lane_waves,
            planned_spilled=self.planned_spilled,
            planner_switches=(self.planner.switches
                              if self.planner is not None else 0),
            replica_commits=self.replica_commits,
            replica_refreshes=(self.replicas.refreshes
                               if self.replicas is not None else 0),
            placement_moves=self.placement_moves,
            moved_keys=self.moved_keys,
            imbalance=self._imbalance(),
            occupancy=([] if self._occupancy is None
                       else self._occupancy.tolist()),
            tenants=self._tenant_report(),
            fold_groups=self.former.fold_groups,
            folded_requests=self.former.folded_requests,
        )

    def _tenant_report(self) -> Dict[str, Dict]:
        """Per-tenant rows (keys stringified for JSON): admission counters
        from the former joined with the service-side outcome/latency
        accounting.  Single-tenant runs report one row for tenant \"0\".

        ``replica_commits`` counts reads answered from hot-key replicas AT
        SUBMIT TIME — those never pass admission, so a row's ``committed``
        can exceed ``admitted`` by exactly that amount; fairness analyses
        over engine capacity should use ``committed - replica_commits``."""
        former_stats = self.former.tenant_stats()
        rows: Dict[str, Dict] = {}
        for t in sorted(set(former_stats) | set(self._tenant_stats)):
            fs = former_stats.get(t, {})
            st = self._tenant_stats.get(t, {})
            lat = st.get("latencies", [])
            rows[str(t)] = {
                "weight": float(fs.get("weight", 1.0)),
                "offered": int(st.get("offered", 0)),
                "admitted": int(fs.get("admitted", 0)),
                "rejected": int(fs.get("rejected", 0)),
                "committed": int(st.get("committed", 0)),
                "replica_commits": int(st.get("replica_commits", 0)),
                "dropped": int(st.get("dropped", 0)),
                "retries": int(st.get("retries", 0)),
                "latency_p50": _pct(lat, 50),
                "latency_p95": _pct(lat, 95),
                "latency_p99": _pct(lat, 99),
            }
        return rows

    def _imbalance(self) -> float:
        """Max/mean per-node committed-txn occupancy under the current
        placement (1.0 = perfectly balanced; 0.0 when static or empty)."""
        if self._occupancy is None or self._occupancy.sum() == 0:
            return 0.0
        occ = self._occupancy.astype(np.float64)
        return round(float(occ.max() / occ.mean()), 4)

    def verify(self) -> List[str]:
        """Post-hoc correctness of the served history: SI (or CV) validity
        plus final-store-matches-serial-replay, via ``repro.core.verify``."""
        check = verify_cv if self.sched == "cv" else verify_si
        errors = check(self.history, base_store=self.base_store)
        # the history speaks logical keys; under an elastic placement the
        # final store is in physical slot order — permute it back before
        # the serial-replay comparison (moves don't change ring contents)
        store = (self.store if self.placement is None
                 else logical_store(self.store, self.placement))
        errors += final_values_ok(store, self.history, self.n_keys)
        return errors


def smallbank_txn_gen(rng: np.random.RandomState, n_nodes: int,
                      keys_per_node: int, dist_frac: float = 0.2,
                      hot_frac: float = 0.0, hot_per_node: int = 20):
    """Request factory for ``run_stream``: SmallBank transactions on random
    host nodes (the open-stream analogue of ``workloads.smallbank_waves``)."""
    def gen():
        host = int(rng.randint(0, n_nodes))
        op_kind, op_key, op_val = smallbank_txn(
            rng, host, n_nodes, keys_per_node, dist_frac, hot_frac,
            hot_per_node)
        return op_kind, op_key, op_val, host
    return gen


def ycsb_txn_gen(rng: np.random.RandomState, n_nodes: int,
                 keys_per_node: int, theta: float = 0.9,
                 read_frac: float = 0.8, dist_frac: float = 0.1,
                 n_ops: int = 4):
    """Request factory for the streaming plane: YCSB-style transactions with
    zipfian key skew ``theta`` on random host nodes (paper §V-D's
    skew/contention regime as an open stream — ``theta=0`` is uniform,
    ``theta>=0.9`` concentrates traffic on each node's rank-0 hot keys).
    ``read_frac``/``dist_frac``/``n_ops`` mirror ``workloads.ycsb_txn``."""
    def gen():
        host = int(rng.randint(0, n_nodes))
        op_kind, op_key, op_val = ycsb_txn(
            rng, host, n_nodes, keys_per_node, theta, read_frac, dist_frac,
            n_ops)
        return op_kind, op_key, op_val, host
    return gen


def rmw_txn_gen(rng: np.random.RandomState, n_nodes: int,
                keys_per_node: int, theta: float = 0.99, n_ops: int = 4,
                val_max: int = 8):
    """Request factory for the write-hot regime the fold plane targets
    (DESIGN.md §12.2): every transaction is a SINGLE zipfian RMW (op slot 0
    active, the rest NOP padding) with a small positive delta — θ=0.99
    concentrates the stream on each host's rank-0 key, the workload where
    unfolded same-key RMWs serialize via lost-update retries."""
    from repro.core.workloads import rmw_hot_txn

    def gen():
        host = int(rng.randint(0, n_nodes))
        op_kind, op_key, op_val = rmw_hot_txn(
            rng, host, n_nodes, keys_per_node, theta, n_ops, val_max)
        return op_kind, op_key, op_val, host
    return gen


def tenant_txn_gen(gens):
    """Compose per-tenant request factories for 2-D ``run_stream``
    arrivals: ``gens[t]()`` returns ``(op_kind, op_key, op_val, host)``;
    the returned ``gen(tenant)`` appends the tenant tag that
    ``TxnService.submit`` consumes."""
    def gen(tenant: int):
        return (*gens[tenant](), tenant)
    return gen
