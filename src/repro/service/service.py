"""Closed-loop transaction service over the fused wave engine (DESIGN.md §8).

The replay drivers in ``repro.core.engine`` execute *static* wave lists:
aborted transactions die silently and nothing ever arrives.  ``TxnService``
closes the loop into the open system the paper describes serving:

    arrivals ──> WaveFormer ──> engine.step_wave ──> outcomes
                   ^  (admission, packing)   │
                   └── RetryPolicy (backoff) ┴──> committed / dropped

Each scheduler *tick* forms at most one ``[T, O]`` wave from due retries
plus fresh arrivals, executes it on-device through ``engine.step_wave``
(any of the six schedulers), and routes per-transaction outcomes: commits
record end-to-end latency (admission tick → commit tick); aborts re-enter
through the retry calendar with a fresh TID and exponential backoff until
the retry budget drops them.  The ``VisibilityGC`` tracker supplies the
version-reclamation watermark to the engine's install path and accumulates
the ``evicted_visible`` accounting.

The full history (including aborted attempts) is kept in the engine's
``(tids, WaveOut)`` format, so the standard verifiers run unchanged on
served traffic: ``service.verify()`` checks SI/CV validity and that the
final store matches a serial replay of the committed history.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, Iterable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import COMMITTED, make_store, step_wave
from repro.core.verify import final_values_ok, verify_cv, verify_si
from repro.core.workloads import SMALLBANK_O, smallbank_txn

from .former import TxnRequest, WaveFormer
from .gc import VisibilityGC
from .retry import RetryPolicy


def _pct(xs: List[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclasses.dataclass
class ServiceReport:
    """End-of-run metrics for one closed-loop session."""
    sched: str
    offered: int           # requests presented to admission
    admitted: int
    rejected: int          # shed at admission (queue full)
    committed: int
    dropped: int           # retry budget exhausted
    retries: int           # re-executions scheduled
    executions: int        # total txn slots executed (incl. retries)
    waves: int
    idle_ticks: int
    wall_s: float
    txns_per_sec: float    # sustained executed txns/sec (wall)
    goodput_tps: float     # committed txns/sec (wall)
    retry_rate: float      # retries / admitted
    latency_p50: float     # ticks, admission -> commit
    latency_p95: float
    latency_p99: float
    evicted_visible: int   # GC watermark violations observed
    gc: Dict[str, int]

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        return d


class TxnService:
    """Closed-loop transaction service: open stream in, commits out.

    ``mesh`` switches the data plane: ``None`` serves from the single-device
    engine (``engine.step_wave``); a 1-D ``("node",)`` mesh (from
    ``dist_engine.make_node_mesh``) shards the version store over the mesh
    and serves every wave through ``dist_engine.step_wave_dist`` — the same
    commit loop over peer collectives, any scheduler, with the GC watermark
    merged from per-node reader floors by ``lax.pmin`` instead of a host-side
    min.  Outcomes are bit-identical between the two placements.
    """

    def __init__(self, n_keys: int, n_versions: int = 8, T: int = 64,
                 O: int = SMALLBANK_O, sched: str = "postsi",
                 n_nodes: int = 8, retry: Optional[RetryPolicy] = None,
                 gc_block: bool = False, max_queue: Optional[int] = None,
                 host_skew: Optional[np.ndarray] = None, seed: int = 0,
                 mesh=None, kernels=None):
        from repro.core.substrate import mesh_kernels
        from repro.kernels import resolve
        self.sched = sched
        self.n_nodes = n_nodes
        self.host_skew = host_skew
        self.T, self.O = T, O
        self.mesh = mesh
        # kernel-backend plane knob (DESIGN.md §7): resolved once, threaded
        # into every engine step; on the mesh placement it is normalized
        # through the shard_map degrade so it reports what actually runs
        self.kernels = resolve(kernels) if mesh is None else \
            mesh_kernels(kernels)
        if mesh is None:
            self.store = make_store(n_keys, n_versions)
        else:
            from repro.core.dist_engine import shard_store
            self.store = shard_store(make_store(n_keys, n_versions), mesh)
        self.n_keys = n_keys
        self.clock = jnp.int32(1)
        self.former = WaveFormer(T, O, max_queue=max_queue)
        self.retry = retry or RetryPolicy()
        self.gc = VisibilityGC(
            block=gc_block,
            n_nodes=None if mesh is None else mesh.devices.size)
        self.rng = np.random.RandomState(seed)       # backoff jitter only
        self.tick = 0
        self.wave_idx = 0
        self.history: List = []                      # (tids, WaveOut) numpy
        self.requests: List[TxnRequest] = []         # every offered request
        self.committed = 0
        self.dropped = 0
        self.retries = 0
        self.executions = 0
        self.idle_ticks = 0
        self.latencies: List[int] = []
        self._req_ids = itertools.count(1)
        self._wall_s = 0.0

    # ------------------------------------------------------------ intake
    def submit(self, op_kind: np.ndarray, op_key: np.ndarray,
               op_val: np.ndarray, host: int) -> TxnRequest:
        """Offer one transaction to admission control; the returned request
        carries its fate (``rejected`` immediately, else async)."""
        req = TxnRequest(next(self._req_ids), np.asarray(op_kind, np.int32),
                         np.asarray(op_key, np.int32),
                         np.asarray(op_val, np.int32), int(host))
        self.requests.append(req)
        self.former.offer(req, self.tick + 1)     # eligible from next tick
        return req

    # ------------------------------------------------------------- loop
    def step(self):
        """One scheduler tick: form a wave, execute it, route outcomes.
        Returns the numpy ``WaveOut`` or ``None`` for an idle tick."""
        self.tick += 1
        t0 = time.perf_counter()
        formed = self.former.form(self.tick)
        if formed is None:
            self.idle_ticks += 1
            return None
        wave, slots = formed
        self.wave_idx += 1
        self.store, out, self.clock = self._step_wave(wave)
        self.gc.observe(out, int(self.clock))
        self.history.append((np.asarray(wave.tid), out))
        self.executions += len(slots)
        for i, req in enumerate(slots):
            if out.status[i] == COMMITTED:
                req.status = "committed"
                req.commit_tick = self.tick
                req.s, req.c = int(out.s[i]), int(out.c[i])
                self.committed += 1
                self.latencies.append(req.latency)
            else:
                delay = self.retry.next_delay(req.attempts, self.rng)
                if delay is None:
                    req.status = "dropped"
                    self.dropped += 1
                else:
                    self.retries += 1
                    self.former.requeue(req, self.tick + delay)
        self._wall_s += time.perf_counter() - t0
        return out

    def _step_wave(self, wave):
        """Dispatch one formed wave to the configured data plane."""
        if self.mesh is None:
            return step_wave(
                self.store, wave, self.wave_idx, self.clock, sched=self.sched,
                n_nodes=self.n_nodes, host_skew=self.host_skew,
                watermark=self.gc.watermark(), gc_block=self.gc.block,
                kernels=self.kernels)
        from repro.core.dist_engine import mesh_watermark, step_wave_dist
        # decentralized GC watermark: per-node live-reader floors merged by
        # a pmin collective on the mesh, never a host-side reduction; with
        # no pins the engine's own wave-boundary collapse applies (None)
        wm = None
        if self.gc.pinned:
            wm = mesh_watermark(self.mesh,
                                self.gc.node_floors(self.mesh.devices.size))
        return step_wave_dist(
            self.store, wave, self.wave_idx, self.clock, self.mesh,
            sched=self.sched, n_nodes=self.n_nodes, host_skew=self.host_skew,
            watermark=wm, gc_block=self.gc.block, kernels=self.kernels)

    def drain(self, max_ticks: Optional[int] = None) -> int:
        """Run ticks until no request is pending (or the safety cap).
        Returns the number of ticks consumed."""
        if max_ticks is None:
            max_ticks = (self.retry.worst_case_ticks()
                         + self.former.pending() // max(self.T, 1) + 8)
        n = 0
        while self.former.pending() and n < max_ticks:
            self.step()
            n += 1
        return n

    def run_stream(self, arrivals: Iterable[int],
                   txn_gen: Callable[[], tuple], drain: bool = True):
        """Feed ``arrivals[t]`` fresh requests per tick (from ``txn_gen``,
        which returns ``(op_kind, op_key, op_val, host)``), stepping once
        per tick; optionally drain the backlog afterwards."""
        for n_arr in arrivals:
            for _ in range(int(n_arr)):
                self.submit(*txn_gen())
            self.step()
        if drain:
            self.drain()
        return self.report()

    # ------------------------------------------------------------ output
    def report(self) -> ServiceReport:
        wall = max(self._wall_s, 1e-9)
        admitted = self.former.admitted
        return ServiceReport(
            sched=self.sched,
            offered=len(self.requests),
            admitted=admitted,
            rejected=self.former.rejected,
            committed=self.committed,
            dropped=self.dropped,
            retries=self.retries,
            executions=self.executions,
            waves=self.wave_idx,
            idle_ticks=self.idle_ticks,
            wall_s=round(wall, 6),
            txns_per_sec=round(self.executions / wall, 1),
            goodput_tps=round(self.committed / wall, 1),
            retry_rate=round(self.retries / max(admitted, 1), 4),
            latency_p50=_pct(self.latencies, 50),
            latency_p95=_pct(self.latencies, 95),
            latency_p99=_pct(self.latencies, 99),
            evicted_visible=self.gc.evicted_visible,
            gc=self.gc.report(),
        )

    def verify(self) -> List[str]:
        """Post-hoc correctness of the served history: SI (or CV) validity
        plus final-store-matches-serial-replay, via ``repro.core.verify``."""
        check = verify_cv if self.sched == "cv" else verify_si
        errors = check(self.history)
        errors += final_values_ok(self.store, self.history, self.n_keys)
        return errors


def smallbank_txn_gen(rng: np.random.RandomState, n_nodes: int,
                      keys_per_node: int, dist_frac: float = 0.2,
                      hot_frac: float = 0.0, hot_per_node: int = 20):
    """Request factory for ``run_stream``: SmallBank transactions on random
    host nodes (the open-stream analogue of ``workloads.smallbank_waves``)."""
    def gen():
        host = int(rng.randint(0, n_nodes))
        op_kind, op_key, op_val = smallbank_txn(
            rng, host, n_nodes, keys_per_node, dist_frac, hot_frac,
            hot_per_node)
        return op_kind, op_key, op_val, host
    return gen
