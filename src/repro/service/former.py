"""Open-stream wave former (DESIGN.md §8, §12).

The fused engine consumes fixed-shape ``[T, O]`` waves; an open system
produces a ragged request stream.  The wave former is the adapter: it holds
bounded *per-tenant* ready queues (admission control — a request arriving
to its tenant's full queue is **rejected**, the load-shedding answer an
open system must give), per-tenant retry calendars ordered by
earliest-eligible tick, and packs up to ``T`` transactions per tick into a
wave, padding the tail with NOP rows so the jitted engine never recompiles.

Fairness (DESIGN.md §12.1): slots are granted by deficit round-robin over
weighted tenant quotas.  Each forming pass deals every backlogged tenant a
quantum ``T * w_i / sum(w)``; a tenant spends whole-slot deficits in
round-robin order, and leftover capacity is filled work-conservingly from
any backlogged tenant (uncharged).  Due retries are packed **before**
fresh arrivals *within* a tenant — a transaction that already burned
scheduler work has priority over new load — but a tenant's retries can
never overdraw another tenant's quota.  With a single (default) tenant the
whole mechanism degenerates to the original global retries-first FIFO.

Write-hot mitigation (DESIGN.md §12.2): when ``fold_rmw`` is on, requests
whose single active op is an RMW on the same (tenant, host, key) are
*folded* into one wave row carrying the summed delta — the engine's RMW is
``val_new = r_val + op_val`` (commutative, associative), so one folded row
commits the same final value the members would reach serially via
lost-update retries.  Members ride free (no slot, no deficit charge) and
fan back out on retire with the leader's outcome.

TIDs are a contiguous ``arange`` per wave — the engine's commit phase maps
newest-version creators to wave-local slots by ``tid - tid[0]``
(``commit_phase.creator_slots``), so the former owns the TID counter and
every retry executes under a fresh TID, as the paper's rules require.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional, Tuple
from dataclasses import field

import numpy as np

from repro.core.engine import Wave
from repro.core.commit_phase import NOP, RMW

_I32_MIN, _I32_MAX = -(2 ** 31), 2 ** 31 - 1


@dataclasses.dataclass
class TxnRequest:
    """One client transaction riding the closed loop."""
    req_id: int
    op_kind: np.ndarray          # [O] int32
    op_key: np.ndarray           # [O] int32
    op_val: np.ndarray           # [O] int32
    host: int
    arrive_tick: int = -1        # set at admission
    attempts: int = 0            # executions so far
    tid: int = -1                # TID of the latest execution
    tids: List[int] = field(default_factory=list)  # TID of every execution
    status: str = "new"          # new|queued|inflight|committed|dropped|rejected
    commit_tick: int = -1
    s: int = -1                  # induced interval of the committed run
    c: int = -1
    replica: bool = False        # served from a hot-key read replica
                                 # (s == c == replica floor, never entered
                                 # the engine)
    tenant: int = 0              # admission/fairness class (DESIGN.md §12)
    folded: List["TxnRequest"] = field(default_factory=list)
                                 # same-key RMW members riding this leader's
                                 # wave row; empty unless fold_rmw packed it

    @property
    def latency(self) -> int:
        """End-to-end ticks from admission to commit (incl. the commit
        tick); -1 until committed."""
        if self.status != "committed":
            return -1
        return self.commit_tick - self.arrive_tick + 1


def fold_counts(slots: List["TxnRequest"], T: int) -> np.ndarray:
    """[T] int32 request multiplicity per wave row: 1 + folded members for
    occupied rows, 0 for NOP padding.  Logged alongside each WAL block so
    recovery can account fan-out without re-deriving fold groups; replay
    itself is untouched — the folded row IS what executed."""
    fold = np.zeros(T, np.int32)
    for i, req in enumerate(slots):
        fold[i] = 1 + len(req.folded)
    return fold


class _TenantQueue:
    """One tenant's admission queue + retry calendar + DRR deficit."""

    __slots__ = ("weight", "max_queue", "ready", "retry", "deficit",
                 "admitted", "rejected", "_seq")

    def __init__(self, weight: float, max_queue: int):
        self.weight = float(weight)
        self.max_queue = int(max_queue)
        self.ready: deque = deque()       # admitted, eligible now (FIFO)
        self.retry: list = []             # heap: (eligible_tick, seq, req)
        self.deficit = 0.0
        self.admitted = 0
        self.rejected = 0
        self._seq = 0

    def due(self, tick: int) -> bool:
        return bool(self.ready) or bool(self.retry
                                        and self.retry[0][0] <= tick)

    def pop(self, tick: int) -> TxnRequest:
        """Next eligible request: due retries before fresh arrivals."""
        if self.retry and self.retry[0][0] <= tick:
            return heapq.heappop(self.retry)[2]
        return self.ready.popleft()

    def push_retry(self, req: TxnRequest, eligible_tick: int) -> None:
        self._seq += 1
        heapq.heappush(self.retry, (eligible_tick, self._seq, req))

    def backlog(self, tick: int) -> int:
        return len(self.ready) + sum(1 for t, _, _ in self.retry if t <= tick)

    def pending(self) -> int:
        return len(self.ready) + len(self.retry)


class WaveFormer:
    """Admission control + retry calendars + fixed-shape wave packing,
    multiplexed over weighted tenants (deficit round-robin)."""

    def __init__(self, T: int, O: int, max_queue: Optional[int] = None,
                 next_tid: int = 1,
                 tenants: Optional[Dict[int, float]] = None,
                 fold_rmw: bool = False, fold_max: int = 256,
                 auto_tenant_cap: int = 64):
        self.T, self.O = T, O
        self.max_queue = 4 * T if max_queue is None else max_queue
        self.next_tid = next_tid
        self.fold_rmw = bool(fold_rmw)
        self.fold_max = int(fold_max)     # max requests per folded row
        self.fold_groups = 0              # wave rows that carried a fold
        self.folded_requests = 0          # member requests that rode free
        self._tenants: Dict[int, _TenantQueue] = {}
        self._order: List[int] = []       # round-robin rotation of tenant ids
        self._rr = 0                      # rotation cursor (advances per form)
        # the tenant tag space must stay BOUNDED: with an explicit map only
        # registered tenants may admit; without one, tags auto-register at
        # weight 1 up to ``auto_tenant_cap`` — otherwise every spurious tag
        # would grow admission capacity and dilute real tenants' DRR quotas
        self._explicit = bool(tenants)
        self.auto_tenant_cap = int(auto_tenant_cap)
        self._unknown_rejects: Dict[int, int] = {}   # shed-at-tag counters
        if tenants:
            for t, w in tenants.items():
                self._register(int(t), float(w))

    # --------------------------------------------------------- tenants
    def _register(self, tenant: int, weight: float = 1.0) -> _TenantQueue:
        q = _TenantQueue(weight, self.max_queue)
        self._tenants[tenant] = q
        self._order.append(tenant)
        return q

    def _queue_of(self, tenant: int) -> _TenantQueue:
        q = self._tenants.get(tenant)
        if q is None:                     # unknown tenants join at weight 1
            q = self._register(tenant)
        return q

    def tenant_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-tenant admission counters for ServiceReport.  Unregistered
        tags that were shed at admission report at weight 0 with no queue."""
        rows = {t: {"weight": q.weight, "admitted": q.admitted,
                    "rejected": q.rejected, "pending": q.pending()}
                for t, q in self._tenants.items()}
        for t, n in self._unknown_rejects.items():
            rows.setdefault(t, {"weight": 0.0, "admitted": 0,
                                "rejected": n, "pending": 0})
        return dict(sorted(rows.items()))

    # aggregating views keep the single-tenant API of the original former
    @property
    def admitted(self) -> int:
        return sum(q.admitted for q in self._tenants.values())

    @property
    def rejected(self) -> int:
        return (sum(q.rejected for q in self._tenants.values())
                + sum(self._unknown_rejects.values()))

    # --------------------------------------------------------- admission
    def offer(self, req: TxnRequest, tick: int) -> bool:
        """Admit a fresh arrival, or shed it when its tenant's queue is
        full.  Admission is judged per tenant: one tenant flooding its
        bounded queue cannot evict or block another tenant's arrivals.
        Unregistered tenant tags are shed without creating a queue when an
        explicit tenant map was configured (or past ``auto_tenant_cap``)."""
        assert req.op_kind.shape == (self.O,), (req.op_kind.shape, self.O)
        q = self._tenants.get(req.tenant)
        if q is None:
            if self._explicit or len(self._tenants) >= self.auto_tenant_cap:
                req.status = "rejected"
                self._unknown_rejects[req.tenant] = \
                    self._unknown_rejects.get(req.tenant, 0) + 1
                return False
            q = self._register(req.tenant)
        if len(q.ready) >= q.max_queue:
            req.status = "rejected"
            q.rejected += 1
            return False
        req.status = "queued"
        req.arrive_tick = tick
        q.admitted += 1
        q.ready.append(req)
        return True

    def requeue(self, req: TxnRequest, eligible_tick: int) -> None:
        """Put an aborted transaction on its tenant's retry calendar (no
        admission check — it already holds a slot in the system)."""
        req.status = "queued"
        self._queue_of(req.tenant).push_retry(req, eligible_tick)

    # ----------------------------------------------------------- packing
    def backlog(self, tick: int) -> int:
        """Transactions eligible to run at ``tick`` (ready + due retries)."""
        return sum(q.backlog(tick) for q in self._tenants.values())

    def pending(self) -> int:
        """All transactions still inside the former, due or not."""
        return sum(q.pending() for q in self._tenants.values())

    def _fold_slot(self, req: TxnRequest) -> Optional[int]:
        """Op index if ``req`` is foldable (exactly one active op, an RMW);
        None otherwise."""
        active = req.op_kind != NOP
        n = int(active.sum())
        if n != 1:
            return None
        o = int(np.argmax(active))
        return o if int(req.op_kind[o]) == RMW else None

    def _pack(self, req: TxnRequest, slots: List[TxnRequest],
              folds: Dict[Tuple[int, int, int], List[int]]) -> bool:
        """Place ``req``: either fold it onto an existing leader (returns
        False — no slot consumed) or append it as a new row (True).

        ``folds`` maps the group key to ``[leader row, running delta]``; a
        member joins only while the group is under ``fold_max`` AND the
        summed delta stays inside int32 — the engine's RMW adds int32s, so
        a wrapping fold would commit a value no serial (unfolded) execution
        could produce.  An over-cap/overflow request starts a new leader."""
        if self.fold_rmw:
            o = self._fold_slot(req)
            if o is not None:
                d = int(req.op_val[o])
                gk = (req.tenant, int(req.host), int(req.op_key[o]))
                ent = folds.get(gk)
                if ent is not None:
                    li, total = ent
                    if (len(slots[li].folded) + 1 < self.fold_max
                            and _I32_MIN <= total + d <= _I32_MAX):
                        slots[li].folded.append(req)
                        ent[1] = total + d
                        return False
                folds[gk] = [len(slots), d]   # this row becomes the leader
        req.folded = []
        slots.append(req)
        return True

    def form(self, tick: int,
             T: Optional[int] = None) -> Optional[Tuple[Wave, List[TxnRequest]]]:
        """Pack one wave for ``tick``; ``None`` when nothing is eligible.

        Returns ``(wave, slots)``: ``slots[i]`` is the request in wave row
        ``i`` (the NOP padding rows have no request and always commit
        vacuously — the service skips them when reading outcomes).  When
        folding is on, ``slots[i].folded`` lists member requests riding
        that row; the service fans the row outcome out to them on retire.

        ``T`` overrides the wave size for this call — the contention-adaptive
        streaming driver resizes waves on a bounded ladder (DESIGN.md §8);
        every distinct T is a distinct jitted engine shape.

        Slot grant is deficit round-robin: backlogged tenants split ``T``
        by weight (deficits bank across calls, capped at one wave), then a
        work-conserving pass fills leftover rows from any backlog."""
        T = self.T if T is None else T
        order = self._order
        if not order:
            return None
        n = len(order)
        rr = self._rr % n
        rotation = [order[(rr + j) % n] for j in range(n)]
        active = [t for t in rotation if self._tenants[t].due(tick)]
        if not active:
            return None
        self._rr += 1

        # deal quanta: backlogged tenants share T by weight; idle tenants
        # forfeit their deficit (classic DRR — no banking while idle)
        w_sum = sum(self._tenants[t].weight for t in active) or 1.0
        for t in order:
            q = self._tenants[t]
            if q.due(tick):
                q.deficit = min(q.deficit + T * q.weight / w_sum, float(T))
            else:
                q.deficit = 0.0

        slots: List[TxnRequest] = []
        folds: Dict[Tuple[int, int, int], List[int]] = {}
        # quota pass: spend whole-slot deficits in round-robin order
        for t in active:
            q = self._tenants[t]
            while len(slots) < T and q.deficit >= 1.0 and q.due(tick):
                if self._pack(q.pop(tick), slots, folds):
                    q.deficit -= 1.0
        # work-conserving pass: leftover rows go to any backlog, round-robin
        # one request at a time, uncharged (spare capacity is nobody's quota)
        while len(slots) < T:
            served = False
            for t in active:
                if len(slots) >= T:
                    break
                q = self._tenants[t]
                if q.due(tick):
                    self._pack(q.pop(tick), slots, folds)
                    served = True
            if not served:
                break
        if not slots:
            return None

        O = self.O
        op_kind = np.full((T, O), NOP, np.int32)
        op_key = np.zeros((T, O), np.int32)
        op_val = np.zeros((T, O), np.int32)
        host = np.zeros(T, np.int32)
        tid0 = self.next_tid
        self.next_tid += T                     # padding rows burn TIDs too
        for i, req in enumerate(slots):
            op_kind[i] = req.op_kind
            op_key[i] = req.op_key
            op_val[i] = req.op_val
            host[i] = req.host
            if req.folded:
                o = self._fold_slot(req)
                # each member's delta lives at ITS OWN active op index —
                # groups form by (tenant, host, key), never by op slot, so
                # reading the leader's slot would drop any member whose RMW
                # sits elsewhere (a silent lost update)
                delta = sum(int(m.op_val[self._fold_slot(m)])
                            for m in req.folded)
                op_val[i, o] = np.int32(int(req.op_val[o]) + delta)
                self.fold_groups += 1
                self.folded_requests += len(req.folded)
            for r in (req, *req.folded):
                r.tid = tid0 + i
                r.tids.append(r.tid)
                r.attempts += 1
                r.status = "inflight"
        # numpy leaves on purpose: the wave crosses to the device exactly
        # once — at the jit boundary of the step dispatch, or in one
        # [B,T,O] block transfer by the streaming driver's stacker; eager
        # per-wave device_puts were the service plane's biggest host cost
        wave = Wave(op_kind=op_kind, op_key=op_key, op_val=op_val, host=host,
                    tid=(tid0 + np.arange(T)).astype(np.int32))
        return wave, slots
