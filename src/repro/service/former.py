"""Open-stream wave former (DESIGN.md §8).

The fused engine consumes fixed-shape ``[T, O]`` waves; an open system
produces a ragged request stream.  The wave former is the adapter: it holds
a bounded ready queue (admission control — a request arriving to a full
queue is **rejected**, the load-shedding answer an open system must give),
a retry calendar ordered by earliest-eligible tick, and packs up to ``T``
transactions per tick into a wave, padding the tail with NOP rows so the
jitted engine never recompiles.  Due retries are packed **before** fresh
arrivals: a transaction that already burned scheduler work has priority
over new load (no starvation under saturation).

TIDs are a contiguous ``arange`` per wave — the engine's commit phase maps
newest-version creators to wave-local slots by ``tid - tid[0]``
(``commit_phase.creator_slots``), so the former owns the TID counter and
every retry executes under a fresh TID, as the paper's rules require.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import List, Optional, Tuple
from dataclasses import field

import numpy as np

from repro.core.engine import Wave
from repro.core.commit_phase import NOP


@dataclasses.dataclass
class TxnRequest:
    """One client transaction riding the closed loop."""
    req_id: int
    op_kind: np.ndarray          # [O] int32
    op_key: np.ndarray           # [O] int32
    op_val: np.ndarray           # [O] int32
    host: int
    arrive_tick: int = -1        # set at admission
    attempts: int = 0            # executions so far
    tid: int = -1                # TID of the latest execution
    tids: List[int] = field(default_factory=list)  # TID of every execution
    status: str = "new"          # new|queued|inflight|committed|dropped|rejected
    commit_tick: int = -1
    s: int = -1                  # induced interval of the committed run
    c: int = -1
    replica: bool = False        # served from a hot-key read replica
                                 # (s == c == replica floor, never entered
                                 # the engine)

    @property
    def latency(self) -> int:
        """End-to-end ticks from admission to commit (incl. the commit
        tick); -1 until committed."""
        if self.status != "committed":
            return -1
        return self.commit_tick - self.arrive_tick + 1


class WaveFormer:
    """Admission control + retry calendar + fixed-shape wave packing."""

    def __init__(self, T: int, O: int, max_queue: Optional[int] = None,
                 next_tid: int = 1):
        self.T, self.O = T, O
        self.max_queue = 4 * T if max_queue is None else max_queue
        self.next_tid = next_tid
        self.ready: deque = deque()          # admitted, eligible now (FIFO)
        self._retry: list = []               # heap: (eligible_tick, seq, req)
        self._seq = 0
        self.rejected = 0
        self.admitted = 0

    # --------------------------------------------------------- admission
    def offer(self, req: TxnRequest, tick: int) -> bool:
        """Admit a fresh arrival, or shed it when the queue is full."""
        assert req.op_kind.shape == (self.O,), (req.op_kind.shape, self.O)
        if len(self.ready) >= self.max_queue:
            req.status = "rejected"
            self.rejected += 1
            return False
        req.status = "queued"
        req.arrive_tick = tick
        self.admitted += 1
        self.ready.append(req)
        return True

    def requeue(self, req: TxnRequest, eligible_tick: int) -> None:
        """Put an aborted transaction on the retry calendar (no admission
        check — it already holds a slot in the system)."""
        req.status = "queued"
        self._seq += 1
        heapq.heappush(self._retry, (eligible_tick, self._seq, req))

    # ----------------------------------------------------------- packing
    def backlog(self, tick: int) -> int:
        """Transactions eligible to run at ``tick`` (ready + due retries)."""
        return len(self.ready) + sum(1 for t, _, _ in self._retry if t <= tick)

    def pending(self) -> int:
        """All transactions still inside the former, due or not."""
        return len(self.ready) + len(self._retry)

    def form(self, tick: int,
             T: Optional[int] = None) -> Optional[Tuple[Wave, List[TxnRequest]]]:
        """Pack one wave for ``tick``; ``None`` when nothing is eligible.

        Returns ``(wave, slots)``: ``slots[i]`` is the request in wave row
        ``i`` (the NOP padding rows have no request and always commit
        vacuously — the service skips them when reading outcomes).

        ``T`` overrides the wave size for this call — the contention-adaptive
        streaming driver resizes waves on a bounded ladder (DESIGN.md §8);
        every distinct T is a distinct jitted engine shape."""
        T = self.T if T is None else T
        slots: List[TxnRequest] = []
        while len(slots) < T and self._retry and self._retry[0][0] <= tick:
            slots.append(heapq.heappop(self._retry)[2])
        while len(slots) < T and self.ready:
            slots.append(self.ready.popleft())
        if not slots:
            return None

        O = self.O
        op_kind = np.full((T, O), NOP, np.int32)
        op_key = np.zeros((T, O), np.int32)
        op_val = np.zeros((T, O), np.int32)
        host = np.zeros(T, np.int32)
        tid0 = self.next_tid
        self.next_tid += T                     # padding rows burn TIDs too
        for i, req in enumerate(slots):
            op_kind[i] = req.op_kind
            op_key[i] = req.op_key
            op_val[i] = req.op_val
            host[i] = req.host
            req.tid = tid0 + i
            req.tids.append(req.tid)
            req.attempts += 1
            req.status = "inflight"
        # numpy leaves on purpose: the wave crosses to the device exactly
        # once — at the jit boundary of the step dispatch, or in one
        # [B,T,O] block transfer by the streaming driver's stacker; eager
        # per-wave device_puts were the service plane's biggest host cost
        wave = Wave(op_kind=op_kind, op_key=op_key, op_val=op_val, host=host,
                    tid=(tid0 + np.arange(T)).astype(np.int32))
        return wave, slots
