"""Pipelined streaming service plane (DESIGN.md §8).

``TxnService.step`` syncs the host after every single wave: form → dispatch
→ block on the device → route outcomes.  At service wave sizes the dispatch
plus host round-trip dominates the wave's own device time, so the step loop
measures coordination overhead, not the concurrency-control rules — the
exact failure mode the paper's decentralization argument is about.  This
module amortizes it the BOHM way: batch waves into *blocks* and pipeline
block formation against block execution.

    arrivals ─> WaveFormer ─> [wave,wave,..B] ─> run_block (ONE lax.scan
                   ^            block buffer      device program)
                   │                                   │  ≤ K-1 blocks
                   │                                   ▼  dispatched, unsynced
                   └──── RetryPolicy ◄──── retire: np.asarray(outs) syncs,
                                           routes per-wave outcomes

Two levers, both bounded so the jitted engine sees a small closed set of
shapes:

* **B — block size.**  Up to B formed ``[T, O]`` waves are stacked and
  executed as ONE device program (``engine.run_block``: ``lax.scan`` with
  (store, clock) carry, the §7 fused executor made resumable).  One
  dispatch + one host sync per B waves instead of per wave; a partially
  filled buffer ships as power-of-two-sized blocks (3 waves → [2]+[1]),
  never as NOP filler, so the engine sees at most log2(B)+1 block shapes
  and every dispatched wave carries real work.
* **K — pipeline depth.**  A dispatched block is not synced until K-1
  further blocks have been dispatched: under JAX async dispatch the
  returned arrays are futures, so the host forms (and dispatches, chaining
  on the store/clock futures) the next blocks while the device runs.
  "K in flight" means exactly that — K dispatched-but-unretired device
  programs — not K independent executors; the device still runs blocks in
  order, the overlap is host-side forming/routing against device compute.

With ``B=1, K=1`` the plane degenerates to the synchronous step loop and is
bit-identical to it (tests/test_streaming.py).  With B>1 retries route at
block granularity (an abort in wave j of a block re-enters only after the
whole block retires), so histories are commit-set-equal modulo retry
timing, and every invariant — commit-or-drop, SI/CV validity, GC watermark
safety — holds unchanged.

**Contention-adaptive wave sizing** (paper §V-D): ``AdaptiveWaveSizer``
regulates the wave size T (and optionally B) from the trailing abort rate
with bounded AIMD — additive increase by one ``quantum`` rung when the
stream is calm, multiplicative (halving) decrease when aborts exceed the
high-water threshold.  All sizes live on the ladder of quantum multiples in
``[t_min, t_max]``, so recompiles are bounded by the ladder length, not the
stream length.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

import jax
import numpy as np

from repro.core import ABORTED, Wave, WaveOut

from .former import fold_counts


def _stack_np(waves: List[Wave]) -> Wave:
    """Stack numpy-leaved formed waves into one [B, T, O] block on the
    host: a single contiguous copy per field, crossing to the device in one
    transfer at the block dispatch's jit boundary (``engine.stack_waves``
    is its on-device twin for already-transferred replay workloads)."""
    return Wave(*(np.stack([getattr(w, f) for w in waves])
                  for f in Wave._fields))


def _ladder_snap(T: int, quantum: int, t_min: int, t_max: int) -> int:
    """Snap T to the bounded ladder {multiples of quantum} ∩ [t_min, t_max],
    with t_max itself always a rung — an off-quantum ceiling (e.g. T0=12 on
    a quantum-8 ladder) must stay reachable or additive increase could
    never restore the configured wave size."""
    T = max(t_min, min(t_max, T))
    if T == t_max:
        return t_max
    return max(t_min, (T // quantum) * quantum)


class AdaptiveWaveSizer:
    """Bounded-AIMD wave sizing from the trailing abort rate.

    Observes per-wave (executed, aborted) counts; once ``window`` executions
    accumulate it compares the trailing abort rate against two thresholds:

    * rate > ``high``  →  multiplicative decrease: T ← max(t_min, T/2),
      snapped to the quantum ladder — smaller waves put fewer concurrent
      writers on the hot keys, which is the §V-D contention regulation
      (fewer conflicts per wave ⇒ fewer aborts ⇒ less retry re-traffic);
    * rate < ``low``   →  additive increase: T ← min(t_max, T + quantum) —
      probe back toward full parallelism one rung at a time.

    The trailing window resets after every adjustment so decisions are made
    on post-change evidence only.  With ``adapt_B=True`` the block size
    rides the same signal on a halving ladder in [b_min, B0]: high abort
    rates shorten the pipeline's feedback delay (retries see fresher store
    state), calm streams restore full fusion.
    """

    def __init__(self, T0: int, B0: int = 1, t_min: int = 8,
                 t_max: Optional[int] = None, high: float = 0.35,
                 low: float = 0.10, window: int = 128,
                 quantum: Optional[int] = None, adapt_B: bool = False,
                 b_min: int = 1):
        if not 0.0 <= low < high <= 1.0:
            raise ValueError(f"need 0 <= low < high <= 1, got {low}/{high}")
        self.t_min = t_min
        self.t_max = T0 if t_max is None else t_max
        if self.t_max < self.t_min:
            raise ValueError(f"empty ladder: t_max={self.t_max} < "
                             f"t_min={self.t_min}")
        self.quantum = t_min if quantum is None else quantum
        self.high, self.low, self.window = high, low, window
        self.adapt_B, self.b_min = adapt_B, b_min
        self.B0 = B0
        self.T = _ladder_snap(T0, self.quantum, self.t_min, self.t_max)
        self.B = B0
        self._exec = 0
        self._abort = 0
        self.decreases = 0     # MD events (contention reactions)
        self.increases = 0     # AI events (recovery probes)

    def observe(self, executed: int, aborted: int) -> None:
        """Fold one retired wave's counts in; adjust at window boundaries."""
        self._exec += executed
        self._abort += aborted
        if self._exec < self.window:
            return
        rate = self._abort / self._exec
        if rate > self.high:
            self.T = _ladder_snap(self.T // 2, self.quantum, self.t_min,
                                  self.t_max)
            if self.adapt_B:
                self.B = max(self.b_min, self.B // 2)
            self.decreases += 1
        elif rate < self.low:
            self.T = _ladder_snap(self.T + self.quantum, self.quantum,
                                  self.t_min, self.t_max)
            if self.adapt_B:
                self.B = min(self.B0, max(self.b_min, self.B * 2))
            self.increases += 1
        else:
            # deadband: stay put, but shrink the counters back to one
            # window's worth so the rate stays *trailing* — an unbounded
            # cumulative average would react to a later contention spike
            # thousands of executions late instead of within ~one window
            scale = self.window / self._exec
            self._abort = int(round(self._abort * scale))
            self._exec = self.window
            return
        self._exec = self._abort = 0    # decide on post-adjustment data only

    def abort_rate(self) -> float:
        """Trailing abort rate of the (possibly partial) current window."""
        return self._abort / self._exec if self._exec else 0.0


@dataclasses.dataclass
class _Block:
    """One dispatched-but-unretired block: device futures + host metadata."""
    outs: WaveOut                               # device, leading [B] axis
    clock: jax.Array                            # device scalar after block
    waves: List[Tuple[np.ndarray, list]]        # per wave: (tids, slots)
    stacked: Wave                               # numpy [B,T,O] block input
    wave_idx0: int                              # wave-index origin at dispatch
    wm: object = None                           # GC watermark at dispatch


class StreamingDriver:
    """K-blocks-in-flight pump between a ``TxnService`` and the fused block
    engine.  One instance per ``run_streaming`` session; the service owns
    all request/GC/latency state, the driver owns only the pipeline."""

    def __init__(self, svc, B: int = 4, K: int = 2,
                 sizer: Optional[AdaptiveWaveSizer] = None):
        if B < 1 or K < 1:
            raise ValueError(f"need B >= 1 and K >= 1, got B={B} K={K}")
        self.svc = svc
        self.B, self.K = B, K
        self.sizer = sizer
        self._buf: List[Tuple[Wave, list]] = []   # block under formation
        self._buf_T: Optional[int] = None         # its wave size (fixed/blk)
        self._buf_B: Optional[int] = None         # its block size (fixed/blk)
        self._inflight: Deque[_Block] = deque()

    # ---------------------------------------------------------------- pump
    def tick(self) -> None:
        """One scheduler tick: form up to B waves into the open block (the
        step loop forms exactly one per tick; the pipeline may catch up on
        backlog), dispatch when it reaches B.  On an arrival gap the partial
        block is held while the device is busy (retiring one finished block
        instead, which feeds retries back to the former) and shipped only
        when the pipeline is empty — the device never idles behind a
        hoarded buffer, and no tick ships NOP filler.

        With a hybrid planner attached (DESIGN.md §10) and in planned mode,
        the pipeline is first drained (planned lanes must see every earlier
        wave's commits — and routed retries re-enter before the planner
        forms) and the tick is served synchronously through the service's
        planned step path; when the policy drops back to optimistic the
        pipelined path resumes on the next tick."""
        svc = self.svc
        if svc.planner is not None and svc.planner.planned:
            self.flush()
            svc.step()
            return
        svc.tick += 1
        t0 = time.perf_counter()
        if self._buf_T is None:            # block boundary: propose sizes
            self._buf_T = self.sizer.T if self.sizer else svc.T
            self._buf_B = (self.sizer.B if self.sizer and self.sizer.adapt_B
                           else self.B)    # sizer owns B only when adapting
        formed_n = 0
        while len(self._buf) < self._buf_B:
            if formed_n and svc.former.backlog(svc.tick) < self._buf_T:
                break              # catch-up waves beyond the first must be
                                   # full-T: thin waves waste device slots
            formed = svc.former.form(svc.tick, T=self._buf_T)
            if formed is None:
                break
            self._buf.append(formed)
            formed_n += 1
        if len(self._buf) == self._buf_B:
            self._dispatch()               # full block: ship it
        elif self._buf:
            if self._inflight:
                # hold the partial; feed retries (tick-level retire: the
                # one place an injected delay_retire may stall)
                self._retire_one(allow_delay=True)
            else:
                self._dispatch()           # device idle: ship what we have
        else:
            self._buf_T = self._buf_B = None   # no open block: re-propose
            svc.idle_ticks += 1
            if self._inflight:             # nothing to form: drain the pipe
                self._retire_one(allow_delay=True)
        svc._wall_s += time.perf_counter() - t0

    def flush(self) -> None:
        """Ship the partial block and sync every in-flight block."""
        t0 = time.perf_counter()
        if self._buf:
            self._dispatch(retire_to=0)
        while self._inflight:
            self._retire_one()
        self.svc._wall_s += time.perf_counter() - t0

    def drain(self, max_ticks: Optional[int] = None) -> int:
        """Tick until no request is pending anywhere (former, open block,
        pipeline) or the safety cap; returns ticks consumed."""
        svc = self.svc
        if max_ticks is None:
            max_ticks = (svc.retry.worst_case_ticks()
                         + svc.former.pending() // max(svc.T, 1)
                         + self.K * self.B + 16)
        n = 0
        while (svc.former.pending() or self._buf or self._inflight) \
                and n < max_ticks:
            self.tick()
            n += 1
        self.flush()
        return n

    # ------------------------------------------------------------ internals
    def _dispatch(self, retire_to: Optional[int] = None) -> None:
        """Ship the buffered waves as power-of-two-sized blocks, largest
        first (a full buffer with power-of-two B is exactly one [B,T,O]
        program; a partial one splits, e.g. 3 waves → [2]+[1]) — every
        dispatched wave carries real work and the engine sees a closed set
        of at most log2(B)+1 shapes per T.  Then retire until at most
        ``retire_to`` (default K-1) blocks remain unsynced."""
        svc = self.svc
        while self._buf:
            b = 1 << (len(self._buf).bit_length() - 1)   # max pow2 <= len
            chunk, self._buf = self._buf[:b], self._buf[b:]
            meta = [(np.asarray(w.tid), slots) for w, slots in chunk]
            stacked = _stack_np([w for w, _ in chunk])
            outs, clock = svc._run_block(stacked)
            wave_idx0, wm = svc._last_dispatch
            if svc.faults is not None:
                svc.faults.at_dispatch(svc)   # kill: launched, not durable
            self._inflight.append(
                _Block(outs, clock, meta, stacked, wave_idx0, wm))
            svc.blocks += 1
        self._buf_T = self._buf_B = None
        limit = (self.K - 1) if retire_to is None else retire_to
        while len(self._inflight) > limit:
            self._retire_one()

    def _retire_one(self, allow_delay: bool = False) -> None:
        """Sync the oldest in-flight block (the pipeline's only blocking
        point), WAL-log it when a durability manager is attached
        (durable-before-ack), then route its per-wave outcomes through the
        service.  ``allow_delay`` marks tick-level calls — the only ones a
        ``delay_retire`` fault may skip; the dispatch loop's K-limit drain
        always completes, so an armed delay stalls the pipeline but can
        never deadlock it."""
        svc = self.svc
        if allow_delay and svc.faults is not None \
                and svc.faults.delay_retire(svc):
            return                       # injected straggler: hold the block
        if svc.faults is not None:
            svc.faults.at_retire(svc)    # kill: computed, never logged/acked
        blk = self._inflight.popleft()
        outs = jax.tree_util.tree_map(np.asarray, blk.outs)   # device sync
        clock = int(blk.clock)
        per_wave = []
        for j, (tids, slots) in enumerate(blk.waves):
            out_j = WaveOut(*(leaf[j] for leaf in outs))
            svc.gc.observe(out_j, clock)
            svc.history.append((tids, out_j))
            per_wave.append((out_j, slots))
        if svc.durability is not None:
            # retire point = durability boundary (DESIGN.md §9): one record
            # per retired block, appended before any outcome is acked; the
            # fold multiplicities (DESIGN.md §12.2) ride along so recovery
            # accounts fan-out — computed here, before _route clears them
            T = blk.stacked.op_kind.shape[1]
            fold = np.stack([fold_counts(slots, T)
                             for _, slots in blk.waves])
            svc.durability.log_block(blk.stacked, blk.wave_idx0, blk.wm,
                                     outs, clock, svc.gc.clock, fold=fold)
            if svc.faults is not None:
                svc.faults.post_log(svc)   # kill: durable-but-unacked window
        for out_j, slots in per_wave:
            svc._route(out_j, slots)
            n_abort = int((out_j.status[:len(slots)] == ABORTED).sum())
            if self.sizer is not None:
                self.sizer.observe(len(slots), n_abort)
            if svc.planner is not None:
                svc.planner.observe_optimistic(len(slots), n_abort)
        if svc.durability is not None:
            svc.durability.maybe_snapshot(
                svc, pipeline_empty=not self._inflight and not self._buf)
