"""Visibility-based version GC watermark (DESIGN.md §8).

The paper removes the central timestamp authority; this module removes the
central garbage-collection authority the same way.  The reclamation
watermark is not handed down by a coordinator — it is the **decentralized
min over live readers' ``s_lo``**: a version superseded by a commit at or
below the watermark can never again be the visible version for any live or
future snapshot, so its ring slot may be reused.  (Proof sketch, mirrored by
``tests/test_gc_watermark.py`` against the sequential oracle: a reader that
would still need version ``v`` must take a snapshot ``s`` with
``s < CID(superseder) <= watermark <= s_lo <= s`` — contradiction; PostSI
rule 5 aborts it before it can read ``v``.)

In the wave engine every reader's snapshot is pinned at its wave boundary,
so between waves the min over live readers collapses to the engine clock at
the last boundary — that is the engine's default watermark
(``run_wave(watermark=None)``).  This tracker contributes the parts the
engine cannot see:

* **pins** — external long-lived readers (an s_hi-pinned retry per paper
  §IV-B, a backup/analytics scanner, a clock-skewed host whose snapshot
  lags by ``skew`` waves) register the lowest snapshot they may still take;
  the watermark is the min over all pins and never exceeds the clock.
* **accounting** — the per-wave ``evicted_visible`` counters stream in via
  ``observe`` so the service can report when V (the ring depth) is too
  small for the offered load, and ``block=True`` asks the engine to abort
  the offending writer instead of corrupting a still-visible version.
"""
from __future__ import annotations

import itertools
from typing import Dict, Optional


def seq_watermark(scheduler, pins=()) -> int:
    """The decentralized watermark over a ``repro.core.seq.SeqScheduler``:
    min over running transactions' ``s_lo`` and external ``pins``; with no
    live reader at all it rises to the newest commit time (every future
    reader then resolves to newest versions only).  Versions superseded at
    or below this value are reclaimable — ``tests/test_gc_watermark.py``
    checks that differentially against the oracle's actual reads."""
    lows = [t.s_lo for t in scheduler.txns.values() if t.status == "running"]
    lows += [int(p) for p in pins]
    if lows:
        return min(lows)
    return max((v.cid for chain in scheduler.versions.values()
                for v in chain), default=0)


class VisibilityGC:
    """Watermark tracker + eviction accounting for one service instance."""

    def __init__(self, block: bool = False, n_nodes: Optional[int] = None):
        self.block = block
        self.n_nodes = n_nodes            # mesh node-id bound for pins (opt.)
        self.clock = 0                    # engine clock after the last wave
        self.evicted_visible = 0          # cumulative watermark violations
        self.replica_reads = 0            # reads served at a replica floor
        self.replica_floor = 0            # lowest floor a replica served at
        self._pins: Dict[int, int] = {}   # handle -> pinned snapshot floor
        self._pin_node: Dict[int, int] = {}  # handle -> hosting mesh node
        self._handles = itertools.count(1)

    # ------------------------------------------------------------- pins
    def pin(self, snapshot_floor: int, node: int = 0) -> int:
        """Register a live reader whose snapshot may go as low as
        ``snapshot_floor``; returns a handle for ``release``.  ``node`` is
        the mesh node hosting the reader — on the sharded service the
        watermark is merged *from per-node floors* with a ``lax.pmin``
        collective (``dist_engine.mesh_watermark``), so each pin must name
        where its reader lives; single-device callers can ignore it."""
        if node < 0:
            raise ValueError(f"pin: node must be >= 0, got {node}")
        if self.n_nodes is not None and node >= self.n_nodes:
            # fail at the buggy call, not ticks later inside the serve loop
            raise ValueError(f"pin: node {node} out of range for the "
                             f"{self.n_nodes}-node mesh")
        h = next(self._handles)
        self._pins[h] = int(snapshot_floor)
        self._pin_node[h] = int(node)
        return h

    @property
    def pinned(self) -> bool:
        """True when any live pin exists (the watermark is then lower than
        the engine's own wave-boundary collapse may assume)."""
        return bool(self._pins)

    def release(self, handle: int) -> None:
        self._pins.pop(handle, None)
        self._pin_node.pop(handle, None)

    # -------------------------------------------------------- watermark
    def watermark(self) -> Optional[int]:
        """Current reclamation watermark, or ``None`` when no pins exist —
        the engine then uses its own boundary collapse (the wave-entry
        clock), which is the exact min over its live readers."""
        if not self._pins:
            return None
        return min(min(self._pins.values()), self.clock)

    def node_floors(self, n_nodes: int):
        """Per-node snapshot floors for the decentralized mesh merge: node
        ``k``'s entry is the min floor over its live pinned readers, or the
        engine clock when it hosts none (neutral in the min — the wave
        boundary is every unpinned reader's floor).  ``lax.pmin`` over
        these equals ``watermark()`` by construction."""
        floors = [self.clock] * n_nodes
        for h, f in self._pins.items():
            node = self._pin_node[h]
            if node >= n_nodes:
                raise ValueError(
                    f"pin handle {h} names node {node}, but the mesh has "
                    f"only {n_nodes} node(s)")
            floors[node] = min(floors[node], f)
        return floors

    # ------------------------------------------------------- accounting
    def observe(self, out_np, clock: int) -> None:
        """Fold one wave's outcome into the accounting state."""
        self.clock = int(clock)
        self.evicted_visible += int(out_np.evicted_visible)

    def observe_replica(self, floor: int, n_reads: int = 1) -> None:
        """Account reads served from a hot-key replica at visibility floor
        ``floor`` (DESIGN.md §11): the replica reader's snapshot equals the
        GC watermark, so it needs no pin — versions visible at the floor are
        frozen by the watermark invariant and can never be reclaimed out
        from under it.  Pure accounting; the watermark is unaffected."""
        self.replica_reads += int(n_reads)
        self.replica_floor = int(floor)

    def report(self) -> Dict[str, int]:
        return {
            "evicted_visible": self.evicted_visible,
            "pins": len(self._pins),
            "watermark": self.watermark() if self._pins else self.clock,
            "blocking": int(self.block),
            "replica_reads": self.replica_reads,
            "replica_floor": self.replica_floor,
        }
