"""Visibility-based version GC watermark (DESIGN.md §8).

The paper removes the central timestamp authority; this module removes the
central garbage-collection authority the same way.  The reclamation
watermark is not handed down by a coordinator — it is the **decentralized
min over live readers' ``s_lo``**: a version superseded by a commit at or
below the watermark can never again be the visible version for any live or
future snapshot, so its ring slot may be reused.  (Proof sketch, mirrored by
``tests/test_gc_watermark.py`` against the sequential oracle: a reader that
would still need version ``v`` must take a snapshot ``s`` with
``s < CID(superseder) <= watermark <= s_lo <= s`` — contradiction; PostSI
rule 5 aborts it before it can read ``v``.)

In the wave engine every reader's snapshot is pinned at its wave boundary,
so between waves the min over live readers collapses to the engine clock at
the last boundary — that is the engine's default watermark
(``run_wave(watermark=None)``).  This tracker contributes the parts the
engine cannot see:

* **pins** — external long-lived readers (an s_hi-pinned retry per paper
  §IV-B, a backup/analytics scanner, a clock-skewed host whose snapshot
  lags by ``skew`` waves) register the lowest snapshot they may still take;
  the watermark is the min over all pins and never exceeds the clock.
* **accounting** — the per-wave ``evicted_visible`` counters stream in via
  ``observe`` so the service can report when V (the ring depth) is too
  small for the offered load, and ``block=True`` asks the engine to abort
  the offending writer instead of corrupting a still-visible version.
"""
from __future__ import annotations

import itertools
from typing import Dict, Optional


def seq_watermark(scheduler, pins=()) -> int:
    """The decentralized watermark over a ``repro.core.seq.SeqScheduler``:
    min over running transactions' ``s_lo`` and external ``pins``; with no
    live reader at all it rises to the newest commit time (every future
    reader then resolves to newest versions only).  Versions superseded at
    or below this value are reclaimable — ``tests/test_gc_watermark.py``
    checks that differentially against the oracle's actual reads."""
    lows = [t.s_lo for t in scheduler.txns.values() if t.status == "running"]
    lows += [int(p) for p in pins]
    if lows:
        return min(lows)
    return max((v.cid for chain in scheduler.versions.values()
                for v in chain), default=0)


class VisibilityGC:
    """Watermark tracker + eviction accounting for one service instance."""

    def __init__(self, block: bool = False):
        self.block = block
        self.clock = 0                    # engine clock after the last wave
        self.evicted_visible = 0          # cumulative watermark violations
        self._pins: Dict[int, int] = {}   # handle -> pinned snapshot floor
        self._handles = itertools.count(1)

    # ------------------------------------------------------------- pins
    def pin(self, snapshot_floor: int) -> int:
        """Register a live reader whose snapshot may go as low as
        ``snapshot_floor``; returns a handle for ``release``."""
        h = next(self._handles)
        self._pins[h] = int(snapshot_floor)
        return h

    def release(self, handle: int) -> None:
        self._pins.pop(handle, None)

    # -------------------------------------------------------- watermark
    def watermark(self) -> Optional[int]:
        """Current reclamation watermark, or ``None`` when no pins exist —
        the engine then uses its own boundary collapse (the wave-entry
        clock), which is the exact min over its live readers."""
        if not self._pins:
            return None
        return min(min(self._pins.values()), self.clock)

    # ------------------------------------------------------- accounting
    def observe(self, out_np, clock: int) -> None:
        """Fold one wave's outcome into the accounting state."""
        self.clock = int(clock)
        self.evicted_visible += int(out_np.evicted_visible)

    def report(self) -> Dict[str, int]:
        return {
            "evicted_visible": self.evicted_visible,
            "pins": len(self._pins),
            "watermark": self.watermark() if self._pins else self.clock,
            "blocking": int(self.block),
        }
