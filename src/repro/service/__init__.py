"""Closed-loop transaction service on the decentralized wave engine.

Four cooperating parts (DESIGN.md §8): the open-stream **wave former**
(admission control + fixed-shape packing), the **abort-retry pipeline**
(fresh TIDs, bounded exponential backoff, end-to-end latency tracking),
the **visibility-based GC watermark** (decentralized min over live readers'
``s_lo``, consulted by the store's ring-slot reuse) and the **pipelined
streaming plane** (K-blocks-in-flight fused dispatch with bounded-AIMD
contention-adaptive wave sizing).
"""
from .former import TxnRequest, WaveFormer, fold_counts
from .gc import VisibilityGC, seq_watermark
from .retry import RetryPolicy
from .service import (ServiceReport, TxnService, rmw_txn_gen,
                      smallbank_txn_gen, tenant_txn_gen, ycsb_txn_gen)
from .stream import AdaptiveWaveSizer, StreamingDriver

__all__ = [
    "TxnRequest", "WaveFormer", "VisibilityGC", "RetryPolicy",
    "ServiceReport", "TxnService", "seq_watermark", "smallbank_txn_gen",
    "ycsb_txn_gen", "rmw_txn_gen", "tenant_txn_gen", "fold_counts",
    "AdaptiveWaveSizer", "StreamingDriver",
]
