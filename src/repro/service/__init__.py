"""Closed-loop transaction service on the decentralized wave engine.

Three cooperating parts (DESIGN.md §8): the open-stream **wave former**
(admission control + fixed-shape packing), the **abort-retry pipeline**
(fresh TIDs, bounded exponential backoff, end-to-end latency tracking) and
the **visibility-based GC watermark** (decentralized min over live readers'
``s_lo``, consulted by the store's ring-slot reuse).
"""
from .former import TxnRequest, WaveFormer
from .gc import VisibilityGC, seq_watermark
from .retry import RetryPolicy
from .service import ServiceReport, TxnService, smallbank_txn_gen

__all__ = [
    "TxnRequest", "WaveFormer", "VisibilityGC", "RetryPolicy",
    "ServiceReport", "TxnService", "seq_watermark", "smallbank_txn_gen",
]
