"""Abort-retry pipeline policy (DESIGN.md §8).

An aborted transaction is not an error in PostSI — it is the scheduler
telling the client to try again with a fresh interval.  The closed-loop
service re-enqueues aborted transactions with a **fresh TID** (the paper's
rules never resurrect an interval; a retry is a brand-new transaction over
the same operations) and **bounded exponential backoff** so a contended
hotspot is not hammered by its own rejects: attempt ``a`` waits
``base * 2**(a-1)`` ticks, capped at ``max_backoff``, with optional ±1 tick
jitter to break retry synchronization.  After ``max_attempts`` executions
the request is reported **dropped** — every admitted request therefore
terminates in exactly one of {committed, dropped}, which is the invariant
the property tests pin down.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff in scheduler ticks."""
    max_attempts: int = 8      # total executions (first try + retries)
    base_backoff: int = 1      # ticks before the first retry
    max_backoff: int = 16      # backoff ceiling, ticks
    jitter: bool = True        # +-1 tick to decorrelate retry storms

    def next_delay(self, attempts: int,
                   rng: np.random.RandomState | None = None) -> int | None:
        """Delay before the next execution, given ``attempts`` completed
        executions so far; ``None`` means the retry budget is exhausted and
        the request must be dropped."""
        if attempts >= self.max_attempts:
            return None
        delay = min(self.base_backoff << (attempts - 1), self.max_backoff)
        if self.jitter and rng is not None and delay > 1:
            delay += int(rng.randint(-1, 2))
        return max(1, delay)

    def worst_case_ticks(self) -> int:
        """Upper bound on ticks between admission and the final verdict —
        the horizon the drain loop and the commit-or-drop test use.  Counts
        one execution tick plus the (jitter-inflated) backoff per retry."""
        jit = 1 if self.jitter else 0
        total = 0
        for a in range(1, self.max_attempts):
            total += min(self.base_backoff << (a - 1),
                         self.max_backoff) + jit + 1
        return total + 1
