"""Executing a key-range move against the version store.

A move copies the full version rings of the moving keys from their old
physical slots to freshly-allocated slots inside the destination node's
block, then clears the sources to the empty state (``tid == NO_TID``
everywhere, so the freed rows answer no read and accept a later move-in).
Old and new slots are disjoint by construction — destinations were free —
so copy-then-clear is race-free in any order.

The move executes **under the GC watermark** like any writer: the service
only fires it at a block boundary, when no wave is in flight and every
retired reader's snapshot floor is at or below the current clock, so no
in-flight visibility computation can observe the half-moved state.  On the
mesh it is one ``shard_map`` program: a masked-answer + ``lax.psum``
gather of the source rows (the same peer-collective idiom as the read
phase) followed by owner-local masked scatters with OOB-dropped indices —
zero coordinator, like everything else on this mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.store import MVStore, NO_TID

from .map import MoveRecord

_N_STORE = len(MVStore._fields)
_EMPTY = {"val": 0, "tid": int(NO_TID), "cid": 0, "sid": 0,
          "head": 0, "wave": 0}


def _pad(slots: np.ndarray, m_pad: int) -> jnp.ndarray:
    """Pad a slot vector to ``m_pad`` with the ``-1`` sentinel (gathers see
    a non-owned row, scatters drop it) so the jitted mover retraces only on
    the padded size, not every move size."""
    out = np.full(m_pad, -1, np.int32)
    out[:slots.size] = slots
    return jnp.asarray(out)


def _pad_size(m: int) -> int:
    p = 8
    while p < m:
        p *= 2
    return p


def apply_move_local(store: MVStore, rec: MoveRecord) -> MVStore:
    """Single-device move: gather rings at old slots, scatter to new,
    clear sources to empty."""
    if rec.keys.size == 0:
        return store
    old = jnp.asarray(rec.old_slots)
    new = jnp.asarray(rec.new_slots)
    out = {}
    for name in MVStore._fields:
        a = getattr(store, name)
        out[name] = a.at[new].set(a[old]).at[old].set(_EMPTY[name])
    return MVStore(**out)


@functools.lru_cache(maxsize=None)
def _move_fn(mesh: Mesh):
    """Jitted shard_map mover; retraces per padded move size only."""

    def node_fn(*args):
        st = MVStore(*args[:_N_STORE])
        old, new = args[_N_STORE:]
        n_local = st.head.shape[0]
        base = lax.axis_index("node") * n_local
        lk_src = old - base
        mine_src = (old >= 0) & (lk_src >= 0) & (lk_src < n_local)
        gi = jnp.where(mine_src, lk_src, 0)
        # dropped scatter index: n_local is out of the local block, so
        # mode="drop" discards it (a plain clamp would corrupt the last row)
        si = jnp.where(mine_src, lk_src, n_local)
        lk_dst = new - base
        mine_dst = (new >= 0) & (lk_dst >= 0) & (lk_dst < n_local)
        di = jnp.where(mine_dst, lk_dst, n_local)
        out = []
        for name in MVStore._fields:
            a = getattr(st, name)
            rows = a[gi]
            mask = mine_src.reshape((-1,) + (1,) * (rows.ndim - 1))
            rows = lax.psum(jnp.where(mask, rows, 0), "node")
            out.append(a.at[di].set(rows, mode="drop")
                        .at[si].set(_EMPTY[name], mode="drop"))
        return tuple(out)

    return jax.jit(shard_map(
        node_fn, mesh=mesh,
        in_specs=(P("node"),) * _N_STORE + (P(), P()),
        out_specs=(P("node"),) * _N_STORE,
        check_rep=False))


def apply_move_mesh(store: MVStore, rec: MoveRecord, mesh: Mesh) -> MVStore:
    """Mesh move as one shard_map program: psum gather of the source rings,
    owner-local scatter installs, owner-local source clears."""
    if rec.keys.size == 0:
        return store
    m_pad = _pad_size(rec.keys.size)
    out = _move_fn(mesh)(*store, _pad(rec.old_slots, m_pad),
                         _pad(rec.new_slots, m_pad))
    return MVStore(*out)


def apply_move(store: MVStore, rec: MoveRecord, mesh: Mesh | None = None
               ) -> MVStore:
    if mesh is None:
        return apply_move_local(store, rec)
    return apply_move_mesh(store, rec, mesh)


def move_payload(rec: MoveRecord, seq: int, clock: int) -> dict:
    """WAL payload for a REC_MOVE frame: the explicit arrays (replay never
    re-runs the allocator) plus the log position and the watermark clock
    the move executed under."""
    return {"seq": int(seq), "clock": int(clock),
            "lo": int(rec.lo), "hi": int(rec.hi), "dst": int(rec.dst),
            "keys": np.asarray(rec.keys, np.int32),
            "old_slots": np.asarray(rec.old_slots, np.int32),
            "new_slots": np.asarray(rec.new_slots, np.int32)}


def record_from_payload(payload: dict) -> MoveRecord:
    arr = lambda x: np.asarray(x, np.int32)
    return MoveRecord(int(payload["lo"]), int(payload["hi"]),
                      int(payload["dst"]), arr(payload["keys"]),
                      arr(payload["old_slots"]), arr(payload["new_slots"]))
