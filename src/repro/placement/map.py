"""PlacementMap: host-side owner/slot tables for elastic key routing.

The contract (DESIGN.md §11):

* every logical key ``k in [0, n_keys)`` has exactly one owning node
  ``owner[k]`` and one physical store row ``slot[k]``;
* ``slot`` is injective, and ``slot[k] // capacity == owner[k]`` — a key's
  ring lives inside its owner's block of the sharded store, so the mesh
  substrate's block arithmetic (``base = axis_index * n_local``) needs no
  change: the engine translates logical keys to slots ONCE per wave and
  everything downstream is slot-space;
* ownership is maintained as contiguous logical ranges (splits/merges move
  range boundaries), but the representation of record is the per-key
  ``owner``/``slot`` arrays — ``ranges()`` is *derived* from them, so live
  state and WAL-replayed state are structurally identical by construction.

``move()`` only plans: it returns a :class:`MoveRecord` naming the exact
keys, source slots and destination slots.  Applying the record to the
store (copy rings, clear sources) is ``placement.move.apply_move``;
applying it to this map is :meth:`PlacementMap.apply_record`.  Replay from
the WAL re-applies the explicit arrays, never re-runs the allocator — so
recovery is bit-identical even if allocator heuristics change.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class PlacementError(AssertionError):
    """Routing/placement invariant violation (raised by validate_routing)."""


@dataclass(frozen=True)
class MoveRecord:
    """One executed (or planned) key-range move, fully explicit for replay."""
    lo: int                 # logical range [lo, hi) that moved
    hi: int
    dst: int                # destination node
    keys: np.ndarray        # [m] int32 logical keys (== arange(lo, hi))
    old_slots: np.ndarray   # [m] int32 source store rows
    new_slots: np.ndarray   # [m] int32 destination store rows

    def as_dict(self) -> Dict:
        return {"lo": int(self.lo), "hi": int(self.hi), "dst": int(self.dst),
                "keys": self.keys.tolist(),
                "old_slots": self.old_slots.tolist(),
                "new_slots": self.new_slots.tolist()}

    @staticmethod
    def from_dict(d: Dict) -> "MoveRecord":
        arr = lambda x: np.asarray(x, np.int32)
        return MoveRecord(int(d["lo"]), int(d["hi"]), int(d["dst"]),
                          arr(d["keys"]), arr(d["old_slots"]),
                          arr(d["new_slots"]))


class PlacementMap:
    """Mutable host-side placement state; device tables via device_arrays().

    The initial layout is *block* placement: key ``k`` is owned by node
    ``k // ceil(n_keys / n_nodes)`` at slot ``owner * capacity + offset``.
    With ``headroom=1`` and a dividing key space this is the identity slot
    map over ``n_slots == n_keys`` — bit-identical to no placement at all
    (the differential tests pin this).  ``headroom > 1`` reserves free
    slots per node so ranges can move in.
    """

    def __init__(self, n_keys: int, n_nodes: int, *, headroom: int = 1):
        if n_nodes < 1 or n_keys < 1:
            raise ValueError(f"need n_keys,n_nodes >= 1, got {n_keys},{n_nodes}")
        if headroom < 1:
            raise ValueError(f"headroom must be >= 1, got {headroom}")
        self.n_keys = int(n_keys)
        self.n_nodes = int(n_nodes)
        base = -(-n_keys // n_nodes)            # ceil: block size per node
        self.capacity = int(base * headroom)    # slots per node
        self.owner = np.empty(n_keys, np.int32)
        self.slot = np.empty(n_keys, np.int32)
        for node in range(n_nodes):
            lo, hi = node * base, min((node + 1) * base, n_keys)
            if lo >= hi:
                continue
            self.owner[lo:hi] = node
            self.slot[lo:hi] = node * self.capacity + np.arange(hi - lo)
        self._cache = None      # invalidated device_arrays cache
        self._rebuild()

    # -- derived state -----------------------------------------------------

    def _rebuild(self) -> None:
        """Recompute free-slot lists from owner/slot occupancy.  Derived, not
        tracked: live mutation and WAL replay land in identical state."""
        used = np.zeros(self.n_slots, bool)
        used[self.slot] = True
        self._free: List[List[int]] = []
        for node in range(self.n_nodes):
            blk = slice(node * self.capacity, (node + 1) * self.capacity)
            self._free.append(
                (np.nonzero(~used[blk])[0] + node * self.capacity).tolist())
        self._cache = None

    @property
    def n_slots(self) -> int:
        return self.n_nodes * self.capacity

    def ranges(self) -> List[Tuple[int, int, int]]:
        """Contiguous ownership ranges [(lo, hi, node), ...], derived."""
        out, lo = [], 0
        for k in range(1, self.n_keys + 1):
            if k == self.n_keys or self.owner[k] != self.owner[lo]:
                out.append((lo, k, int(self.owner[lo])))
                lo = k
        return out

    def owner_of(self, key: int) -> int:
        return int(self.owner[key])

    def slot_of(self, keys):
        return self.slot[np.asarray(keys, np.int64)]

    def free_slots(self, node: int) -> int:
        return len(self._free[node])

    def device_arrays(self):
        """Replicated int32 device tables (cached until the next mutation)."""
        if self._cache is None:
            import jax.numpy as jnp
            from repro.core.store import PlacementArrays
            self._cache = PlacementArrays(jnp.asarray(self.owner),
                                          jnp.asarray(self.slot))
        return self._cache

    # -- mutation ----------------------------------------------------------

    def move(self, lo: int, hi: int, dst: int) -> MoveRecord:
        """Plan moving logical range [lo, hi) to node ``dst``: allocate
        destination slots (smallest free offsets first, so replayed and live
        allocation agree) and return the explicit record.  Does NOT mutate
        this map — call :meth:`apply_record` once the store move committed."""
        if not (0 <= lo < hi <= self.n_keys):
            raise ValueError(f"bad range [{lo}, {hi}) for n_keys={self.n_keys}")
        if not (0 <= dst < self.n_nodes):
            raise ValueError(f"bad destination node {dst}")
        keys = np.arange(lo, hi, dtype=np.int32)
        moving = self.owner[lo:hi] != dst
        keys = keys[moving]
        if keys.size > len(self._free[dst]):
            raise PlacementError(
                f"node {dst} has {len(self._free[dst])} free slots, "
                f"range [{lo},{hi}) needs {keys.size}; raise headroom")
        new_slots = np.asarray(sorted(self._free[dst])[:keys.size], np.int32)
        return MoveRecord(lo, hi, dst, keys,
                          self.slot[keys].astype(np.int32), new_slots)

    def apply_record(self, rec: MoveRecord) -> None:
        """Apply an executed move to the map (live or WAL replay — same path)."""
        self.owner[rec.keys] = rec.dst
        self.slot[rec.keys] = rec.new_slots
        self._rebuild()

    # -- (de)serialization -------------------------------------------------

    def to_config(self) -> Dict:
        """Durable identity of the *initial* layout (moves replay on top)."""
        return {"n_keys": self.n_keys, "n_nodes": self.n_nodes,
                "capacity": self.capacity}

    @staticmethod
    def from_config(cfg: Dict) -> "PlacementMap":
        pm = PlacementMap(int(cfg["n_keys"]), int(cfg["n_nodes"]), headroom=1)
        cap = int(cfg["capacity"])
        if cap != pm.capacity:
            # re-derive headroom'd layout: same block assignment, wider blocks
            base = -(-pm.n_keys // pm.n_nodes)
            if cap % base:
                raise ValueError(f"capacity {cap} not a multiple of base {base}")
            pm = PlacementMap(pm.n_keys, pm.n_nodes, headroom=cap // base)
        return pm

    def validate(self) -> None:
        """Full invariant check (tests + REPRO_PLACEMENT_CHECK)."""
        if np.unique(self.slot).size != self.n_keys:
            raise PlacementError("slot map is not injective")
        if (self.slot < 0).any() or (self.slot >= self.n_slots).any():
            raise PlacementError("slot out of store range")
        if ((self.owner < 0) | (self.owner >= self.n_nodes)).any():
            raise PlacementError("owner out of node range")
        if (self.slot // self.capacity != self.owner).any():
            raise PlacementError("slot block does not match owner")


def validate_routing(n_slots: int, n_nodes: int, placement,
                     op_key=None) -> None:
    """REPRO_PLACEMENT_CHECK=1 gate: assert the owner/slot tables route every
    (touched) key into its owner's physical block before a mesh dispatch.

    This closes the documented silent-corruption hole in ``shard_store``:
    a visitor read routed to the wrong owner under static modulo sharding
    was "not an error" — with placement tables it IS detectable, because
    ``slot // n_local`` must equal ``owner`` for every key the wave touches.
    """
    if placement is None:
        return
    owner = np.asarray(placement.owner)
    slot = np.asarray(placement.slot)
    if n_slots % n_nodes:
        raise PlacementError(f"n_slots {n_slots} not divisible by {n_nodes}")
    n_local = n_slots // n_nodes
    if op_key is None:
        keys = np.arange(owner.shape[0])
    else:
        keys = np.unique(np.asarray(op_key).reshape(-1))
        keys = keys[(keys >= 0) & (keys < owner.shape[0])]
    s, o = slot[keys], owner[keys]
    if (s < 0).any() or (s >= n_slots).any():
        bad = keys[(s < 0) | (s >= n_slots)]
        raise PlacementError(f"slots out of range for keys {bad[:8].tolist()}")
    mis = s // n_local != o
    if mis.any():
        bad = keys[mis]
        raise PlacementError(
            f"mis-routed keys {bad[:8].tolist()}: slot block "
            f"{(s[mis] // n_local)[:8].tolist()} != owner {o[mis][:8].tolist()}")
    if np.unique(s).size != s.size:
        raise PlacementError("duplicate physical slots across touched keys")


def logical_store(store, placement: Optional["PlacementMap"]):
    """View a (possibly padded, possibly permuted) physical store in LOGICAL
    key order — row ``k`` is logical key ``k``'s ring.  Used by verify() and
    final-state differentials; ``placement=None`` is the identity layout."""
    if placement is None:
        return store
    perm = placement.slot_of(np.arange(placement.n_keys))
    return store._replace(**{f: getattr(store, f)[perm]
                             for f in store._fields})


def physical_store(store, placement: "PlacementMap"):
    """Inverse of :func:`logical_store`: lay a logical store (row ``k`` =
    key ``k``) out in SLOT order — key ``k``'s ring lands at physical row
    ``slot[k]``, every unmapped (free/headroom) row is EMPTY (``tid ==
    NO_TID``: answers no read, ready to receive a move-in).  This is how an
    elastic service builds its initial placed store before sharding."""
    import jax.numpy as jnp
    if store.val.shape[0] != placement.n_keys:
        raise ValueError(f"store has {store.val.shape[0]} rows, placement "
                         f"maps {placement.n_keys} keys")
    perm = jnp.asarray(placement.slot)
    out = {}
    for name in store._fields:
        a = getattr(store, name)
        fill = -1 if name == "tid" else 0        # NO_TID marks rows empty
        e = jnp.full((placement.n_slots,) + a.shape[1:], fill, a.dtype)
        out[name] = e.at[perm].set(a)
    return store._replace(**out)
