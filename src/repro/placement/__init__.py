"""Elastic placement plane (DESIGN.md §11).

Replaces the frozen ``key % n_nodes`` layout with a host-side
``PlacementMap`` (contiguous key ranges -> nodes, with per-key physical
slot assignments), live range moves executed under the GC watermark and
WAL-logged for bit-identical replay, hot-key read replicas whose
visibility floor is the ``lax.pmin`` watermark, and a load balancer that
plans splits off per-node commit/abort counters.
"""
from .balancer import LoadBalancer
from .map import (MoveRecord, PlacementError, PlacementMap, logical_store,
                  physical_store, validate_routing)
from .move import (apply_move, apply_move_local, apply_move_mesh,
                   move_payload, record_from_payload)
from .replica import HotKeyReplicas

__all__ = [
    "HotKeyReplicas",
    "LoadBalancer",
    "MoveRecord",
    "PlacementError",
    "PlacementMap",
    "apply_move",
    "apply_move_local",
    "apply_move_mesh",
    "logical_store",
    "move_payload",
    "physical_store",
    "record_from_payload",
    "validate_routing",
]
