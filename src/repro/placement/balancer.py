"""Load balancer: plans key-range splits off per-node commit/abort counters.

Observes committed-op key traffic (host-side counters fed from retired
wave outcomes) and, when the max/mean per-node load imbalance crosses
``trigger``, plans moves that peel a load-targeted contiguous prefix of
the hottest node's hottest range onto the coldest node.  The split point
is a prefix-sum walk over per-key load — a *range split*, never a
scatter, so ownership stays contiguous and the PlacementMap's range
invariant holds.  Planning is deterministic given the counters, which the
differential tests rely on.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .map import PlacementMap


class LoadBalancer:
    def __init__(self, n_keys: int, n_nodes: int, *, every: int = 4,
                 trigger: float = 1.25, max_moves: int = 2,
                 decay: float = 0.5):
        self.n_keys = int(n_keys)
        self.n_nodes = int(n_nodes)
        self.every = int(every)         # plan each `every` observed blocks
        self.trigger = float(trigger)   # max/mean imbalance threshold
        self.max_moves = int(max_moves)
        self.decay = float(decay)       # EWMA so old hot spots cool off
        self.key_ops = np.zeros(self.n_keys, np.float64)
        self.node_commits = np.zeros(self.n_nodes, np.int64)
        self.node_aborts = np.zeros(self.n_nodes, np.int64)
        self.blocks_seen = 0
        self.moves_planned = 0

    # -- observation -------------------------------------------------------

    def observe(self, op_key: np.ndarray, active: np.ndarray,
                committed: np.ndarray, owner: np.ndarray) -> None:
        """Fold one retired wave's outcomes into the counters.

        op_key/active: [T, O]; committed: [T] bool; owner: [n_keys] int.
        Per-key traffic (``key_ops``, what ``plan`` splits on) counts every
        committed transaction's active ops; the per-node occupancy counters
        (``node_commits``/``node_aborts``) count each transaction ONCE,
        charged to the owner of its first active key — committed-TXN
        occupancy, the same statistic DESIGN §11 and the service's
        ``_observe_placement`` report (counting per op skews the balancer
        toward wide-footprint ranges).  Aborts feed the abort counter only
        (abort pressure is a hot-shard symptom too, but moving keys on
        abort noise thrashes).
        """
        op_key = np.asarray(op_key)
        active = np.asarray(active, bool)
        committed = np.asarray(committed, bool)
        mask = active & committed[:, None]
        keys = op_key[mask]
        keys = keys[(keys >= 0) & (keys < self.n_keys)]
        np.add.at(self.key_ops, keys, 1.0)
        T = op_key.shape[0]
        touched = active.any(axis=1)
        first = np.argmax(active, axis=1)
        fk = op_key[np.arange(T), first]
        in_range = (fk >= 0) & (fk < self.n_keys)
        np.add.at(self.node_commits,
                  owner[fk[committed & touched & in_range]], 1)
        np.add.at(self.node_aborts,
                  owner[fk[~committed & touched & in_range]], 1)

    def end_block(self) -> bool:
        """Advance the block counter; True when a planning round is due."""
        self.blocks_seen += 1
        due = self.blocks_seen % self.every == 0
        if due:
            self.key_ops *= self.decay      # cool old traffic pre-plan
        return due

    # -- planning ----------------------------------------------------------

    def node_load(self, pm: PlacementMap) -> np.ndarray:
        load = np.zeros(self.n_nodes, np.float64)
        np.add.at(load, pm.owner, self.key_ops)
        return load

    def imbalance(self, pm: PlacementMap) -> float:
        load = self.node_load(pm)
        mean = load.mean()
        return float(load.max() / mean) if mean > 0 else 1.0

    def plan(self, pm: PlacementMap) -> List[Tuple[int, int, int]]:
        """Plan up to ``max_moves`` splits (lo, hi, dst).  Each step peels
        the prefix of the hottest node's hottest range whose load best
        approaches the surplus over the mean, onto the coldest node —
        capacity-clamped.  Works on a load copy so multi-move rounds see
        the effect of earlier moves in the same round."""
        load = self.node_load(pm)
        owner = pm.owner.copy()
        free = [pm.free_slots(n) for n in range(self.n_nodes)]
        moves: List[Tuple[int, int, int]] = []
        for _ in range(self.max_moves):
            mean = load.mean()
            if mean <= 0 or load.max() / mean < self.trigger:
                break
            hot = int(load.argmax())
            # coldest node WITH free slots: the globally coldest node being
            # full must not end the round while a cooler-than-hot node still
            # has headroom (the fullest-cluster case is exactly when hot
            # ranges most need to move)
            cold = next((int(n) for n in np.argsort(load, kind="stable")
                         if int(n) != hot and free[int(n)] > 0), None)
            if cold is None or load[cold] >= load[hot]:
                break
            split = self._split(owner, hot, cold, load, free[cold])
            if split is None:
                break
            lo, hi = split
            moved = float(self.key_ops[lo:hi].sum())
            owner[lo:hi] = cold
            load[hot] -= moved
            load[cold] += moved
            free[cold] -= hi - lo
            moves.append((lo, hi, cold))
        self.moves_planned += len(moves)
        return moves

    def _split(self, owner: np.ndarray, hot: int, cold: int,
               load: np.ndarray, cap: int) -> Optional[Tuple[int, int]]:
        """Choose [lo, hi) inside the hot node's hottest contiguous range:
        the prefix whose cumulative load is closest to half the hot-cold
        surplus (so one move meets the other halfway), >= 1 key, <= cap,
        and never the whole key set of the hot node (it must keep a key)."""
        ranges, lo = [], 0
        n = owner.shape[0]
        for k in range(1, n + 1):
            if k == n or owner[k] != owner[lo]:
                if owner[lo] == hot:
                    ranges.append((lo, k))
                lo = k
        if not ranges:
            return None
        r_lo, r_hi = max(ranges,
                         key=lambda r: float(self.key_ops[r[0]:r[1]].sum()))
        hot_keys = int((owner == hot).sum())
        width = min(r_hi - r_lo, cap, hot_keys - 1)
        if width < 1:
            return None
        prefix = np.cumsum(self.key_ops[r_lo:r_lo + width])
        target = (load[hot] - load[cold]) / 2.0
        if prefix[-1] <= 0:
            return None
        cut = int(np.argmin(np.abs(prefix - target))) + 1
        return r_lo, r_lo + cut

    def report(self) -> dict:
        return {"blocks_seen": self.blocks_seen,
                "moves_planned": self.moves_planned,
                "node_commits": self.node_commits.tolist(),
                "node_aborts": self.node_aborts.tolist()}
