"""Read-only hot-key replicas with a visibility floor (DESIGN.md §11.3).

The paper's core selling point made concrete: under visibility CC a
*stale-but-consistent* replica read is nearly free.  Every version visible
at snapshot ``s = watermark`` is **frozen** — any future writer commits at
``cid > clock >= watermark``, so the visible-at-watermark version set can
never change — and a reader pinned at ``s = c = watermark`` needs no SID
bump either: rule 4(c) raises SID to protect the reader from writers with
``cid <= s``, and no such writer can still commit.  So a replica serves
reads with ZERO coordination: no ownership check, no visitor message, no
interval negotiation.  The staleness bound is exactly the watermark lag.

``HotKeyReplicas`` keeps host-side numpy snapshots (``val``/``cid`` per
replicated key) refreshed from the store via ``read_visible`` at the
current ``lax.pmin`` GC watermark.  A read-only transaction whose keys are
all replicated is answered at submit time and never enters the engine —
writes still route to the owner and advance the ring, which the next
refresh picks up.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.commit_phase import NOP, READ
from repro.core.store import MVStore, read_visible


class HotKeyReplicas:
    """Replicated read-only snapshots of a hot key set at a visibility floor.

    ``keys`` are LOGICAL keys; ``slot_of`` (when elastic) maps them to
    physical store rows at refresh time, so replicas follow keys through
    range moves transparently.
    """

    def __init__(self, keys) -> None:
        self.keys = np.unique(np.asarray(keys, np.int64))
        self.floor = -1                       # watermark of the last refresh
        self.refreshes = 0
        self.served = 0                       # read ops answered locally
        # dense key-indexed snapshots: ``can_serve`` runs on EVERY submit,
        # so membership and value lookups must be vectorized array hits,
        # not per-key python dict probes
        hi = int(self.keys.max()) + 1 if self.keys.size else 1
        self._member = np.zeros(hi, bool)
        self._member[self.keys] = True
        self._val = np.zeros(hi, np.int32)
        self._cid = np.zeros(hi, np.int32)

    def can_serve(self, op_kind: np.ndarray, op_key: np.ndarray) -> bool:
        """True iff the txn is read-only (every active op is a READ) and
        every active op's key is in the replica set."""
        if self.floor < 0:
            return False
        kinds = np.asarray(op_kind)
        keys = np.asarray(op_key)
        active = kinds != NOP
        if not active.any() or (kinds[active] != READ).any():
            return False
        ka = keys[active]
        # clamp BOTH ends before indexing: a negative (padding/adversarial)
        # key would wrap via Python negative indexing into ``_member`` and
        # could report false membership, serving a garbage snapshot
        ok = (ka >= 0) & (ka < self._member.size)
        return bool((ok & self._member[
            np.clip(ka, 0, self._member.size - 1)]).all())

    def serve(self, op_kind: np.ndarray, op_key: np.ndarray):
        """Answer a read-only txn from the replica snapshot.  Returns
        (values, snapshot) — the txn commits with s = c = floor."""
        keys = np.asarray(op_key)[np.asarray(op_kind) != NOP]
        vals = self._val[keys].astype(np.int32)
        self.served += int(keys.size)
        return vals, self.floor

    def refresh(self, store: MVStore, floor: int,
                slot_of: Optional[np.ndarray] = None) -> None:
        """Re-snapshot every replicated key at visibility floor ``floor``
        (the merged GC watermark).  One batched ``read_visible`` gather —
        this is the whole replication protocol; no invalidation traffic is
        needed because the floor only moves forward and versions visible at
        or below it are immutable."""
        if self.keys.size == 0:
            self.floor = max(self.floor, int(floor))
            return
        rows = self.keys if slot_of is None else slot_of[self.keys]
        k = jnp.asarray(rows, jnp.int32)
        wm = jnp.broadcast_to(jnp.int32(floor), k.shape)
        val, _, cid, _, _ = read_visible(store, k, wm)
        self._val[self.keys] = np.asarray(val)
        self._cid[self.keys] = np.asarray(cid)
        self.floor = int(floor)
        self.refreshes += 1

    def max_cid(self) -> int:
        """Largest commit timestamp any replica answer could carry — the
        staleness-property tests assert this never exceeds the floor."""
        return int(self._cid[self.keys].max()) if self.keys.size else 0

    def report(self) -> Dict:
        return {"n_keys": int(self.keys.size), "floor": int(self.floor),
                "refreshes": self.refreshes, "served_reads": self.served}
