"""PostSI-committed distributed checkpoints (the paper as a framework
feature — DESIGN.md §3.1).

Every checkpoint *save* is a PostSI writer transaction over a versioned
object store: one logical key per parameter leaf, the value being a content
file handle.  Every *restore* is a read-only transaction: CID-based
visibility (paper §IV-B) guarantees it observes an **atomic snapshot** —
never a torn mix of two checkpoints — without any central "latest-step"
counter or manifest lock.  Concurrent save/restore interleavings are safe by
the paper's Theorem 1; tests/test_checkpoint.py exercises exactly the torn
read scenario.

Elastic restore: leaves are stored by logical tree path, so loading onto a
*different* mesh re-shards via ``jax.device_put`` with the new sharding
(``reshard_tree``) — the basis for elastic scaling and shrink/grow restarts.
"""
from __future__ import annotations

import os
import pickle
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.seq import SeqScheduler


def _leaf_paths(tree) -> List[str]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in leaves]


def _path_mismatch(saved: List[str], given: List[str]) -> str:
    """Human-readable diff of two leaf-path lists for the errors below."""
    missing = [p for p in saved if p not in given]
    unexpected = [p for p in given if p not in saved]
    parts = []
    if missing:
        parts.append(f"missing from tree_example: {missing[:4]}")
    if unexpected:
        parts.append(f"not in checkpoint: {unexpected[:4]}")
    if not parts:          # same set, different order
        parts.append("leaf order differs")
    return "; ".join(parts)


class PostSICheckpointer:
    """Directory layout: <dir>/<key_id>_<file_id>.npy + postsi_meta.pkl.

    The scheduler state (version chains of file handles) *is* the metadata;
    there is no manifest file naming "the" checkpoint — the latest consistent
    snapshot is induced from visibility, per the paper.
    """

    META = "postsi_meta.pkl"

    def __init__(self, directory: str, tree_example):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.paths = _leaf_paths(tree_example)
        self.key_of = {p: i for i, p in enumerate(self.paths)}
        self.meta_corrupt = False      # True when a damaged meta was ignored
        # +1 key: the step counter rides the same transaction
        meta = os.path.join(directory, self.META)
        saved = None
        if os.path.exists(meta):
            try:
                with open(meta, "rb") as f:
                    saved = pickle.load(f)
                if not isinstance(saved, dict) or \
                        {"sched", "next_file", "paths"} - saved.keys():
                    raise ValueError("meta missing required keys")
            except Exception:
                # a torn/bit-rotted meta must degrade, not kill: treat the
                # directory as holding no committed checkpoint (restore then
                # returns (None, None) and durable recovery falls back to a
                # full WAL replay — DESIGN.md §9); the next successful save
                # rewrites a clean meta
                saved = None
                self.meta_corrupt = True
        if saved is not None:
            if saved["paths"] != self.paths:
                raise ValueError(
                    "PostSICheckpointer: checkpointed tree structure does "
                    "not match tree_example; "
                    + _path_mismatch(saved["paths"], self.paths))
            self.sched: SeqScheduler = saved["sched"]
            self._next_file = saved["next_file"]
        else:
            self.sched = SeqScheduler(len(self.paths) + 1, mode="postsi")
            self._next_file = 1

    def _persist_meta(self) -> None:
        with open(os.path.join(self.dir, self.META), "wb") as f:
            pickle.dump({"sched": self.sched, "next_file": self._next_file,
                         "paths": self.paths}, f)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> bool:
        """One writer transaction: write every leaf + the step key, commit."""
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        tid = self.sched.begin()
        for pth, leaf in leaves:
            key = self.key_of[jax.tree_util.keystr(pth)]
            fid = self._next_file
            self._next_file += 1
            np.save(os.path.join(self.dir, f"{key}_{fid}.npy"),
                    np.asarray(leaf))
            self.sched.write(tid, key, fid)
        self.sched.write(tid, len(self.paths), step)
        ok = self.sched.commit(tid)
        if ok:
            self._persist_meta()
        return ok

    # --------------------------------------------------------------- restore
    def restore(self, tree_example, shardings=None) -> Tuple[Optional[int], Any]:
        """One reader transaction over all leaves: PostSI guarantees the file
        handles form one atomic checkpoint. Returns (step, tree) or (None,
        None) when no committed checkpoint exists.

        ``tree_example`` must have the same leaf paths as the checkpointed
        tree — a mismatch is rejected HERE with a readable error instead of
        failing deep inside ``tree_unflatten`` (or, worse, silently loading
        leaves under the wrong paths when only the order changed)."""
        paths = _leaf_paths(tree_example)
        if paths != self.paths:
            raise ValueError(
                "PostSICheckpointer.restore: tree_example leaf paths do not "
                "match the checkpointed tree; "
                + _path_mismatch(self.paths, paths))
        tid = self.sched.begin()
        step = self.sched.read(tid, len(self.paths))
        if step is None or step == 0:
            self.sched.abort(tid)
            return None, None
        handles = {}
        for p in self.paths:
            key = self.key_of[p]
            fid = self.sched.read(tid, key)
            if fid is None or fid == 0:
                self.sched.abort(tid)
                return None, None
            handles[key] = fid
        assert self.sched.commit(tid)

        leaves_ex = jax.tree_util.tree_flatten_with_path(tree_example)
        flat, treedef = leaves_ex
        out = []
        shard_flat = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        for (pth, ex), sh in zip(flat, shard_flat):
            key = self.key_of[jax.tree_util.keystr(pth)]
            arr = np.load(os.path.join(self.dir, f"{key}_{handles[key]}.npy"))
            arr = arr.astype(ex.dtype) if hasattr(ex, "dtype") else arr
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return int(step), jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------- gc
    def gc(self, keep_latest: int = 2) -> int:
        """Drop files not reachable from the last ``keep_latest`` versions."""
        live = set()
        for key in range(len(self.paths)):
            chain = self.sched.versions[key]
            for v in chain[-keep_latest:]:
                live.add((key, v.value))
        removed = 0
        for fn in os.listdir(self.dir):
            if not fn.endswith(".npy"):
                continue
            key, fid = (int(x) for x in fn[:-4].split("_"))
            if (key, fid) not in live:
                os.remove(os.path.join(self.dir, fn))
                removed += 1
        return removed


def reshard_tree(tree, shardings):
    """Elastic reshard: place every leaf per the (new-mesh) sharding tree."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
