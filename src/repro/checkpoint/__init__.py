from .postsi_store import PostSICheckpointer, reshard_tree
